"""Batched serving with SASP-deployed weights + int8 KV cache.

Trains nothing — builds a small model, deploys it three ways (dense /
SASP-masked / SASP+int8-KV) and serves the same request batch through
the slot-based engine, comparing outputs and reporting per-path step
timings.

Run: PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import prune_params
from repro.models import lm
from repro.serve.engine import Engine, Request


def main():
    sasp = SASPConfig(enabled=True, block_k=16, block_n=16, sparsity=0.25)
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-32b"), layers=4, d_model=128, vocab=256),
        sasp=sasp)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=(n,)).astype(np.int32)
               for n in (17, 33, 8, 25, 40, 12)]

    def requests():
        return [Request(rid=i, prompt=p, max_new_tokens=16)
                for i, p in enumerate(prompts)]

    results = {}
    for name, (p, c) in {
        "dense": (params, cfg),
        "sasp-25%": (prune_params(params, sasp)[0], cfg),
        "sasp+int8kv": (prune_params(params, sasp)[0],
                        dataclasses.replace(cfg, kv_quant=True)),
    }.items():
        eng = Engine(p, c, batch_slots=4, cache_len=128)
        t0 = time.time()
        done = eng.run(requests())
        dt = time.time() - t0
        outs = {r.rid: r.out_tokens for r in done}
        results[name] = outs
        total_toks = sum(len(v) for v in outs.values())
        print(f"{name:12s}: {len(done)} requests, {total_toks} tokens in "
              f"{dt:.1f}s ({dt/total_toks*1e3:.0f} ms/token on CPU)")

    agree = sum(
        int(results["sasp-25%"][i] == results["sasp+int8kv"][i])
        for i in results["dense"])
    diff = sum(
        int(results["dense"][i] != results["sasp-25%"][i])
        for i in results["dense"])
    print(f"\nint8-KV vs fp-KV (same pruned weights): {agree}/"
          f"{len(prompts)} sequences identical")
    print(f"pruning changed {diff}/{len(prompts)} sequences "
          f"(untrained model — the QoS tier quantifies the real effect)")
    first = results["dense"][0][:8]
    print(f"sample continuation (dense, req 0): {first}")


if __name__ == "__main__":
    main()
