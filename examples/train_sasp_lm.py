"""End-to-end training driver: train an LM, apply SASP mid-training with
the cubic pruning schedule (straight-through masks), checkpoint
atomically, simulate a failure, restore and continue bit-exact.

Default config is container-sized (~12 M params, 300 steps, minutes on
1 CPU core); ``--full`` selects the ~100 M-param musicgen-family config
(same code path, hours on CPU, normal on a real accelerator).

Run: PYTHONPATH=src python examples/train_sasp_lm.py [--steps N] [--full]
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import cubic_sparsity_schedule
from repro.core.sasp import build_sasp_overlay
from repro.data.pipeline import DataConfig, DataState, Pipeline
from repro.models import lm
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.schedule import StragglerWatchdog, warmup_cosine
from repro.train.train_step import make_train_step


def build_cfg(full: bool):
    base = get_config("musicgen-medium")     # decoder family of the run
    if full:
        # ~100M: 12L, d=768 (musicgen-small-ish)
        cfg = dataclasses.replace(
            base, num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=2048,
            frontend="none", param_dtype="float32",
            compute_dtype="float32", remat="none")
    else:
        cfg = dataclasses.replace(
            reduced(base, layers=6, d_model=256, vocab=512),
            d_ff=1024, num_heads=8, num_kv_heads=8, head_dim=32,
            frontend="none", remat="none")
    return dataclasses.replace(
        cfg, sasp=SASPConfig(enabled=True, block_k=32, block_n=32,
                             sparsity=0.25))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/sasp_lm_ckpt")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = build_cfg(args.full)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}-family, {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = Pipeline(dcfg, kind="lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)
    sched = warmup_cosine(30, args.steps)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    wd = StragglerWatchdog()

    prune_start = args.steps // 3
    prune_end = 2 * args.steps // 3
    overlay = None
    jit_cache = {}

    def get_step(overlay_key, overlay):
        if overlay_key not in jit_cache:
            step = make_train_step(cfg, opt_cfg, overlay=overlay,
                                   lr_schedule=sched)
            jit_cache[overlay_key] = jax.jit(step, donate_argnums=(0, 1))
        return jit_cache[overlay_key]

    t_start = time.time()
    i = 0
    crash_at = args.steps // 2           # simulated failure
    restored = False
    losses = []
    while i < args.steps:
        # pruning schedule: recompute masks when the target rate moves
        target = round(cubic_sparsity_schedule(
            i, start_step=prune_start, end_step=prune_end,
            final_sparsity=cfg.sasp.sparsity), 2)
        key = target
        if target > 0 and (overlay is None or key not in jit_cache):
            sasp_i = dataclasses.replace(cfg.sasp, sparsity=target)
            overlay, got = build_sasp_overlay(params, sasp_i)
            print(f"  step {i}: SASP masks -> {got:.1%} sparsity")
        step_fn = get_step(key if target > 0 else "dense",
                           overlay if target > 0 else None)

        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch)
        slow = wd.observe(time.time() - t0)
        losses.append(float(metrics["loss"]))
        i += 1

        if i % wd.checkpoint_every(50) == 0 or i == crash_at:
            mgr.wait()
            mgr.save_async(i, {"params": params, "opt": opt},
                           extra=pipe.state.to_dict())
        if i % 25 == 0:
            print(f"  step {i:4d} loss {losses[-1]:.4f} "
                  f"({'SLOW ' if slow else ''}ewma "
                  f"{wd.ewma*1e3:.0f}ms/step)")

        if i == crash_at and not restored:
            print(f"  === simulating failure at step {i}; "
                  f"restoring from checkpoint ===")
            mgr.wait()
            like = jax.eval_shape(lambda: {"params": params, "opt": opt})
            state, extra = mgr.restore(like)
            params, opt = state["params"], state["opt"]
            pipe = Pipeline(dcfg, kind="lm",
                            state=DataState.from_dict(extra))
            i = mgr.latest_step()
            restored = True

    mgr.wait()
    dt = time.time() - t_start
    print(f"\ndone in {dt:.0f}s ({dt/args.steps*1e3:.0f} ms/step): "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(final sparsity {cfg.sasp.sparsity:.0%}, straggler flags: "
          f"{wd.slow_steps})")
    assert losses[-1] < losses[0] * 0.8, "training did not converge"


if __name__ == "__main__":
    main()
