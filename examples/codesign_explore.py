"""The paper's co-design flow (Fig 2) end to end: sweep (tile × rate ×
quant) with the cost model + measured-or-proxy QoS, pick the best design
under a QoS budget, print the full trade-off table and the Pareto set.

Run: PYTHONPATH=src python examples/codesign_explore.py [--qos-target X]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import load_qos, measured_qos_fn
from repro.core.codesign import (
    best_under_qos,
    exponential_qos_proxy,
    pareto_front,
    sweep,
)
from repro.core.cost_model import encoder_gemms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qos-target", type=float, default=None)
    args = ap.parse_args()

    qos = load_qos()
    if qos is not None:
        qos_fn, src = measured_qos_fn(qos), "measured (trained model)"
        target = args.qos_target or qos["base_ter"] + 1.5
    else:
        qos_fn, src = exponential_qos_proxy(), "proxy (paper-shaped)"
        target = args.qos_target or 5.0

    builder = lambda s: encoder_gemms(num_layers=18, d_model=512,
                                      d_ff=2048, seq=512, ffn_sparsity=s)
    pts = sweep(builder, qos_fn)
    print(f"QoS source: {src}; target <= {target:.2f}%")
    print(f"{len(pts)} design points; Pareto front: "
          f"{len(pareto_front(pts))}")

    print("\nbest design per (tile, quant) under the QoS budget:")
    sel = best_under_qos(pts, target)
    for (tile, quant), p in sorted(sel.items()):
        print(f"  {tile:2d}x{tile:<2d} {quant}: prune {p.sparsity:4.0%} "
              f"qos {p.qos:5.2f}%  speedup {p.speedup:6.2f}x  "
              f"E {p.energy_j:6.2f} J  area {p.area_mm2:5.2f} mm2")

    best = max(sel.values(), key=lambda p: p.speedup / p.area_energy)
    print(f"\nrecommended edge design (speedup per area-energy): "
          f"{best.tile}x{best.tile} {best.quant} @ {best.sparsity:.0%} "
          f"pruning -> {best.speedup:.1f}x, {best.energy_j:.2f} J, "
          f"{best.area_mm2:.2f} mm2, QoS {best.qos:.2f}%")


if __name__ == "__main__":
    main()
