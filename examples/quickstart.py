"""SASP in 60 seconds: build a small model, prune it with the paper's
global-L1 tile selection, run all three execution paths, and estimate
the edge-accelerator speedup with the paper-calibrated cost model.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SASPConfig, get_config, reduced
from repro.core.cost_model import SystolicConfig, encoder_gemms, \
    speedup_vs_cpu
from repro.core.pruning import compute_sasp_masks, prune_params
from repro.core.sasp import bsr_overlay_from_masks, build_sasp_overlay, \
    merge_overlay
from repro.models import lm


def main():
    print("=== 1. a small qwen3-family model ===")
    sasp = SASPConfig(enabled=True, block_k=16, block_n=16, sparsity=0.3)
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-32b"), layers=4, d_model=128, vocab=256),
        sasp=sasp)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 256)
    print(f"params: {sum(x.size for x in jax.tree.leaves(params)):,}")

    print("\n=== 2. SASP: global-L1 tile pruning (paper §3.1) ===")
    overlay, achieved = build_sasp_overlay(params, sasp)
    print(f"requested sparsity 30%, achieved {achieved:.1%} "
          f"(tile = {sasp.block_k}x{sasp.block_n}, FF scope)")

    l_dense = float(lm.loss_fn(params, cfg, {"tokens": toks})[0])
    l_masked = float(lm.loss_fn(merge_overlay(params, overlay), cfg,
                                {"tokens": toks})[0])
    print(f"loss dense={l_dense:.4f}  pruned(masked)={l_masked:.4f}")

    print("\n=== 3. the three execution paths agree ===")
    masks = compute_sasp_masks(params, sasp)
    pruned, _ = prune_params(params, sasp)
    bsr_overlay = bsr_overlay_from_masks(params, masks, sasp)
    for path in ("bsr", "kernel"):
        cfg_p = dataclasses.replace(
            cfg, sasp=dataclasses.replace(sasp, path=path))
        l = float(lm.loss_fn(merge_overlay(params, bsr_overlay), cfg_p,
                             {"tokens": toks})[0])
        print(f"  {path:7s}: loss={l:.4f} (Δ vs masked "
              f"{abs(l - l_masked):.2e})")

    print("\n=== 4. edge-accelerator speedup (paper-calibrated model) ===")
    for tile in (8, 32):
        for quant in ("fp32", "int8"):
            sa = SystolicConfig(tile, quant)
            dense_sp = speedup_vs_cpu(sa, encoder_gemms(
                num_layers=18, d_model=512, d_ff=2048, seq=512))
            sasp_sp = speedup_vs_cpu(sa, encoder_gemms(
                num_layers=18, d_model=512, d_ff=2048, seq=512,
                ffn_sparsity=0.2))
            print(f"  {tile:2d}x{tile:<2d} {quant}: dense {dense_sp:6.2f}x"
                  f" -> SASP@20% {sasp_sp:6.2f}x vs CPU")
    print("\ndone — see examples/train_sasp_lm.py for the full loop.")


if __name__ == "__main__":
    main()
