import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and record memory/cost/roofline analysis.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above executes before any other import pulls in jax,
because jax locks the device count on first init. Do NOT import this
module from test/bench processes that want 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
  python -m repro.launch.dryrun --arch jamba-1.5-large-398b --all-shapes
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             sasp_bsr_sparsity: float = 0.0, remat: str = "full",
             quant_weights: bool = False, n_microbatches: int = 1,
             profile: str = "tp", kv_quant: bool = False,
             tp_comm: str = "ar",
             out_dir: str = None, verbose: bool = True):
    """Lower + compile one (arch × shape × mesh) cell; return CellReport."""
    from repro.analysis.roofline import analyze_compiled, format_row
    from repro.configs import get_config, get_shape
    from repro.distribution import sharding as shd
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh
    from repro.train.optimizer import AdamWConfig

    cfg = get_config(arch)
    # pad vocab to a TP-shardable multiple (real deployments pad the
    # embedding; unpadded 50280-style vocabs force replicated logits)
    vpad = -(-cfg.vocab_size // 2048) * 2048
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16",
                              compute_dtype="bfloat16", remat=remat,
                              vocab_size=vpad, kv_quant=kv_quant,
                              tp_comm=tp_comm)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size

    from repro.distribution import context as dctx

    t0 = time.time()
    with mesh, dctx.use_mesh(mesh, profile=profile):
        params_shape = S.abstract_params(cfg)
        if sasp_bsr_sparsity > 0.0 or quant_weights:
            from repro.launch.sasp_abstract import abstract_bsr_params
            params_shape, cfg = abstract_bsr_params(
                params_shape, cfg, sasp_bsr_sparsity,
                quantize=quant_weights)
        param_sh = shd.param_shardings(cfg, params_shape, mesh,
                                       profile=profile)

        inputs = S.input_specs(cfg, shape)
        in_sh = S.input_shardings(cfg, shape, mesh, inputs,
                                  profile=profile)
        step = S.make_step_fn(cfg, shape)

        if shape.kind == "train":
            opt_cfg = AdamWConfig(quantized=True)
            from repro.launch.specs import abstract_opt_state
            opt_shape = abstract_opt_state(cfg, opt_cfg, params_shape)
            from repro.train.optimizer import opt_state_shardings
            opt_sh = opt_state_shardings(cfg, params_shape, mesh, opt_cfg,
                                         param_sh)
            step = S.make_step_fn(cfg, shape, opt_cfg=opt_cfg,
                                  n_microbatches=n_microbatches)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, in_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, inputs)
        else:
            out_cache_sh = in_sh.get("caches")
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, in_sh),
                out_shardings=(None, out_cache_sh)
                if shape.kind == "decode" else None,
                donate_argnums=(1,) if shape.kind == "decode" else (),
            )
            lowered = jitted.lower(params_shape, inputs)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    notes = []
    if sasp_bsr_sparsity:
        notes.append(f"sasp_bsr={sasp_bsr_sparsity}")
    if quant_weights:
        notes.append("int8")
    if n_microbatches > 1:
        notes.append(f"mb={n_microbatches}")
    if profile != "tp":
        notes.append(profile)
    if kv_quant:
        notes.append("kv8")
    if tp_comm != "ar":
        notes.append(tp_comm)
    rep = analyze_compiled(arch, shape, mesh_name, chips, compiled, cfg,
                           note=";".join(notes),
                           sparsity=sasp_bsr_sparsity,
                           weight_quant_bytes=1 if quant_weights else 0)
    if verbose:
        print(format_row(rep) + f"  lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s", flush=True)
        ma = compiled.memory_analysis()
        print(f"    memory_analysis: args="
              f"{ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"(per device)", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_name}"
        if sasp_bsr_sparsity:
            tag += f"_sasp{int(sasp_bsr_sparsity*100)}"
        if quant_weights:
            tag += "_int8"
        if n_microbatches > 1:
            tag += f"_mb{n_microbatches}"
        if profile != "tp":
            tag += f"_{profile}"
        if kv_quant:
            tag += "_kv8"
        if tp_comm != "ar":
            tag += f"_{tp_comm}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            f.write(rep.to_json())
    return rep


def run_all(multi_pod: bool, out_dir: str, archs=None):
    from repro.configs import ASSIGNED_ARCHS, get_config, shapes_for, \
        skipped_shapes_for

    reports, failures = [], []
    for arch in archs or ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for sh in shapes_for(cfg):
            try:
                reports.append(run_cell(arch, sh.name, multi_pod=multi_pod,
                                        out_dir=out_dir))
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, sh.name, repr(e)))
        for sk in skipped_shapes_for(cfg):
            print(f"{arch:26s} {sk:12s} SKIP (full-attention arch; "
                  f"see DESIGN.md §5)", flush=True)
    print(f"\n{len(reports)} cells compiled, {len(failures)} failures")
    for f in failures:
        print("FAIL:", f)
    return reports, failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--all-shapes", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sasp", type=float, default=0.0,
                    help="SASP BSR sparsity variant (hillclimb)")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--profile", default="tp")
    ap.add_argument("--kvquant", action="store_true")
    ap.add_argument("--tp-comm", default="ar")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        _, failures = run_all(args.multi_pod, args.out)
        sys.exit(1 if failures else 0)
    if args.all_shapes:
        from repro.configs import get_config, shapes_for
        for sh in shapes_for(get_config(args.arch)):
            run_cell(args.arch, sh.name, multi_pod=args.multi_pod,
                     sasp_bsr_sparsity=args.sasp, remat=args.remat,
                     out_dir=args.out)
        return
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
             sasp_bsr_sparsity=args.sasp, remat=args.remat,
             n_microbatches=args.microbatches, quant_weights=args.quant,
             profile=args.profile, kv_quant=args.kvquant,
             tp_comm=args.tp_comm, out_dir=args.out)


if __name__ == "__main__":
    main()
