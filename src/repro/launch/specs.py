"""Abstract input/param/state specs for the dry-run — ShapeDtypeStruct
stand-ins only, weak-type-correct, shardable, no device allocation."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distribution import sharding as shd
from repro.models import lm
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: AdamWConfig,
                       params_shape=None):
    ps = params_shape or abstract_params(cfg)
    return jax.eval_shape(partial_adamw_init(opt_cfg), ps)


def partial_adamw_init(opt_cfg: AdamWConfig):
    from repro.train.optimizer import adamw_init

    def fn(params):
        return adamw_init(params, opt_cfg)

    return fn


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                decode_cache_len: Optional[int] = None) -> Dict[str, Any]:
    """Model inputs for one step of the given shape kind.

    train / prefill: {tokens (B, S) int32 [, embeds (B, S, d)]}
    decode:          {tokens (B, 1) int32 [, embeds], pos (B,), caches}
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    if shape.kind in ("train", "prefill"):
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.frontend != "none":
            out["embeds"] = sds((B, S, cfg.d_model), cdt)
        return out
    # decode: one new token against a cache of size seq_len
    cache_len = decode_cache_len or S
    caches = jax.eval_shape(
        lambda: lm.init_caches(None, cfg, B, cache_len))
    out = {"tokens": sds((B, 1), jnp.int32),
           "pos": sds((B,), jnp.int32),
           "caches": caches}
    if cfg.frontend != "none":
        out["embeds"] = sds((B, 1, cfg.d_model), cdt)
    return out


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    inputs: Dict[str, Any],
                    profile: str = "tp") -> Dict[str, Any]:
    B = shape.global_batch
    dp = shd.dp_axes(mesh, profile)
    ok = B % shd.axis_size(mesh, dp) == 0 and B > 1
    bspec = P(dp) if ok else P()
    out: Dict[str, Any] = {}
    for k, v in inputs.items():
        if k == "caches":
            out[k] = shd.cache_shardings(cfg, mesh, B, v)
        elif k == "pos":
            out[k] = NamedSharding(mesh, bspec)
        else:
            nd = len(v.shape)
            out[k] = NamedSharding(
                mesh, P(*(tuple(bspec) + (None,) * (nd - 1))) if ok
                else P(*(None,) * nd))
    return out


# ---------------------------------------------------------------------------
# Step functions per shape kind (what the dry-run lowers)
# ---------------------------------------------------------------------------


def make_step_fn(cfg: ModelConfig, shape: ShapeConfig,
                 opt_cfg: Optional[AdamWConfig] = None,
                 overlay=None, n_microbatches: int = 1):
    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig(quantized=True)
        tstep = make_train_step(cfg, opt_cfg, overlay=overlay,
                                n_microbatches=n_microbatches)

        def train_step(params, opt_state, batch):
            return tstep(params, opt_state, batch)

        return train_step

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, caches = lm.prefill(
                params, cfg, tokens=batch["tokens"],
                embeds=batch.get("embeds"))
            # serving returns greedy next-token ids + the cache
            return jnp.argmax(logits, axis=-1), caches

        return prefill_step

    def serve_step(params, batch):
        logits, caches = lm.decode_step(
            params, cfg, batch["tokens"], batch["pos"], batch["caches"],
            embeds=batch.get("embeds"))
        return jnp.argmax(logits, axis=-1), caches

    return serve_step
