"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state (the dry-run sets
``xla_force_host_platform_device_count`` before first jax init)."""
from __future__ import annotations

import os

import jax

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def ensure_fake_cpu_devices(n: int) -> None:
    """Give the CPU platform ``n`` fake devices (mesh runs on dev boxes /
    CI). MUST be called before JAX initializes its backends — before the
    first jax operation; merely importing jax is fine. No-op when the
    flag is already set; harmless on real accelerators (the flag only
    affects the CPU platform's device count)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} " + flags)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axes)


def make_test_mesh(n_devices: int = None, model: int = 2):
    """Small mesh over however many (possibly fake) devices exist — used
    by the subprocess multi-device tests."""
    n = n_devices or len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
