"""Serving launcher CLI: load (or init) a model, optionally deploy SASP
(prune + INT8 + int8-KV), pick an execution path, and serve synthetic
requests through the batched engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduce \
      --sasp 0.5 --path packed --int8-kv --requests 8

Paths (DESIGN.md §4 + §9):
  dense   — unpruned baseline.
  masked  — pruned tiles zeroed in place, matmuls stay dense.
  bsr     — BlockSparseWeight containers, gathered jnp matmul.
  kernel  — same containers through the Pallas tile-skip kernel
            (re-flattens the padded k_max × NB list per call).
  packed  — `core.deploy.deploy_packed` compact containers: sorted block
            lists + fused bias/act epilogues + fused gated-FFN kernel.
            The serving fast path.
"""
from __future__ import annotations

import argparse
import dataclasses
import re
import threading
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import prune_params
from repro.core.sasp import bsr_overlay_from_masks, merge_overlay, \
    quantize_params
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.telemetry import Telemetry, pcts_ms
from repro.train.checkpoint import CheckpointManager

PATHS = ("dense", "masked", "bsr", "kernel", "packed")


def build_serving_params(params, cfg, *, path: str, sparsity: float,
                         int8_weights: bool = False,
                         block_k: int = 32, block_n: int = 32,
                         scope: str = "ffn", verbose: bool = True,
                         mesh=None):
    """Deploy `params` for serving along one execution path. Returns
    (params, cfg) ready for the Engine. ``mesh``: TP-shard the packed
    visit lists by the mesh's 'model' axis (packed path only,
    DESIGN.md §10)."""
    assert path in PATHS, path
    if path == "dense" or sparsity <= 0:
        return params, cfg
    sasp = SASPConfig(enabled=True, block_k=block_k, block_n=block_n,
                      sparsity=sparsity, scope=scope,
                      quantize=int8_weights)
    cfg = dataclasses.replace(cfg, sasp=sasp)
    params, masks = prune_params(params, sasp)
    if verbose:
        print(f"SASP deployed: {sparsity:.0%} tile sparsity, "
              f"{len(masks)} matrices, path={path}")
    if path == "masked":
        if int8_weights:
            params = quantize_params(params, sasp)
            if verbose:
                print("weights quantized to INT8 (per-block scales)")
        return params, cfg
    if path in ("bsr", "kernel"):
        overlay = bsr_overlay_from_masks(params, masks, sasp)
        params = merge_overlay(params, overlay)
        cfg = dataclasses.replace(
            cfg, sasp=dataclasses.replace(sasp, path=path))
        return params, cfg
    # packed: compact kernel containers, built once at load time
    from repro.core.deploy import deploy_packed, packed_summary
    params, cfg = deploy_packed(params, cfg, mesh=mesh)
    if verbose:
        s = packed_summary(params)
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        shard = f", {tp}-way shard-local visit lists" if tp > 1 else ""
        print(f"packed: {s['n_packed_matrices']} matrices + "
              f"{s['n_fused_ffns']} fused FFNs, "
              f"{s['compression']:.2f}x dense bytes{shard}")
    return params, cfg


def parse_mesh(spec: Optional[str]):
    """'dp,tp' -> a (data, model) Mesh, forcing enough fake CPU devices
    when the host platform would otherwise come up short (harmless on
    real accelerators: the flag only affects the CPU platform, and it
    must be set before JAX first initializes its backends). Malformed
    specs fail HERE with a usage message, not as a downstream
    make_mesh/submesh shape error."""
    if not spec:
        return None
    m = re.fullmatch(r"\s*(\d+)\s*,\s*(\d+)\s*", spec)
    if not m:
        raise SystemExit(
            f"--mesh expects 'DP,TP' — two comma-separated positive "
            f"integers, e.g. --mesh 2,2 — got {spec!r}")
    dp, tp = int(m.group(1)), int(m.group(2))
    if dp < 1 or tp < 1:
        raise SystemExit(
            f"--mesh axes must both be >= 1, got {spec!r}")
    from repro.launch.mesh import ensure_fake_cpu_devices
    ensure_fake_cpu_devices(dp * tp)
    import jax
    if len(jax.devices()) < dp * tp:
        raise SystemExit(
            f"--mesh {spec} needs {dp * tp} devices, have "
            f"{len(jax.devices())} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={dp * tp} before "
            "any jax import initializes the backend)")
    return jax.make_mesh((dp, tp), ("data", "model"))


def parse_buckets(spec: Optional[str], cache_len: int
                  ) -> Optional[Tuple[int, ...]]:
    """--buckets 'N' -> geometric table of N lengths topping out at
    --cache-len (distribution.sharding.prefill_bucket_table);
    --buckets 'l1,l2,…' -> explicit lengths. None/'' -> exact shapes."""
    if not spec:
        return None
    try:
        if "," in spec:
            lens = tuple(int(v) for v in spec.split(","))
        else:
            lens = int(spec)
    except ValueError:
        raise SystemExit(
            f"--buckets expects an int count (e.g. --buckets 4) or "
            f"comma-separated lengths (e.g. --buckets 32,64,128), "
            f"got {spec!r}")
    if isinstance(lens, int):
        if lens < 1:
            raise SystemExit(f"--buckets count must be >= 1, got {spec!r}")
        from repro.distribution.sharding import prefill_bucket_table
        return prefill_bucket_table(cache_len, lens)
    if not lens or any(v < 1 for v in lens):
        raise SystemExit(
            f"--buckets lengths must all be >= 1, got {spec!r}")
    if any(v > cache_len for v in lens):
        raise SystemExit(
            f"--buckets lengths must not exceed --cache-len "
            f"({cache_len}): a bucket beyond the cache can never "
            f"admit — got {spec!r}")
    return lens


def validate_kv_flags(*, kv_pages: Optional[int], kv_watermark: float,
                      kv_share: bool, kv_share_min_pages: int,
                      int8_kv: bool, draft_sparsity: Optional[float],
                      draft_k: int = 4, draft_int8: bool = False,
                      kv_dedup_every: int = 0, cache_len: int = 256):
    """Single source of truth for cross-flag KV / speculative-decode
    validation. Every serving path (--hosts frontend, --scheduler,
    solo engine) builds its engines from the same flag set, so they
    must reject the same combinations identically — the checks used to
    be scattered per-branch and drifted (a bad combo that the solo
    path rejected sailed through the frontend until an engine deep in
    a host raised). Raises SystemExit with a usage message."""
    if not 0.0 < kv_watermark <= 1.0:
        raise SystemExit(
            f"--kv-watermark must lie in (0, 1], got {kv_watermark}")
    if kv_pages is not None and kv_pages < 1:
        raise SystemExit(f"--kv-pages must be >= 1, got {kv_pages}")
    if kv_share:
        if kv_pages is None:
            raise SystemExit("--kv-share requires --kv-pages (prefix "
                             "sharing lives on the paged pool)")
        if int8_kv:
            raise SystemExit("--kv-share is incompatible with "
                             "--int8-kv: suffix prefill would attend "
                             "dequantized prefix KV and break "
                             "bit-identity (DESIGN.md §16)")
    if kv_share_min_pages < 1:
        raise SystemExit(f"--kv-share-min-pages must be >= 1, got "
                         f"{kv_share_min_pages}")
    if draft_sparsity is not None:
        if kv_pages is None:
            raise SystemExit("--draft-sparsity requires --kv-pages: "
                             "speculative drafts live on scratch pages "
                             "of the paged pool (DESIGN.md §17)")
        if int8_kv:
            raise SystemExit("--draft-sparsity is incompatible with "
                             "--int8-kv: verification attends fresh "
                             "fp KV while sequential decode attends "
                             "dequantized KV, breaking bit-identity "
                             "(DESIGN.md §17)")
        if not 0.0 < draft_sparsity < 1.0:
            raise SystemExit(f"--draft-sparsity must lie in (0, 1), "
                             f"got {draft_sparsity}")
        if draft_k < 1:
            raise SystemExit(f"--draft-k must be >= 1, got {draft_k}")
        if draft_k + 1 > cache_len:
            raise SystemExit(
                f"--draft-k {draft_k} needs a draft+verify window of "
                f"{draft_k + 1} tokens inside --cache-len "
                f"({cache_len}); shrink --draft-k")
    elif draft_int8:
        raise SystemExit("--draft-int8 modifies the drafter pack; add "
                         "--draft-sparsity S")
    if kv_dedup_every < 0:
        raise SystemExit(f"--kv-dedup-every must be >= 0, got "
                         f"{kv_dedup_every}")
    if kv_dedup_every and not (kv_pages and kv_share):
        raise SystemExit("--kv-dedup-every requires --kv-pages and "
                         "--kv-share: the dedup sweep re-links "
                         "identical resident pages through the prefix "
                         "radix (DESIGN.md §16)")


def start_metrics_reporter(summary_fn: Callable[[], dict],
                           interval: float) -> threading.Event:
    """Print ``summary_fn()`` every ``interval`` seconds from a daemon
    thread until the returned event is set (--metrics-interval)."""
    stop = threading.Event()
    if interval <= 0:
        return stop

    def loop():
        while not stop.wait(interval):
            s = summary_fn()
            print(f"metrics: {s}")

    threading.Thread(target=loop, daemon=True).start()
    return stop


def check_ranks(ranks: Optional[int], mesh, profile: str = "tp"):
    """--ranks vs the mesh's DP size: a clear usage error instead of
    the cryptic submesh-count ValueError from the scheduler."""
    if ranks is None or mesh is None:
        return
    from repro.distribution import sharding as shd
    dp = 1
    for a in shd.dp_axes(mesh, profile):
        dp *= mesh.shape[a]
    if ranks > dp:
        raise SystemExit(
            f"--ranks {ranks} exceeds the mesh's DP size {dp} "
            f"(mesh {dict(mesh.shape)}): each scheduler rank needs its "
            f"own DP slice of the mesh; drop --ranks or grow the DP "
            f"axis to >= {ranks}")
    if ranks != dp:
        raise SystemExit(
            f"--ranks {ranks} conflicts with the mesh's DP size {dp}: "
            f"under a mesh the DP axis decides the rank count; drop "
            f"--ranks")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a CheckpointManager dir")
    ap.add_argument("--sasp", type=float, default=0.0)
    ap.add_argument("--path", choices=PATHS, default="masked",
                    help="SASP execution path (ignored when --sasp 0)")
    ap.add_argument("--scope", choices=("ffn", "all"), default="ffn")
    ap.add_argument("--int8-weights", action="store_true")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="serve under a (data, model) mesh: caches and "
                         "decode state carry NamedShardings, packed "
                         "visit lists are TP-sharded per output-block "
                         "shard (e.g. --mesh 1,2)")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve through the sharded request scheduler "
                         "(DESIGN.md §11): admission-controlled queue + "
                         "one engine shard per DP rank + continuous "
                         "batching")
    ap.add_argument("--slots-per-rank", type=int, default=None,
                    help="KV-cache slots owned by each DP-rank engine "
                         "shard (default: --slots)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission control: reject submissions once "
                         "this many requests are waiting beyond free "
                         "slot capacity (default: unbounded)")
    ap.add_argument("--admission", choices=("fcfs", "sjf", "edf"),
                    default="fcfs",
                    help="queue policy: fcfs (arrival order), sjf "
                         "(shortest remaining work first), or edf "
                         "(earliest effective deadline first — the QoS "
                         "policy, DESIGN.md §12)")
    ap.add_argument("--aging", type=float, default=0.0,
                    help="anti-starvation credit per second waited "
                         "(seconds of deadline for edf, tokens for "
                         "sjf); 0 = pure EDF/SJF")
    ap.add_argument("--preempt", action="store_true",
                    help="interactive-class requests may evict the "
                         "worst-deadline batch-class decode at step "
                         "granularity (resume is bit-identical)")
    ap.add_argument("--preempt-mode", choices=("kv", "reprefill"),
                    default="kv",
                    help="preempted-slot resume: 'kv' snapshots the "
                         "slot's cache rows, 'reprefill' re-prefills "
                         "prompt + generated tokens")
    ap.add_argument("--interactive-every", type=int, default=0,
                    help="mark every Nth synthetic request "
                         "interactive-class (0 = all batch)")
    ap.add_argument("--shed", choices=("count", "deadline"),
                    default="count",
                    help="overload shedding once --max-queue overflows: "
                         "'count' rejects the newcomer, 'deadline' "
                         "evicts the waiting request least likely to "
                         "meet its deadline (batch before interactive)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="paged KV cache (DESIGN.md §13): device page "
                         "pool size per rank engine; slots share pages "
                         "through block tables and become "
                         "oversubscribable (default: contiguous "
                         "per-slot rings)")
    ap.add_argument("--kv-page-len", type=int, default=None,
                    help="page length in tokens — must be a multiple "
                         "of the SASP tile and divide --cache-len "
                         "(default: tile-aligned automatic)")
    ap.add_argument("--kv-watermark", type=float, default=1.0,
                    help="high-watermark fraction of --kv-pages that "
                         "may stay resident; allocations beyond it "
                         "spill cold (preempted) pages to host RAM")
    ap.add_argument("--kv-host-pool", type=int, default=0,
                    help="host-RAM spill pool size in pages (0 = no "
                         "spill; cold pages drop to re-prefill resume "
                         "under pressure instead)")
    ap.add_argument("--kv-share", action="store_true",
                    help="prefix sharing over the paged pool "
                         "(DESIGN.md §16): admission maps a prompt's "
                         "full pages onto identical already-resident "
                         "pages (refcounted, copy-on-write) and "
                         "prefills only the suffix; requires "
                         "--kv-pages, incompatible with --int8-kv")
    ap.add_argument("--kv-share-min-pages", type=int, default=1,
                    help="minimum whole pages a prompt must match "
                         "before sharing is taken (shorter matches "
                         "prefill from scratch)")
    ap.add_argument("--draft-sparsity", type=float, default=None,
                    help="self-speculative decoding (DESIGN.md §17): "
                         "repack the SAME weights at this higher tile "
                         "sparsity as a cheap drafter; greedy streams "
                         "stay bit-identical (every emitted token is a "
                         "target argmax). Requires --kv-pages, "
                         "incompatible with --int8-kv")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculation depth: drafter tokens proposed "
                         "per verify step (draft-k/verify-1)")
    ap.add_argument("--draft-int8", action="store_true",
                    help="quantize the drafter pack's weights to INT8 "
                         "on top of --draft-sparsity (drafter fidelity "
                         "only moves acceptance rate, never outputs)")
    ap.add_argument("--draft-interactive", action="store_true",
                    help="let interactive-SLO requests speculate too "
                         "(default: batch-class only — speculation "
                         "trades per-step latency for throughput)")
    ap.add_argument("--kv-dedup-every", type=int, default=0,
                    help="cross-request dedup sweep cadence in decode "
                         "steps (0 = off): re-link identical "
                         "already-resident pages that missed "
                         "admission-time sharing; requires --kv-share")
    ap.add_argument("--buckets", default=None,
                    help="prefill shape bucketing: an int count builds "
                         "a geometric table up to --cache-len; "
                         "comma-separated lengths give it explicitly. "
                         "Bounds jitted-admission compiles at "
                         "O(buckets) under diverse prompt lengths")
    ap.add_argument("--stream", action="store_true",
                    help="serve through the per-token streaming "
                         "iterator and print tokens as they retire")
    ap.add_argument("--drain", action="store_true",
                    help="drain-batch baseline: admit only when every "
                         "slot is free (A/B control for continuous "
                         "batching)")
    ap.add_argument("--ranks", type=int, default=None,
                    help="engine shards without a mesh (testing); with "
                         "--mesh the DP axis must agree (clear error "
                         "otherwise)")
    ap.add_argument("--hosts", type=int, default=None,
                    help="serve through the fault-tolerant cluster "
                         "frontend (DESIGN.md §14) over N in-process "
                         "hosts, each its own sharded scheduler: "
                         "heartbeat health checks, bounded retries with "
                         "backoff, watchdog, graceful drain")
    ap.add_argument("--retries", type=int, default=2,
                    help="re-submissions after a host failure before a "
                         "request fails for real (frontend only)")
    ap.add_argument("--backoff", type=float, default=0.05,
                    help="retry backoff base seconds: attempt k waits "
                         "base*2^(k-1), capped, with seeded jitter")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request wall-clock watchdog seconds "
                         "(default: none) — an overdue request is "
                         "cancelled out of its host and failed")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="graceful-shutdown bound: drain stops "
                         "admission and serves in-flight work at most "
                         "this many seconds before cutting stragglers")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault injection into the "
                         "frontend's hosts, e.g. "
                         "'kill:0@12,raise:1@3,drop-hb:0@5x3,"
                         "slow:1@0.02,seed:7' (serve/chaos.py grammar; "
                         "requires --hosts)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="arm the span tracer and write a Chrome "
                         "trace-event JSON of the whole run — load it "
                         "at ui.perfetto.dev or chrome://tracing "
                         "(DESIGN.md §18)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write Prometheus text exposition of every "
                         "registered counter/gauge/histogram at exit")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="print an aggregated metrics summary every N "
                         "seconds while serving (0 = off)")
    args = ap.parse_args()

    # BEFORE any backend-initializing jax call: may set XLA_FLAGS
    mesh = parse_mesh(args.mesh)
    check_ranks(args.ranks, mesh)
    if args.hosts is not None and args.hosts < 1:
        raise SystemExit(f"--hosts must be >= 1, got {args.hosts}")
    if args.hosts and mesh is not None:
        raise SystemExit(
            "--hosts serves in-process hosts without a mesh; drop "
            "--mesh (per-host meshes are a multi-process deployment "
            "concern — see tests/dist_worker.py frontend_host)")
    if args.chaos and not args.hosts:
        raise SystemExit("--chaos drives the cluster frontend's fault "
                         "hooks; add --hosts N")
    if args.chaos:
        from repro.serve.chaos import parse_chaos_spec
        try:
            chaos_cfg = parse_chaos_spec(args.chaos)
        except ValueError as e:
            raise SystemExit(f"--chaos: {e}")
    else:
        chaos_cfg = None
    buckets = parse_buckets(args.buckets, args.cache_len)
    # one validator for all three serving paths (frontend / scheduler
    # / solo) — they must reject the same flag combos identically
    validate_kv_flags(
        kv_pages=args.kv_pages, kv_watermark=args.kv_watermark,
        kv_share=args.kv_share,
        kv_share_min_pages=args.kv_share_min_pages,
        int8_kv=args.int8_kv, draft_sparsity=args.draft_sparsity,
        draft_k=args.draft_k, draft_int8=args.draft_int8,
        kv_dedup_every=args.kv_dedup_every, cache_len=args.cache_len)

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, layers=4, d_model=128, vocab=512)
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_quant=True)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        like = jax.eval_shape(lambda: {"params": params})
        state, _ = mgr.restore(like)
        params = state["params"]
        print(f"restored step {mgr.latest_step()} from {args.ckpt_dir}")

    params, cfg = build_serving_params(
        params, cfg, path=args.path, sparsity=args.sasp,
        int8_weights=args.int8_weights, scope=args.scope, mesh=mesh)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} over {len(mesh.devices.flat)} "
              "devices")

    rng = np.random.default_rng(0)
    every = args.interactive_every
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(rng.integers(8, 48),))
                    .astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature,
                    eos_id=args.eos_id,
                    slo=("interactive" if every and i % every == 0
                         else "batch"))
            for i in range(args.requests)]

    def drive(run_fn, stream_fn) -> Sequence[Request]:
        """--stream: print tokens as they retire; else run to done."""
        if not args.stream:
            return run_fn(reqs)
        n = 0
        for rid, tok in stream_fn(reqs):
            if n < 12:
                print(f"  stream: req {rid} += {tok}")
            n += 1
        print(f"  … streamed {n} tokens incrementally")
        return [r for r in reqs if r.done]

    if args.hosts:
        from repro.serve.chaos import ChaosMonkey
        from repro.serve.frontend import ClusterFrontend, \
            FrontendConfig, make_local_hosts
        from repro.serve.scheduler import SchedulerConfig
        hosts = make_local_hosts(
            params, cfg, hosts=args.hosts, ranks=args.ranks or 1,
            chaos=ChaosMonkey(chaos_cfg) if chaos_cfg else None,
            trace=bool(args.trace_out),
            sched=SchedulerConfig(
                slots_per_rank=args.slots_per_rank or args.slots,
                cache_len=args.cache_len, max_queue=args.max_queue,
                policy=args.admission, drain=args.drain,
                aging=args.aging, preempt=args.preempt,
                preempt_mode=args.preempt_mode, buckets=buckets,
                shed=args.shed, kv_pages=args.kv_pages,
                kv_page_len=args.kv_page_len,
                kv_watermark=args.kv_watermark,
                kv_host_pages=args.kv_host_pool,
                kv_share=args.kv_share,
                kv_share_min_pages=args.kv_share_min_pages,
                draft_sparsity=args.draft_sparsity,
                draft_k=args.draft_k, draft_int8=args.draft_int8,
                draft_interactive=args.draft_interactive,
                kv_dedup_every=args.kv_dedup_every))
        fe = ClusterFrontend(hosts, FrontendConfig(
            retries=args.retries, backoff_base=args.backoff,
            request_timeout=args.timeout,
            drain_timeout=args.drain_timeout))
        if args.stream:
            n_stream = [0]

            def _tok(req, tok):
                if n_stream[0] < 12:
                    print(f"  stream: req {req.rid} += {tok}")
                n_stream[0] += 1
            fe.on_token = _tok
        trace_writer, prom_fn = fe.write_trace, fe.prometheus

        def cluster_summary():
            out: dict = {}
            for h in hosts:
                cs = h.telemetry.registry.summary()["counters"]
                for k, v in cs.items():
                    out[k] = out.get(k, 0) + v
            return out

        stop_rep = start_metrics_reporter(cluster_summary,
                                          args.metrics_interval)
        t0 = time.time()
        done = fe.run(reqs)
        drained, clean = fe.drain()     # bounded graceful shutdown
        done += drained
        dt = time.time() - t0
        stop_rep.set()
        fe.close()
        if args.stream:
            print(f"  … streamed {n_stream[0]} tokens incrementally")
        st = fe.stats()
        print(f"frontend: {st['hosts']} host(s) "
              f"({st['healthy']} healthy, {st['suspect']} suspect, "
              f"{st['dead']} dead), {st['done']} done, "
              f"{st['failed']} failed, {st['rejected']} rejected, "
              f"{st['retries']} retries, "
              f"{st['deduped_tokens']} deduped tokens, "
              f"drain {'clean' if clean else 'cut stragglers'}")
        for h_st in st["per_host"]:
            print(f"  host {h_st['host']}: steps={h_st['steps']} "
                  f"live_ranks={h_st.get('live_ranks', 0)}/"
                  f"{h_st.get('ranks', 0)} "
                  f"accepted={h_st.get('accepted', 0)} "
                  f"requeued={h_st.get('requeued', 0)}")
    elif args.scheduler:
        from repro.serve.scheduler import SchedulerConfig, \
            ShardedScheduler
        sched = ShardedScheduler(
            params, cfg, mesh=mesh, ranks=args.ranks,
            telemetry=Telemetry(trace=bool(args.trace_out)),
            sched=SchedulerConfig(
                slots_per_rank=args.slots_per_rank or args.slots,
                cache_len=args.cache_len, max_queue=args.max_queue,
                policy=args.admission, drain=args.drain,
                aging=args.aging, preempt=args.preempt,
                preempt_mode=args.preempt_mode, buckets=buckets,
                shed=args.shed, kv_pages=args.kv_pages,
                kv_page_len=args.kv_page_len,
                kv_watermark=args.kv_watermark,
                kv_host_pages=args.kv_host_pool,
                kv_share=args.kv_share,
                kv_share_min_pages=args.kv_share_min_pages,
                draft_sparsity=args.draft_sparsity,
                draft_k=args.draft_k, draft_int8=args.draft_int8,
                draft_interactive=args.draft_interactive,
                kv_dedup_every=args.kv_dedup_every))
        trace_writer = sched.telemetry.write_trace
        prom_fn = sched.telemetry.prometheus
        stop_rep = start_metrics_reporter(
            lambda: sched.telemetry.registry.summary()["counters"],
            args.metrics_interval)
        t0 = time.time()
        done = drive(sched.run, sched.stream)
        dt = time.time() - t0
        stop_rep.set()
        st = sched.stats()
        print(f"scheduler: {st['ranks']} rank(s), "
              f"{st['accepted']}/{st['submitted']} admitted "
              f"({st['rejected']} rejected, {st['failed']} failed, "
              f"{st['preemptions']} preempted), "
              f"policy={args.admission}"
              f"{', drain baseline' if args.drain else ''}")
        for r_st in st["per_rank"]:
            print(f"  rank stats: {r_st}")
        if every:
            for klass in ("interactive", "batch"):
                lats = sorted(r.latency for r in done
                              if r.slo == klass and r.latency)
                if lats:
                    p50, p95 = pcts_ms(lats)
                    print(f"  {klass:12s}: n={len(lats)} "
                          f"p50={p50:.0f}ms p95={p95:.0f}ms")
        for klass, d in st.get("ttft", {}).items():
            print(f"  ttft {klass:12s}: n={d['count']} "
                  f"p50={d['p50_ms']:.1f}ms p95={d['p95_ms']:.1f}ms")
    else:
        eng = Engine(params, cfg, batch_slots=args.slots,
                     cache_len=args.cache_len, mesh=mesh,
                     buckets=buckets, kv_pages=args.kv_pages,
                     kv_page_len=args.kv_page_len,
                     kv_watermark=args.kv_watermark,
                     kv_host_pages=args.kv_host_pool,
                     kv_share=args.kv_share,
                     kv_share_min_pages=args.kv_share_min_pages,
                     draft_sparsity=args.draft_sparsity,
                     draft_k=args.draft_k, draft_int8=args.draft_int8,
                     draft_interactive=args.draft_interactive,
                     kv_dedup_every=args.kv_dedup_every,
                     telemetry=Telemetry(trace=bool(args.trace_out)))
        trace_writer = eng.telemetry.write_trace
        prom_fn = eng.telemetry.prometheus
        stop_rep = start_metrics_reporter(
            lambda: eng.telemetry.registry.summary()["counters"],
            args.metrics_interval)
        t0 = time.time()
        done = drive(eng.run, eng.stream)
        dt = time.time() - t0
        stop_rep.set()
        if args.draft_sparsity is not None:
            st = eng.stats
            drafted = st["spec_draft_tokens"]
            acc = st["spec_accepted_tokens"]
            print(f"speculative: {st['spec_rounds']} rounds, "
                  f"{acc}/{max(drafted, 1)} drafts accepted "
                  f"({acc / max(drafted, 1):.0%}), "
                  f"{st['spec_fallbacks']} fallbacks")
        mem = eng.memory_stats()
        if mem is not None:
            print(f"paged KV: {mem.device_pages} device pages × "
                  f"{eng.pool.page_len} tokens, {mem.spills} spills, "
                  f"{mem.faults} faults, {mem.drops} drops")
            if args.kv_share:
                print(f"prefix sharing: {mem.prefix_hits} hits, "
                      f"{mem.prefix_pages_reused} pages reused, "
                      f"{eng.stats['prefill_tokens_skipped']} prefill "
                      f"tokens skipped, {mem.cow_copies} COW copies")
    if args.trace_out:
        n_ev = trace_writer(args.trace_out)
        print(f"trace: {n_ev} events -> {args.trace_out} "
              "(load at ui.perfetto.dev)")
    if args.metrics_dump:
        with open(args.metrics_dump, "w", encoding="utf-8") as fh:
            fh.write(prom_fn())
        print(f"metrics -> {args.metrics_dump}")
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s, "
          f"{dt/max(toks,1)*1e3:.0f} ms/token)")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.out_tokens[:10]}…")


if __name__ == "__main__":
    main()
