"""Serving launcher CLI: load (or init) a model, optionally deploy SASP
(prune + INT8 + int8-KV), and serve synthetic requests through the
batched engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduce \
      --sasp 0.25 --int8-kv --requests 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import prune_params
from repro.core.sasp import quantize_params
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a CheckpointManager dir")
    ap.add_argument("--sasp", type=float, default=0.0)
    ap.add_argument("--int8-weights", action="store_true")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, layers=4, d_model=128, vocab=512)
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_quant=True)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        like = jax.eval_shape(lambda: {"params": params})
        state, _ = mgr.restore(like)
        params = state["params"]
        print(f"restored step {mgr.latest_step()} from {args.ckpt_dir}")

    if args.sasp:
        sasp = SASPConfig(enabled=True, block_k=32, block_n=32,
                          sparsity=args.sasp,
                          quantize=args.int8_weights)
        params, masks = prune_params(params, sasp)
        print(f"SASP deployed: {args.sasp:.0%} tile sparsity, "
              f"{len(masks)} matrices")
        if args.int8_weights:
            params = quantize_params(params, sasp)
            print("weights quantized to INT8 (per-block scales)")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(rng.integers(8, 48),))
                    .astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]

    eng = Engine(params, cfg, batch_slots=args.slots,
                 cache_len=args.cache_len)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({dt/max(toks,1)*1e3:.0f} ms/token)")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.out_tokens[:10]}…")


if __name__ == "__main__":
    main()
