"""Training launcher CLI.

Local mode (default) trains a reduced config on this host — the smoke
path. ``--mesh single|multi`` selects the production meshes (requires
real devices or forced host devices; the dry-run driver covers the
no-hardware case).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
      --reduce --steps 100 --batch 8 --seq 256 --sasp 0.25
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import cubic_sparsity_schedule
from repro.core.sasp import build_sasp_overlay
from repro.data.pipeline import DataConfig, DataState, Pipeline
from repro.distribution import context as dctx
from repro.distribution import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init, \
    opt_state_shardings
from repro.train.schedule import PreemptionHook, StragglerWatchdog, \
    warmup_cosine
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--reduce", action="store_true",
                    help="family-preserving reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sasp", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, layers=4, d_model=128, vocab=512)
    if args.sasp:
        cfg = dataclasses.replace(
            cfg, sasp=SASPConfig(enabled=True, block_k=32, block_n=32,
                                 sparsity=args.sasp))

    mesh = None
    if args.mesh != "local":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = Pipeline(dcfg, kind="lm")
    opt_cfg = AdamWConfig(lr=args.lr)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    hook = PreemptionHook()
    wd = StragglerWatchdog()
    sched = warmup_cosine(min(30, args.steps // 10 + 1), args.steps)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, opt_cfg)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        like = jax.eval_shape(lambda: {"params": params, "opt": opt})
        state, extra = mgr.restore(like)
        params, opt = state["params"], state["opt"]
        pipe = Pipeline(dcfg, kind="lm",
                        state=DataState.from_dict(extra))
        start = mgr.latest_step()
        print(f"resumed from step {start}")

    overlay = None
    if args.sasp:
        overlay, got = build_sasp_overlay(params, cfg.sasp)
        print(f"SASP masks: {got:.1%} sparsity "
              f"(tile {cfg.sasp.block_k}x{cfg.sasp.block_n})")
    step_fn = make_train_step(cfg, opt_cfg, overlay=overlay,
                              lr_schedule=sched,
                              n_microbatches=args.microbatches)

    ctx = dctx.use_mesh(mesh) if mesh is not None else \
        dctx.use_mesh(None)
    with ctx:
        if mesh is not None:
            psh = shd.param_shardings(
                cfg, jax.eval_shape(lambda: params), mesh)
            osh = opt_state_shardings(
                cfg, jax.eval_shape(lambda: params), mesh, opt_cfg, psh)
            from jax.sharding import NamedSharding, PartitionSpec as P
            bsh = {"tokens": NamedSharding(
                mesh, P(shd.dp_axes(mesh), None))}
            jstep = jax.jit(step_fn, in_shardings=(psh, osh, bsh),
                            out_shardings=(psh, osh, None),
                            donate_argnums=(0, 1))
            params = jax.device_put(params, psh)
            opt = jax.device_put(opt, osh)
        else:
            jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            t0 = time.time()
            params, opt, m = jstep(params, opt, batch)
            slow = wd.observe(time.time() - t0)
            if (i + 1) % 10 == 0:
                print(f"step {i+1:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f}"
                      f"{'  [SLOW]' if slow else ''}", flush=True)
            if (i + 1) % wd.checkpoint_every(args.ckpt_every) == 0 \
                    or hook.requested:
                mgr.wait()
                mgr.save_async(i + 1, {"params": params, "opt": opt},
                               extra=pipe.state.to_dict())
                if hook.requested:
                    print("preemption requested — checkpointed, exiting")
                    mgr.wait()
                    return
    mgr.wait()
    mgr.save(args.steps, {"params": params, "opt": opt},
             extra=pipe.state.to_dict())
    print("done")


if __name__ == "__main__":
    main()
