"""Abstract SASP-BSR params for the dry-run hillclimb variant.

Replaces each FFN weight's dense entry in the *abstract* params pytree
with a BlockSparseWeight of ShapeDtypeStructs whose k_max equals
round((1 - sparsity) · KB): the compiled HLO then carries the tile-skip
FLOP/byte savings without any real weights existing. Mirrors what a
deployment would produce offline via core.sasp.bsr_overlay_from_masks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse import BlockSparseWeight


def _abstract_bsr(shape: Tuple[int, ...], bk: int, bn: int,
                  sparsity: float, dtype) -> BlockSparseWeight:
    *lead, K, N = shape
    bk, bn = min(bk, K), min(bn, N)
    KB, NB = K // bk, N // bn
    k_max = max(1, round((1.0 - sparsity) * KB))
    sds = jax.ShapeDtypeStruct
    return BlockSparseWeight(
        vals=sds((*lead, k_max, NB, bk, bn), dtype),
        idx=sds((*lead, k_max, NB), jnp.int32),
        shape=(K, N), block=(bk, bn), scale=None,
    )


def _pick_bn(N: int, model_size: int, prefer: int = 128) -> int:
    """Largest MXU-friendly block_n (multiple of 64, ≤ 2×prefer) whose
    block count divides the TP axis — otherwise the BSR value tensor
    can't shard over 'model' and replicates (found the hard way on
    qwen2.5's d_ff=27648: NB=216 ∤ 16 → 27 GB/device; §Perf A iter 2)."""
    for bn in (prefer, 256, 192, 64, 512, 320):
        if N % bn == 0 and (N // bn) % model_size == 0:
            return bn
    for bn in (prefer, 64):
        if N % bn == 0:
            return bn
    return N


def abstract_bsr_params(params_shape: Any, cfg: ModelConfig,
                        sparsity: float, quantize: bool = False,
                        model_axis: int = 16):
    """Returns (new abstract params, cfg with sasp.path='bsr'). With
    ``quantize``: int8 block values + per-block fp32 scales (weight HBM
    bytes ÷4 — the paper's FP32_INT8 setting)."""
    sasp = dataclasses.replace(cfg.sasp, enabled=True, sparsity=sparsity,
                               path="bsr", quantize=quantize)
    cfg2 = dataclasses.replace(cfg, sasp=sasp)
    bk = sasp.block_k

    def rewrite(node):
        if isinstance(node, tuple):
            return tuple(rewrite(v) for v in node)
        if isinstance(node, dict):
            out = {}
            if ("w1" in node and "w2" in node and "router" not in node
                    and isinstance(node.get("w1"), dict)
                    and "w" in node.get("w1", {})
                    and getattr(node["w1"]["w"], "ndim", 0) == 3):
                # dense FFN stack (L, K, N): swap to BSR containers
                out = {k: v for k, v in node.items()}
                bsr = {}
                for mat in ("w1", "w2", "w3"):
                    if mat in node:
                        w = node[mat]["w"]
                        L, K, N = w.shape
                        bn = _pick_bn(N, model_axis, sasp.block_n)
                        b = _abstract_bsr((K, N), bk, bn, sparsity, w.dtype)
                        sds = jax.ShapeDtypeStruct
                        vdt = jnp.int8 if quantize else w.dtype
                        scale = (sds((L,) + b.idx.shape, jnp.float32)
                                 if quantize else None)
                        bsr[mat] = BlockSparseWeight(
                            vals=sds((L,) + b.vals.shape, vdt),
                            idx=sds((L,) + b.idx.shape, jnp.int32),
                            shape=b.shape, block=b.block, scale=scale)
                        out[mat] = {"w": sds((L, 1, 1), w.dtype)}  # stub
                out["sasp_bsr"] = bsr
                return out
            return {k: rewrite(v) for k, v in node.items()}
        return node

    return rewrite(params_shape), cfg2
