"""repro — SASP (Systolic-Array Structured Pruning) co-design framework in JAX."""
__version__ = "1.0.0"
