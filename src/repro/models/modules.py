"""Functional parameter/module helpers.

Params are nested dicts of jnp arrays; every layer is an (init, apply) pair
of pure functions. Layer stacks are built by vmapping init over a leading
layer axis and running apply under ``lax.scan`` (see lm.py) — this keeps
compile time flat in depth, which matters both on the 1-core container and
for the 70+ dry-run lowers.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def as_dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, d_in: int, d_out: int, *, dtype, scale: float = None,
               bias: bool = False) -> Params:
    scale = 0.02 if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray, *, dtype=None) -> jnp.ndarray:
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embedding_init(key, vocab: int, d: int, *, dtype) -> Params:
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"emb": w.astype(dtype)}


def embedding_apply(p: Params, tokens: jnp.ndarray, *, dtype) -> jnp.ndarray:
    return jnp.take(p["emb"].astype(dtype), tokens, axis=0)


def act_fn(name: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, *, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, *, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def qknorm_apply(scale: jnp.ndarray, x: jnp.ndarray, *, eps: float):
    """Per-head RMS norm over head_dim (qwen3/chameleon style)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def fold_rng(key, *idx: int):
    for i in idx:
        key = jax.random.fold_in(key, i)
    return key
