"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Train/prefill run the chunked SSD algorithm: ``lax.scan`` over chunks of
``chunk_size`` carrying the (B, H, P, N) inter-chunk state; within a chunk
the quadratic "attention-like" form is used (Q×Q decay-masked C·Bᵀ), which
maps onto the MXU. Decode is the O(1) recurrence on the same state.

Layer structure (Mamba-2 block): RMSNorm → in_proj → [z | xBC | dt] →
causal depthwise conv(k) on xBC → SiLU → split x, B, C → SSD →
gated RMSNorm(y ⊙ SiLU(z)) → out_proj.

Serving note (DESIGN.md §7): unlike attention, the recurrence has no
per-token position masking, so a left-padded prefix WOULD corrupt the
state — the engine's batched multi-slot prefill therefore only engages
on attention-only stacks; hybrid stacks prefill per-request.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.modules import as_dtype, dense_apply, dense_init, \
    rmsnorm_apply


class SSMCache(NamedTuple):
    state: jnp.ndarray      # (B, H, P, N)
    conv: jnp.ndarray       # (B, K-1, conv_dim) trailing inputs


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.num_heads(d)
    G, N, P, K = s.ngroups, s.state_dim, s.head_dim, s.conv_kernel
    conv_dim = di + 2 * G * N
    return d, di, H, G, N, P, K, conv_dim


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    _, di, H, G, N, P, K, conv_dim = _dims(cfg)
    return SSMCache(
        state=jnp.zeros((batch, H, P, N), dtype=jnp.float32),
        conv=jnp.zeros((batch, K - 1, conv_dim), dtype=dtype),
    )


def ssm_init(key, cfg: ModelConfig) -> Dict:
    dt = as_dtype(cfg.param_dtype)
    d, di, H, G, N, P, K, conv_dim = _dims(cfg)
    s = cfg.ssm
    ks = jax.random.split(key, 4)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    # dt bias ~ inverse softplus of dt in [dt_min, dt_max]
    u = jax.random.uniform(ks[2], (H,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min)) +
                  math.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    kz, kx, kd = jax.random.split(ks[0], 3)
    return {
        # separate projections (≡ one concatenated in_proj) so each output
        # dim TP-shards cleanly over 'model' (DESIGN.md §6)
        "in_z": dense_init(kz, d, di, dtype=dt),
        "in_xbc": dense_init(kx, d, conv_dim, dtype=dt),
        "in_dt": dense_init(kd, d, H, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (K, conv_dim), jnp.float32)
                   * (1.0 / math.sqrt(K * 1.0))).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dtype=dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": dt_bias,
        "norm": jnp.ones((di,), dtype=dt),
        "out_proj": dense_init(ks[3], di, d, dtype=dt, scale=out_scale),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """xbc: (B, S, C); w: (K, C) depthwise causal."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, shape=xbc.shape).astype(jnp.float32)
    for i in range(K):                       # tiny K (=4): unrolled taps
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(xbc.dtype)


def _segsum_decay(a_cum: jnp.ndarray) -> jnp.ndarray:
    """a_cum: (..., Q) inclusive cumsum of log-decay -> (..., Q, Q) matrix
    exp(cum[q] - cum[s]) for s <= q, else 0."""
    Q = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, Bm, Cm, D, h0, chunk: int):
    """SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) negative; Bm/Cm: (B, S, G, N);
    D: (H,); h0: (B, H, P, N) initial state. Returns (y (B,S,H,P), h_final).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    a = dt.astype(jnp.float32) * A                     # (B, S, H) log decay

    def reshape_c(t, feat_shape):
        return jnp.moveaxis(t.reshape(Bsz, nc, Q, *feat_shape), 1, 0)

    xc = reshape_c(xdt, (H, P))                        # (nc, B, Q, H, P)
    ac = reshape_c(a, (H,))                            # (nc, B, Q, H)
    bc = reshape_c(Bm.astype(jnp.float32), (G, N))
    cc = reshape_c(Cm.astype(jnp.float32), (G, N))

    def body(h, inp):
        xq, aq, bq, cq = inp                           # per-chunk slices
        cum = jnp.cumsum(aq, axis=1)                   # (B, Q, H) inclusive
        # ---- intra-chunk (quadratic within Q) ----
        cb = jnp.einsum("bqgn,bsgn->bgqs", cq, bq)     # (B, G, Q, Q)
        Lmat = _segsum_decay(jnp.moveaxis(cum, 1, 2))  # (B, H, Q, Q)
        cb_h = jnp.repeat(cb, rep, axis=1)             # (B, H, Q, Q)
        y_intra = jnp.einsum("bhqs,bshp->bqhp", cb_h * Lmat, xq)
        # ---- inter-chunk: contribution of carried state ----
        c_h = jnp.repeat(cq, rep, axis=2)              # (B, Q, H, N)
        decay_q = jnp.exp(cum)                         # (B, Q, H)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", c_h * decay_q[..., None], h)
        # ---- state update ----
        decay_tail = jnp.exp(cum[:, -1:, :] - cum)     # (B, Q, H)
        b_h = jnp.repeat(bq, rep, axis=2)              # (B, Q, H, N)
        s_new = jnp.einsum("bqhp,bqhn->bhpn", xq * decay_tail[..., None],
                           b_h)
        h_new = h * jnp.exp(cum[:, -1])[..., None, None].transpose(
            0, 1, 2, 3) + s_new
        return h_new, y_intra + y_inter

    # reshape exp(cum[-1]) to (B, H, 1, 1): do it inside body via transpose
    h_final, ys = jax.lax.scan(body, h0.astype(jnp.float32),
                               (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, h_final


def ssm_apply_full(p: Dict, cfg: ModelConfig, xin: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, SSMCache]:
    """Train/prefill. xin: (B, S, d) -> (y, final cache)."""
    d, di, H, G, N, P, K, conv_dim = _dims(cfg)
    Bsz, S, _ = xin.shape
    s = cfg.ssm

    z = dense_apply(p["in_z"], xin)
    xbc = dense_apply(p["in_xbc"], xin)
    dt = dense_apply(p["in_dt"], xin)
    xbc_conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x, Bm, Cm = jnp.split(xbc_conv, [di, di + G * N], axis=-1)

    from repro.distribution import context as dctx
    dp = dctx.dp_axes()
    x = x.reshape(Bsz, S, H, P)
    Bm = Bm.reshape(Bsz, S, G, N)
    Cm = Cm.reshape(Bsz, S, G, N)
    if dp:
        # pin SSD activations: batch over DP, heads over TP (stops XLA
        # from inventing shardings inside the chunk scan)
        x = dctx.maybe_shard(x, dp, None, "model", None)
        Bm = dctx.maybe_shard(Bm, dp, None, None, None)
        Cm = dctx.maybe_shard(Cm, dp, None, None, None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    if dp:
        h0 = dctx.maybe_shard(h0, dp, "model", None, None)
    y, h_final = ssd_chunked(x, dt, A, Bm, Cm, p["D"], h0, s.chunk_size)

    y = y.reshape(Bsz, S, di).astype(xin.dtype)
    y = rmsnorm_apply({"scale": p["norm"]}, y * jax.nn.silu(z),
                      eps=cfg.norm_eps)
    out = dense_apply(p["out_proj"], y)

    conv_tail = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))[:, S:S + K - 1]
    if S >= K - 1:
        conv_tail = xbc[:, S - (K - 1):]
    cache = SSMCache(state=h_final, conv=conv_tail)
    return out, cache


def ssm_apply_decode(p: Dict, cfg: ModelConfig, xin: jnp.ndarray,
                     cache: SSMCache) -> Tuple[jnp.ndarray, SSMCache]:
    """Single-token recurrence. xin: (B, 1, d)."""
    d, di, H, G, N, P, K, conv_dim = _dims(cfg)
    Bsz = xin.shape[0]

    x0 = xin[:, 0]                                     # (B, d)
    z = dense_apply(p["in_z"], x0)
    xbc = dense_apply(p["in_xbc"], x0)
    dt = dense_apply(p["in_dt"], x0)

    # conv over [cached K-1 inputs, current]
    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xbc_conv = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    x, Bm, Cm = jnp.split(xbc_conv.astype(xin.dtype), [di, di + G * N],
                          axis=-1)
    x = x.reshape(Bsz, H, P)
    Bm = Bm.reshape(Bsz, G, N)
    Cm = Cm.reshape(Bsz, G, N)
    rep = H // G

    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A)                                       # (B, H)
    b_h = jnp.repeat(Bm, rep, axis=1)                              # (B, H, N)
    c_h = jnp.repeat(Cm, rep, axis=1)
    xdt = x.astype(jnp.float32) * dt1[..., None]                   # (B, H, P)

    state = cache.state * decay[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xdt, b_h)
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h) + \
        x.astype(jnp.float32) * p["D"][None, :, None]

    y = y.reshape(Bsz, 1, di).astype(xin.dtype)
    y = rmsnorm_apply({"scale": p["norm"]}, y * jax.nn.silu(z[:, None]),
                      eps=cfg.norm_eps)
    out = dense_apply(p["out_proj"], y)
    return out, SSMCache(state=state, conv=window[:, 1:].astype(
        cache.conv.dtype))
