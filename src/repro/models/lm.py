"""Decoder-only LM assembled from the substrate modules.

Layer stacks are compiled into a **segment plan**: the per-layer kind
sequence (mixer ∈ {attn, mamba} × attn-locality × ffn ∈ {dense, moe}) is
compressed into segments ``(pattern, repeat)`` where ``pattern`` is a short
tuple of layer specs and ``repeat`` is how many times it tiles. Each segment
runs as one ``lax.scan`` over stacked params with the pattern unrolled in
the body — e.g. jamba-1.5 (72 layers) is one scan over 9 repeats of an
8-layer pattern [7×mamba + 1×attn, alternating dense/MoE FFN], and gemma3
(34 layers) is a scan over 5 repeats of [5×local + 1×global] plus a
4-layer local remainder segment. This keeps compile time flat in depth
(1-core container; 70+ dry-run lowers) while supporting heterogeneous
stacks exactly.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN_LOCAL,
    FFN_MOE,
    MIXER_ATTN,
    ModelConfig,
)
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.modules import (
    as_dtype,
    embedding_apply,
    embedding_init,
    fold_rng,
    rmsnorm_apply,
    rmsnorm_init,
    softcap,
)
from repro.models.ssm import SSMCache

LayerSpec = Tuple[int, int, int]            # (mixer, attn_kind, ffn_kind)


def _moe_dispatch(p, cfg, x):
    """EP (shard_map all_to_all) when an active mesh supports it, else
    the single-shard path."""
    from repro.distribution import context as dctx
    from repro.distribution.moe_ep import can_use_ep, moe_ffn_dp, \
        moe_ffn_ep
    mesh = dctx.active_mesh()
    if mesh is not None and dctx.sharding_profile() == "dp_only":
        return moe_ffn_dp(p, cfg, x, mesh)
    if can_use_ep(cfg, x.shape, mesh):
        return moe_ffn_ep(p, cfg, x, mesh)
    return moe_mod.moe_ffn_local(p, cfg, x)
Segment = Tuple[Tuple[LayerSpec, ...], int]  # (pattern, repeat)


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def segment_plan(cfg: ModelConfig) -> List[Segment]:
    mixers = cfg.layer_mixer_kinds()
    attns = cfg.layer_attn_kinds()
    ffns = cfg.layer_ffn_kinds()
    specs = list(zip(mixers, attns, ffns))
    L = cfg.num_layers
    p = 1
    for per in (cfg.hybrid_attn_period, cfg.local_global_period,
                cfg.moe_period):
        if per:
            p = _lcm(p, per)
    p = min(p, L)
    segments: List[Segment] = []
    full = L // p
    if full:
        segments.append((tuple(specs[:p]), full))
    rem = specs[full * p:]
    if rem:
        if all(s == rem[0] for s in rem):
            segments.append(((rem[0],), len(rem)))
        else:
            segments.append((tuple(rem), 1))
    return segments


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _slot_init(key, cfg: ModelConfig, spec: LayerSpec) -> Dict:
    mixer, _, ffn_kind = spec
    ks = jax.random.split(key, 2)
    p = {
        "norm1": rmsnorm_init(cfg.d_model, dtype=as_dtype(cfg.param_dtype)),
        "norm2": rmsnorm_init(cfg.d_model, dtype=as_dtype(cfg.param_dtype)),
    }
    if mixer == MIXER_ATTN:
        p["mixer"] = attn_mod.attn_init(ks[0], cfg)
    else:
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg)
    if ffn_kind == FFN_MOE:
        p["ffn"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["ffn"] = ffn_mod.ffn_init(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    dt = as_dtype(cfg.param_dtype)
    plan = segment_plan(cfg)
    keys = jax.random.split(key, 2 + len(plan))
    params: Dict[str, Any] = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                dtype=dt),
        "final_norm": rmsnorm_init(cfg.d_model, dtype=dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embedding_init(keys[1], cfg.vocab_size,
                                           cfg.d_model, dtype=dt)
    segs = []
    for si, (pattern, repeat) in enumerate(plan):
        seg = {}
        for slot, spec in enumerate(pattern):
            skeys = jax.random.split(
                fold_rng(keys[2 + si], slot), repeat)
            seg[f"slot{slot}"] = jax.vmap(
                lambda k: _slot_init(k, cfg, spec))(skeys)
        segs.append(seg)
    params["segments"] = tuple(segs)
    return params


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _slot_window(cfg: ModelConfig, spec: LayerSpec, seq_len: int) -> int:
    if spec[1] == ATTN_LOCAL and cfg.sliding_window:
        return cfg.sliding_window
    return max(seq_len, 1) + 1          # effectively unbounded causal


def _apply_slot_full(sp: Dict, spec: LayerSpec, cfg: ModelConfig,
                     x: jnp.ndarray, positions: jnp.ndarray,
                     want_cache: bool, cache_len: int,
                     uniform_cache: bool = False):
    mixer, _, ffn_kind = spec
    S = x.shape[1]
    h = rmsnorm_apply(sp["norm1"], x, eps=cfg.norm_eps)
    cache = None
    if mixer == MIXER_ATTN:
        window = _slot_window(cfg, spec, S)
        y, (k, v) = attn_mod.attn_apply_full(sp["mixer"], cfg, h, positions,
                                             window)
        if want_cache:
            # uniform_cache: every attention layer gets the FULL
            # cache_len ring (the paged KV pool needs one token-page
            # geometry across layers, serve/memory.py). The local-window
            # cap is a pure memory optimization — the decode window mask
            # governs which entries attend, so outputs are identical.
            cap = min(window, cache_len) if (
                spec[1] == ATTN_LOCAL and not uniform_cache) \
                else cache_len
            cache = attn_mod.build_cache_from_prefill(
                k, v, cap, quant=cfg.kv_quant,
                positions=positions if positions.ndim == 2 else None)
    else:
        y, ssm_cache = ssm_mod.ssm_apply_full(sp["mixer"], cfg, h)
        if want_cache:
            cache = ssm_cache
    x = x + y
    h2 = rmsnorm_apply(sp["norm2"], x, eps=cfg.norm_eps)
    if ffn_kind == FFN_MOE:
        y2, aux = _moe_dispatch(sp["ffn"], cfg, h2)
    else:
        y2 = ffn_mod.ffn_apply(sp["ffn"], cfg, h2)
        aux = jnp.zeros((), jnp.float32)
    return x + y2, aux, cache


def _apply_slot_decode(sp: Dict, spec: LayerSpec, cfg: ModelConfig,
                       x: jnp.ndarray, pos: jnp.ndarray, cache):
    mixer, _, ffn_kind = spec
    h = rmsnorm_apply(sp["norm1"], x, eps=cfg.norm_eps)
    if mixer == MIXER_ATTN:
        window = _slot_window(cfg, spec, int(1e9) - 2)
        y, cache = attn_mod.attn_apply_decode(sp["mixer"], cfg, h, pos,
                                              cache, window)
    else:
        y, cache = ssm_mod.ssm_apply_decode(sp["mixer"], cfg, h, cache)
    x = x + y
    h2 = rmsnorm_apply(sp["norm2"], x, eps=cfg.norm_eps)
    if ffn_kind == FFN_MOE:
        y2, _ = _moe_dispatch(sp["ffn"], cfg, h2)
    else:
        y2 = ffn_mod.ffn_apply(sp["ffn"], cfg, h2)
    return x + y2, cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _run_segments_full(params, cfg: ModelConfig, x, positions,
                       want_cache: bool, cache_len: int,
                       uniform_cache: bool = False):
    plan = segment_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    all_caches = []
    for seg_params, (pattern, repeat) in zip(params["segments"], plan):

        def body(carry, slot_params):
            from repro.distribution import context as dctx
            xc, aux = carry
            xc = dctx.shard_batch(xc)
            caches = {}
            for slot, spec in enumerate(pattern):
                xc, a, c = _apply_slot_full(
                    slot_params[f"slot{slot}"], spec, cfg, xc, positions,
                    want_cache, cache_len, uniform_cache)
                aux = aux + a
                if want_cache:
                    caches[f"slot{slot}"] = c
            return (xc, aux), caches

        body = _maybe_remat(body, cfg)
        (x, aux_total), seg_caches = jax.lax.scan(
            body, (x, aux_total), seg_params)
        all_caches.append(seg_caches)
    return x, aux_total, tuple(all_caches) if want_cache else None


def _apply_slot_prefill_past(sp: Dict, spec: LayerSpec, cfg: ModelConfig,
                             x: jnp.ndarray, positions: jnp.ndarray,
                             cache: KVCache):
    """One layer of the suffix prefill (prefix sharing, DESIGN.md §16):
    like :func:`_apply_slot_full` but attention reads the resident
    prefix through ``cache`` and the returned cache holds ONLY the
    suffix tokens. Attention-only stacks (the paged pool rejects
    SSM/hybrid)."""
    mixer, _, ffn_kind = spec
    assert mixer == MIXER_ATTN, "suffix prefill is attention-only"
    C = cache.k.shape[1]
    S = x.shape[1]
    h = rmsnorm_apply(sp["norm1"], x, eps=cfg.norm_eps)
    # global layers: window = C, the ring capacity. Sequential decode
    # can never attend an entry more than C - 1 positions back (the
    # ring holds exactly the last C positions and overwrites before
    # attending), so delta >= C pairs only arise here from OLD-LAP
    # entries a post-wrap past gather still carries — entries the
    # sequential path has already overwritten. Capping at C masks them,
    # which keeps this pass step-equivalent to sequential decode (the
    # speculative verify relies on this, DESIGN.md §17). Pre-wrap
    # callers (suffix prefill of a fresh prompt: all deltas <=
    # prompt_len - 1 <= C - 1) see every valid pair unmasked, exactly
    # as the reference full prefill does. Local layers share
    # cfg.sliding_window exactly.
    window = cfg.sliding_window if (
        spec[1] == ATTN_LOCAL and cfg.sliding_window) else C
    y, new_cache = attn_mod.attn_apply_prefill_past(
        sp["mixer"], cfg, h, positions, cache, window)
    x = x + y
    h2 = rmsnorm_apply(sp["norm2"], x, eps=cfg.norm_eps)
    if ffn_kind == FFN_MOE:
        y2, _ = _moe_dispatch(sp["ffn"], cfg, h2)
    else:
        y2 = ffn_mod.ffn_apply(sp["ffn"], cfg, h2)
    return x + y2, new_cache


def _run_segments_prefill_past(params, cfg: ModelConfig, x, positions,
                               past):
    plan = segment_plan(cfg)
    new_caches = []
    for seg_params, seg_past, (pattern, repeat) in zip(
            params["segments"], past, plan):

        def body(xc, inp):
            from repro.distribution import context as dctx
            slot_params, slot_caches = inp
            xc = dctx.shard_batch(xc)
            out_caches = {}
            for slot, spec in enumerate(pattern):
                xc, c = _apply_slot_prefill_past(
                    slot_params[f"slot{slot}"], spec, cfg, xc,
                    positions, slot_caches[f"slot{slot}"])
                out_caches[f"slot{slot}"] = c
            return xc, out_caches

        body = _maybe_remat(body, cfg)
        x, seg_new = jax.lax.scan(body, x, (seg_params, seg_past))
        new_caches.append(seg_new)
    return x, tuple(new_caches)


def prefill_with_past(params, cfg: ModelConfig, tokens, positions, past,
                      all_logits: bool = False):
    """Suffix-only prefill for prefix sharing (DESIGN.md §16).

    tokens: (B, S) the SUFFIX of each prompt, left-padded; positions:
    (B, S) absolute positions (pads < 0); past: ring caches (the
    gather of each request's matched prefix pages — all other ring
    slots hold pos = -1 and mask out). Returns (last-token logits
    (B, 1, V), suffix-only caches) — the caches scatter to the fresh
    suffix pages and must never touch the shared prefix pages.

    ``all_logits=True`` returns logits for EVERY suffix position
    ((B, S, V)) — the speculative verify pass (DESIGN.md §17) needs
    the target's prediction after each drafted token in one call."""
    x = _embed_in(params, cfg, tokens, None)
    positions = jnp.asarray(positions, jnp.int32)
    x, caches = _run_segments_prefill_past(params, cfg, x, positions,
                                           past)
    logits = logits_fn(params, cfg, x if all_logits else x[:, -1:])
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits, caches


def _run_segments_decode(params, cfg: ModelConfig, x, pos, caches):
    plan = segment_plan(cfg)
    new_caches = []
    for seg_params, seg_caches, (pattern, repeat) in zip(
            params["segments"], caches, plan):

        def body(xc, inp):
            slot_params, slot_caches = inp
            out_caches = {}
            for slot, spec in enumerate(pattern):
                xc, c = _apply_slot_decode(
                    slot_params[f"slot{slot}"], spec, cfg, xc, pos,
                    slot_caches[f"slot{slot}"])
                out_caches[f"slot{slot}"] = c
            return xc, out_caches

        x, seg_new = jax.lax.scan(body, x, (seg_params, seg_caches))
        new_caches.append(seg_new)
    return x, tuple(new_caches)


def _embed_in(params, cfg: ModelConfig, tokens, embeds):
    cdt = as_dtype(cfg.compute_dtype)
    if embeds is not None:
        return embeds.astype(cdt)
    return embedding_apply(params["embed"], tokens, dtype=cdt)


def _head_table(params, cfg: ModelConfig):
    return (params["embed"]["emb"] if cfg.tie_embeddings
            else params["lm_head"]["emb"])


def logits_fn(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    emb = _head_table(params, cfg).astype(x.dtype)
    return jnp.einsum("bsd,vd->bsv", x, emb,
                      preferred_element_type=jnp.float32)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None
            ) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, V). Smoke/QoS path."""
    x = _embed_in(params, cfg, tokens, embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, _ = _run_segments_full(params, cfg, x, positions, False, 0)
    logits = logits_fn(params, cfg, x)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy: never materializes (B, S, V) at once)
# ---------------------------------------------------------------------------


def _xent_chunk(x_chunk, targets, emb, cfg: ModelConfig):
    logits = jnp.einsum("btd,vd->btv", x_chunk, emb,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - tgt).sum()


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            xent_chunk: int = 512):
    """batch: tokens (B, S) [+ optional embeds (B, S, d)]. Next-token CE +
    MoE aux. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    x = _embed_in(params, cfg, tokens, batch.get("embeds"))
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x, aux, _ = _run_segments_full(params, cfg, x, positions, False, 0)
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    emb = _head_table(params, cfg).astype(x.dtype)

    xs = x[:, :-1]
    tgt = tokens[:, 1:]
    n = xs.shape[1]
    c = min(xent_chunk, n)
    while n % c:
        c -= 1
    xs = jnp.moveaxis(xs.reshape(B, n // c, c, -1), 1, 0)
    tg = jnp.moveaxis(tgt.reshape(B, n // c, c), 1, 0)

    def body(tot, inp):
        xc, tc = inp
        return tot + _xent_chunk(xc, tc, emb, cfg), None

    # checkpoint: backward recomputes each chunk's logits instead of
    # stacking (B, chunk, V) f32 residuals across chunks (12+ GiB/device
    # at 50k vocab — see EXPERIMENTS.md §Perf iteration log)
    total, _ = jax.lax.scan(jax.checkpoint(body),
                            jnp.zeros((), jnp.float32), (xs, tg))
    ce = total / (B * n)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            cache_len: Optional[int] = None, positions=None,
            uniform_cache: bool = False):
    """Process the prompt; returns (last-token logits (B, 1, V), caches).

    positions: optional per-batch (B, S) absolute positions for the
    LEFT-padded multi-slot batched prefill (serve/engine.py): row i of a
    prompt of length L_i is [-(S - L_i), …, -1 padded, 0 … L_i - 1].
    Pad columns are masked out of attention and written to the KV cache
    with pos = -1; the last column is every sequence's final real token,
    so the returned logits stay (B, 1, V). Default: shared arange(S).

    uniform_cache: build every attention layer's ring at the FULL
    cache_len (no local-window cap) — required by the paged KV pool
    (serve/memory.py), bit-identical outputs (the window mask governs).
    """
    x = _embed_in(params, cfg, tokens, embeds)
    B, S = x.shape[0], x.shape[1]
    cache_len = cache_len or S
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    else:
        positions = jnp.asarray(positions, jnp.int32)
    x, _, caches = _run_segments_full(params, cfg, x, positions, True,
                                      cache_len, uniform_cache)
    logits = logits_fn(params, cfg, x[:, -1:])
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits, caches


def decode_step(params, cfg: ModelConfig, tokens, pos, caches,
                embeds=None):
    """One decode step. tokens: (B, 1) int32 (or embeds (B, 1, d));
    pos: (B,) absolute positions. Returns (logits (B, 1, V), caches)."""
    x = _embed_in(params, cfg, tokens, embeds)
    x, caches = _run_segments_decode(params, cfg, x, pos, caches)
    logits = logits_fn(params, cfg, x)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits, caches


def init_caches(params, cfg: ModelConfig, batch: int, cache_len: int,
                uniform_cap: bool = False):
    """Zero-initialized cache pytree matching the segment plan.

    uniform_cap: every attention layer gets capacity = cache_len (the
    paged KV pool's page geometry must be shared across layers; the
    window mask keeps local-attention semantics identical)."""
    cdt = as_dtype(cfg.compute_dtype)
    plan = segment_plan(cfg)
    caches = []
    for pattern, repeat in plan:
        seg = {}
        for slot, spec in enumerate(pattern):
            if spec[0] == MIXER_ATTN:
                cap = cache_len if uniform_cap else min(
                    _slot_window(cfg, spec, cache_len), cache_len)
                c = attn_mod.init_kv_cache(batch, cap, cfg.num_kv_heads,
                                           cfg.attn_head_dim, cdt,
                                           quant=cfg.kv_quant)
            else:
                c = ssm_mod.init_ssm_cache(cfg, batch, cdt)
            seg[f"slot{slot}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (repeat,) + a.shape).copy(), c)
        caches.append(seg)
    return tuple(caches)
