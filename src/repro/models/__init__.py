from repro.models import attention, ffn, lm, moe, modules, ssm  # noqa: F401
from repro.models.lm import (  # noqa: F401
    decode_step,
    forward,
    init_caches,
    init_params,
    loss_fn,
    prefill,
    segment_plan,
)
