"""Attention: GQA + RoPE + optional qk-norm / QKV-bias / sliding window.

Full-sequence attention (train/prefill) is a two-level chunked online-softmax
(flash-attention structure in pure jnp): outer ``lax.scan`` over query chunks,
inner ``lax.scan`` over KV chunks carrying (m, l, acc). Memory is
O(q_chunk × kv_chunk) per step instead of O(S²), which is what lets the
32k-prefill cells lower without S² score buffers.

Local (sliding-window) vs global layers share one code path: the window is a
traced scalar (per-layer scan input), so hybrid local:global stacks (gemma3
5:1) stay a single homogeneous ``lax.scan`` over layers.

Decode uses a ring-buffer KV cache with an absolute-position side array —
rings make the local-window cache O(window) instead of O(S) and make cache
semantics uniform between local and global layers.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.modules import (
    apply_rope,
    as_dtype,
    dense_apply,
    dense_init,
    fold_rng,
    qknorm_apply,
    softcap,
)

NEG_INF = -1.0e30


class KVCache(NamedTuple):
    """Ring-buffer KV cache for one attention layer.

    k, v: (B, C, KH, D); pos: (B, C) absolute position of each slot, -1 if
    empty. C is the ring capacity (window for local layers, max context for
    global ones).

    INT8 variant (cfg.kv_quant — beyond-paper: the paper's weight-quant
    theme applied to the decode bottleneck): k/v are int8 with per-
    (slot, head) fp32 scales; decode reads dequantize in-register, so the
    HBM KV term halves vs bf16 (and quarters vs fp32).
    """

    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray
    kscale: Optional[jnp.ndarray] = None    # (B, C, KH) fp32
    vscale: Optional[jnp.ndarray] = None


def init_kv_cache(batch: int, capacity: int, num_kv_heads: int,
                  head_dim: int, dtype, quant: bool = False) -> KVCache:
    shape = (batch, capacity, num_kv_heads, head_dim)
    if quant:
        return KVCache(
            k=jnp.zeros(shape, jnp.int8),
            v=jnp.zeros(shape, jnp.int8),
            pos=jnp.full((batch, capacity), -1, jnp.int32),
            kscale=jnp.zeros((batch, capacity, num_kv_heads),
                             jnp.float32),
            vscale=jnp.zeros((batch, capacity, num_kv_heads),
                             jnp.float32),
        )
    return KVCache(
        k=jnp.zeros(shape, dtype=dtype),
        v=jnp.zeros(shape, dtype=dtype),
        pos=jnp.full((batch, capacity), -1, dtype=jnp.int32),
    )


def _quant_heads(x: jnp.ndarray):
    """x: (..., KH, D) -> int8 values + per-head scale (...)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig) -> Dict:
    dt = as_dtype(cfg.param_dtype)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.attn_head_dim
    ks = jax.random.split(key, 4)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype=dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, kvh * hd, dtype=dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, kvh * hd, dtype=dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], h * hd, d, dtype=dt, scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype=dt)
        p["k_norm"] = jnp.ones((hd,), dtype=dt)
    return p


def _proj(p: Dict, name: str, x: jnp.ndarray,
          cfg: Optional[ModelConfig] = None) -> jnp.ndarray:
    """One attention projection, routed through the packed tile-skip
    kernel when a deployment container is attached (core.deploy,
    DESIGN.md §9) — QKV bias is fused into the kernel's flush epilogue
    there, so dense_apply's bias add must not run twice. TP-sharded
    containers (DESIGN.md §10) run their shard-local visit lists inside
    shard_map: wq/wk/wv col-sharded on head boundaries, wo row-sharded
    with a psum epilogue (or rs+int8-ag when cfg.tp_comm opts in)."""
    packed = p.get("sasp_packed")
    if packed is not None and name in packed:
        pw = packed[name]
        if pw.shards > 1:
            from repro.models.ffn import packed_mm_sharded
            *lead, K = x.shape
            y = packed_mm_sharded(x.reshape(-1, K), pw, cfg)
            return y.reshape(*lead, pw.shape[1])
        from repro.core.deploy import packed_matmul
        return packed_matmul(x, pw)
    return dense_apply(p[name], x)


def _project_qkv(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions):
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,KH,D), RoPE'd + qk-normed.
    ``positions`` broadcasts to (B, S) — per-batch rows support the
    left-padded batched prefill (serve/engine.py)."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.attn_head_dim
    dt = x.dtype
    from repro.distribution import context as dctx
    dp = dctx.dp_axes()
    q = _proj(p, "wq", x, cfg).reshape(B, S, h, hd)
    k = _proj(p, "wk", x, cfg).reshape(B, S, kvh, hd)
    v = _proj(p, "wv", x, cfg).reshape(B, S, kvh, hd)
    if dp and S > 1:
        tp = dctx.axis_size("model")
        if tp > 1 and (h % tp or kvh % tp):
            # GQA/TP mismatch: head counts that don't divide the model
            # axis let XLA invent shardings with per-chunk all-reduces
            # inside SDPA (hundreds of GB/device — EXPERIMENTS.md §Perf
            # B iter 2). Pin SDPA replicated over 'model': redundant
            # attention compute (counted honestly in analysis/counters
            # via the same divisibility rule) in exchange for zero SDPA
            # collectives. Cheap for windowed/short-context attention.
            q = dctx.maybe_shard(q, dp, None, None, None)
            k = dctx.maybe_shard(k, dp, None, None, None)
            v = dctx.maybe_shard(v, dp, None, None, None)
        else:
            q = dctx.maybe_shard(q, dp, None, "model", None)
            k = dctx.maybe_shard(k, dp, None, "model", None)
            v = dctx.maybe_shard(v, dp, None, "model", None)
    if cfg.qk_norm:
        q = qknorm_apply(p["q_norm"], q, eps=cfg.norm_eps)
        k = qknorm_apply(p["k_norm"], k, eps=cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q.astype(dt), k.astype(dt), v.astype(dt)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (full sequence)
# ---------------------------------------------------------------------------


def _pick_chunk(s: int, target: int) -> int:
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def attend_chunked(q, k, v, q_pos, kv_pos, *, window, cap: float = 0.0,
                   q_chunk: int = 512, kv_chunk: int = 1024) -> jnp.ndarray:
    """Causal (optionally windowed) attention.

    q: (B, Sq, KH, G, D); k, v: (B, Sk, KH, D); q_pos (Sq,) or (B, Sq),
    kv_pos (Sk,) or (B, Sk) absolute positions — per-batch position rows
    support the left-padded batched prefill (pad slots carry negative
    positions and are masked as keys); window: traced or static scalar —
    key j attends iff 0 <= q_pos - kv_pos < window AND kv_pos >= 0
    (global layers pass window >= S).
    Returns (B, Sq, KH, G, D).
    """
    B, Sq, KH, G, D = q.shape
    Sk = k.shape[1]
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // qc, Sk // kc
    scale = D ** -0.5

    q = (q * scale).reshape(B, nq, qc, KH, G, D)
    q_pos = jnp.broadcast_to(
        jnp.atleast_2d(jnp.asarray(q_pos, jnp.int32)), (B, Sq)
    ).reshape(B, nq, qc)
    k = k.reshape(B, nk, kc, KH, D)
    v = v.reshape(B, nk, kc, KH, D)
    kv_pos = jnp.broadcast_to(
        jnp.atleast_2d(jnp.asarray(kv_pos, jnp.int32)), (B, Sk)
    ).reshape(B, nk, kc)
    win = jnp.asarray(window, dtype=jnp.int32)

    def q_body(_, qi):
        qb, qp = qi                                # (B,qc,KH,G,D), (B,qc)

        def kv_body(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ki                        # kp: (B, kc)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32)
            if cap:
                s = softcap(s, cap)
            delta = qp[:, :, None] - kp[:, None, :]  # (B, qc, kc)
            mask = (delta >= 0) & (delta < win) & (kp[:, None, :] >= 0)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[:, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, qc), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, KH, G, qc), dtype=jnp.float32)
        a0 = jnp.zeros((B, KH, G, qc, D), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (
            jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
            jnp.moveaxis(kv_pos, 1, 0)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, jnp.moveaxis(out, 3, 1)         # (B, qc, KH, G, D)

    _, ys = jax.lax.scan(jax.checkpoint(q_body), None,
                         (jnp.moveaxis(q, 1, 0),
                          jnp.moveaxis(q_pos, 1, 0)))
    # ys: (nq, B, qc, KH, G, D) -> (B, Sq, KH, G, D)
    return jnp.moveaxis(ys, 0, 1).reshape(B, Sq, KH, G, D)


def _attend_maybe_sharded(qg, k, v, positions, window, cap):
    """SDPA under an active mesh runs inside shard_map: batch over the DP
    axes, kv-heads over 'model' when they divide it, otherwise replicated
    over 'model' (GQA/TP mismatch — redundant attention compute, charged
    honestly in analysis/counters, in exchange for ZERO SDPA collectives;
    XLA left to its own devices invents shardings with per-chunk
    all-reduces here — EXPERIMENTS.md §Perf B)."""
    from functools import partial as _partial

    from jax.sharding import PartitionSpec as P

    from repro.distribution import context as dctx

    mesh = dctx.active_mesh()
    B, Sq, KH = qg.shape[0], qg.shape[1], qg.shape[2]
    fn = _partial(attend_chunked, window=window, cap=cap)
    if mesh is None or Sq <= 1:
        return fn(qg, k, v, positions, positions)
    dp = dctx.dp_axes()
    tp = dctx.axis_size("model")
    bax = dp if (dp and B % dctx.axis_size(dp) == 0 and B > 1) else None
    hax = "model" if (tp > 1 and KH % tp == 0
                      and "model" not in (dp or ())) else None
    q_spec = P(bax, None, hax, None, None)
    kv_spec = P(bax, None, hax, None)
    pos_spec = P(None) if positions.ndim == 1 else P(bax, None)

    def body(qq, kk, vv, pos):
        return fn(qq, kk, vv, pos, pos)

    return dctx.shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, pos_spec),
        out_specs=q_spec,
    )(qg, k, v, positions)


def attn_apply_full(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, window) -> Tuple[jnp.ndarray,
                                                             Tuple]:
    """Train/prefill path. Returns (y, (k, v)) — k/v are handed to the
    caller for cache construction during prefill. ``positions`` is (S,)
    or per-batch (B, S) (left-padded batched prefill)."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.attn_head_dim
    pos2 = positions[None, :] if positions.ndim == 1 else positions
    q, k, v = _project_qkv(p, cfg, x, pos2)
    qg = q.reshape(B, S, kvh, h // kvh, hd)
    out = _attend_maybe_sharded(qg, k, v, positions, window,
                                cfg.logit_softcap)
    out = out.reshape(B, S, h * hd).astype(x.dtype)
    y = _proj(p, "wo", out, cfg)
    return y, (k, v)


# ---------------------------------------------------------------------------
# Suffix prefill against resident prefix KV (prefix sharing, DESIGN.md §16)
# ---------------------------------------------------------------------------


def attn_apply_prefill_past(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                            positions: jnp.ndarray, past: KVCache,
                            window) -> Tuple[jnp.ndarray, KVCache]:
    """Prefill ONLY a prompt's suffix, attending to already-resident
    prefix KV.

    x: (B, S) suffix hidden states; positions: (B, S) absolute suffix
    positions (pad columns < 0); past: the slot's gathered ring cache
    (B, C, …) holding the shared prefix — every non-prefix ring slot
    carries pos = -1 and is masked, exactly like an unwritten ring.
    Keys are ``concat([prefix ring, fresh suffix K/V])`` with
    ``kv_pos = concat([past.pos, positions])``: the valid keys appear
    in the same absolute-position order as a full prefill and the
    interleaved masked slots contribute exact zeros to the online
    softmax (the same masked-reduction identity the left-padded and
    bucketed prefills rest on), so suffix outputs are bit-identical to
    the full-prompt pass. Returns (y, suffix-only cache) — the cache
    holds ONLY the freshly computed suffix tokens (see
    :func:`build_cache_from_suffix`), ready for a page scatter that
    must not touch the shared prefix pages."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.attn_head_dim
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    if past.kscale is not None:
        k_past = _dequant(past.k, past.kscale, k_new.dtype)
        v_past = _dequant(past.v, past.vscale, v_new.dtype)
    else:
        k_past = past.k.astype(k_new.dtype)
        v_past = past.v.astype(v_new.dtype)
    k_all = jnp.concatenate([k_past, k_new], axis=1)
    v_all = jnp.concatenate([v_past, v_new], axis=1)
    kv_pos = jnp.concatenate(
        [past.pos, jnp.asarray(positions, jnp.int32)], axis=1)
    qg = q.reshape(B, S, kvh, h // kvh, hd)
    out = attend_chunked(qg, k_all, v_all, positions, kv_pos,
                         window=window, cap=cfg.logit_softcap)
    out = out.reshape(B, S, h * hd).astype(x.dtype)
    y = _proj(p, "wo", out, cfg)
    cache = build_cache_from_suffix(k_new, v_new, past.k.shape[1],
                                    positions, quant=cfg.kv_quant)
    return y, cache


def build_cache_from_suffix(k: jnp.ndarray, v: jnp.ndarray,
                            capacity: int, positions: jnp.ndarray,
                            quant: bool = False) -> KVCache:
    """Ring cache holding ONLY the freshly prefilled suffix tokens.

    The partial-page validity mask for suffix prefill: pad columns
    (positions < 0) are routed to a sacrificial extra ring slot and
    sliced off, so — unlike :func:`build_cache_from_prefill`, whose
    pad slots ``[C - pad, C)`` are collision-free only when the valid
    span starts at 0 — no pad write can ever land on a slot belonging
    to the resident prefix region. Every non-suffix slot stays zeros
    with pos = -1: the page scatter then writes pristine 'empty ring'
    content to fresh suffix pages and the prefix pages are simply not
    among the scatter destinations."""
    B, S, KH, D = k.shape
    positions = jnp.asarray(positions, jnp.int32)
    if S > capacity:
        k, v = k[:, -capacity:], v[:, -capacity:]
        positions = positions[:, -capacity:]
    valid = positions >= 0
    cache = init_kv_cache(B, capacity + 1, KH, D, k.dtype, quant=quant)
    slots = jnp.where(valid, positions % capacity, capacity)
    posv = jnp.where(valid, positions, -1)
    kz = jnp.where(valid[..., None, None], k, 0)
    vz = jnp.where(valid[..., None, None], v, 0)
    bidx = jnp.arange(B)[:, None]
    trim = lambda a: None if a is None else a[:, :capacity]
    pos = cache.pos.at[bidx, slots].set(posv)
    if quant:
        kq, ks = _quant_heads(kz)
        vq, vs = _quant_heads(vz)
        return KVCache(
            k=trim(cache.k.at[bidx, slots].set(kq)),
            v=trim(cache.v.at[bidx, slots].set(vq)),
            pos=trim(pos),
            kscale=trim(cache.kscale.at[bidx, slots].set(ks)),
            vscale=trim(cache.vscale.at[bidx, slots].set(vs)),
        )
    return KVCache(
        k=trim(cache.k.at[bidx, slots].set(kz.astype(cache.k.dtype))),
        v=trim(cache.v.at[bidx, slots].set(vz.astype(cache.v.dtype))),
        pos=trim(pos),
    )


# ---------------------------------------------------------------------------
# Decode (single new token against a ring cache)
# ---------------------------------------------------------------------------


def attn_apply_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                      pos: jnp.ndarray, cache: KVCache,
                      window) -> Tuple[jnp.ndarray, KVCache]:
    """x: (B, 1, d); pos: (B,) absolute position of the new token."""
    B = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.attn_head_dim
    C = cache.k.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, pos[:, None])

    slot = (pos % C).astype(jnp.int32)
    bidx = jnp.arange(B)
    if cache.kscale is not None:
        kq, ks = _quant_heads(k_new[:, 0])
        vq, vs = _quant_heads(v_new[:, 0])
        cache = KVCache(
            k=cache.k.at[bidx, slot].set(kq),
            v=cache.v.at[bidx, slot].set(vq),
            pos=cache.pos.at[bidx, slot].set(pos.astype(jnp.int32)),
            kscale=cache.kscale.at[bidx, slot].set(ks),
            vscale=cache.vscale.at[bidx, slot].set(vs),
        )
    else:
        cache = KVCache(
            k=cache.k.at[bidx, slot].set(k_new[:, 0].astype(cache.k.dtype)),
            v=cache.v.at[bidx, slot].set(v_new[:, 0].astype(cache.v.dtype)),
            pos=cache.pos.at[bidx, slot].set(pos.astype(jnp.int32)),
        )

    qg = q.reshape(B, kvh, h // kvh, hd) * (hd ** -0.5)
    if cache.kscale is not None:
        k_read = _dequant(cache.k, cache.kscale, qg.dtype)
        v_read = _dequant(cache.v, cache.vscale, qg.dtype)
    else:
        k_read, v_read = cache.k.astype(qg.dtype), cache.v
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_read,
                   preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        s = softcap(s, cfg.logit_softcap)
    delta = pos[:, None] - cache.pos                  # (B, C)
    win = jnp.asarray(window, dtype=jnp.int32)
    mask = (cache.pos >= 0) & (delta >= 0) & (delta < win)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", w.astype(qg.dtype),
                     v_read.astype(qg.dtype),
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, h * hd).astype(x.dtype)
    return _proj(p, "wo", out, cfg), cache


# ---------------------------------------------------------------------------
# Paged KV layout primitives (serve/memory.py, DESIGN.md §13)
#
# A page pool leaf stacks fixed-size token pages: (R, P, L, …) where R is
# the layer-repeat scan dim, P the physical page count and L the page
# length in tokens (a multiple of the SASP tile). A slot's logical ring
# of C = NB·L tokens is assembled by gathering its NB pages through a
# block table — the gathered view is bit-identical to the contiguous
# ring cache, so the existing attention math runs unchanged on top.
# ---------------------------------------------------------------------------


def gather_kv_pages(leaf: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    """Assemble logical ring caches from a page pool leaf.

    leaf: (R, P, L, …) pool pages; bt: (B, NB) int32 physical page ids.
    Returns (R, B, NB·L, …) — exactly the contiguous ring-cache layout
    the decode path expects (unallocated logical pages point at the
    reserved zero page: zeros with pos = -1, masked from attention)."""
    R, _, L = leaf.shape[:3]
    B, NB = bt.shape
    g = jnp.take(leaf, bt.reshape(-1), axis=1)
    return g.reshape((R, B, NB * L) + leaf.shape[3:])


def scatter_kv_written_page(leaf: jnp.ndarray, new_leaf: jnp.ndarray,
                            bt: jnp.ndarray, page_idx: jnp.ndarray
                            ) -> jnp.ndarray:
    """Write back the ONE page per slot that a decode step touched.

    new_leaf: (R, B, C, …) updated logical caches; page_idx: (B,) the
    logical page holding each slot's freshly written ring position.
    The destination is bt[i, page_idx[i]] — idle slots' tables point at
    the reserved trash page (never read), so duplicate trash writes are
    harmless."""
    R = leaf.shape[0]
    L = leaf.shape[2]
    B, NB = bt.shape
    r = new_leaf.reshape((R, B, NB, L) + new_leaf.shape[3:])
    pages = r[:, jnp.arange(B), page_idx]            # (R, B, L, …)
    dest = bt[jnp.arange(B), page_idx]               # (B,)
    return leaf.at[:, dest].set(pages.astype(leaf.dtype))


def scatter_prefill_pages(leaf: jnp.ndarray, new_leaf: jnp.ndarray,
                          dests: jnp.ndarray) -> jnp.ndarray:
    """Scatter freshly prefilled ring caches into the page pool.

    new_leaf: (R, G, C, …) per-request prefill caches; dests: (G, NB)
    physical destination per logical page — the trash page where a
    logical page is unallocated (beyond the prompt) or the row is
    admission-group padding."""
    R, G = new_leaf.shape[0], new_leaf.shape[1]
    NB = dests.shape[1]
    L = new_leaf.shape[2] // NB
    r = new_leaf.reshape((R, G * NB, L) + new_leaf.shape[3:])
    return leaf.at[:, dests.reshape(-1)].set(r.astype(leaf.dtype))


def build_cache_from_prefill(k: jnp.ndarray, v: jnp.ndarray,
                             capacity: int, quant: bool = False,
                             positions: Optional[jnp.ndarray] = None
                             ) -> KVCache:
    """Arrange prefill K/V (B, S, KH, D) into a ring cache of ``capacity``.

    positions: optional per-batch (B, S) absolute positions (left-padded
    batched prefill; pad slots < 0). Pad entries are zeroed and written
    with pos = -1. Collision-freedom: after slicing the trailing
    ``capacity`` columns, valid positions of row i span
    [max(0, L_i - C), L_i) — slots [.. L_i) mod C — while pad positions
    span [-(C - L_i), 0) — slots [L_i, C) — disjoint by construction.
    """
    B, S, KH, D = k.shape
    cache = init_kv_cache(B, capacity, KH, D, k.dtype, quant=quant)
    if positions is None:
        n = min(S, capacity)
        src = jnp.arange(S - n, S)
        slots = src % capacity
        pos = cache.pos.at[:, slots].set(
            jnp.broadcast_to(src, (B, n)).astype(jnp.int32))
        if quant:
            kq, ks = _quant_heads(k[:, src])
            vq, vs = _quant_heads(v[:, src])
            return KVCache(
                k=cache.k.at[:, slots].set(kq),
                v=cache.v.at[:, slots].set(vq),
                pos=pos,
                kscale=cache.kscale.at[:, slots].set(ks),
                vscale=cache.vscale.at[:, slots].set(vs),
            )
        return KVCache(
            k=cache.k.at[:, slots].set(k[:, src]),
            v=cache.v.at[:, slots].set(v[:, src]),
            pos=pos,
        )

    positions = positions.astype(jnp.int32)
    if S > capacity:
        # ring semantics: only the trailing `capacity` tokens survive
        # (positions increase along columns, so these are the newest)
        k, v = k[:, -capacity:], v[:, -capacity:]
        positions = positions[:, -capacity:]
    valid = positions >= 0
    slots = (positions % capacity).astype(jnp.int32)       # (B, n)
    posv = jnp.where(valid, positions, -1)
    kz = jnp.where(valid[..., None, None], k, 0)
    vz = jnp.where(valid[..., None, None], v, 0)
    bidx = jnp.arange(B)[:, None]
    pos = cache.pos.at[bidx, slots].set(posv)
    if quant:
        kq, ks = _quant_heads(kz)
        vq, vs = _quant_heads(vz)
        return KVCache(
            k=cache.k.at[bidx, slots].set(kq),
            v=cache.v.at[bidx, slots].set(vq),
            pos=pos,
            kscale=cache.kscale.at[bidx, slots].set(ks),
            vscale=cache.vscale.at[bidx, slots].set(vs),
        )
    return KVCache(
        k=cache.k.at[bidx, slots].set(kz.astype(cache.k.dtype)),
        v=cache.v.at[bidx, slots].set(vz.astype(cache.v.dtype)),
        pos=pos,
    )
