"""Top-k MoE with capacity-bounded sort-based dispatch.

`moe_ffn_local` is the single-shard math: tokens are routed with a stable
sort by expert id (no (N, E, C) one-hot dispatch tensors — those would show
up as fake-dense FLOPs in the roofline), gathered into a capacity-padded
(E, C, d) buffer, pushed through batched expert GEMMs, and combined with
gate weights. Overflow tokens are dropped (standard GShard capacity
semantics); the residual stream carries them unchanged.

The expert-parallel (EP) version — per-shard dispatch + all_to_all over the
`data` axis with experts sharded across it — lives in
``repro.distribution.moe_ep`` and reuses this file's routing helpers.

SASP: per-expert weights are (E, d_ff-shaped) stacks; block masks with a
leading E dim compose transparently via ``apply_block_mask`` (the paper's
technique extended to MoE — see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pruning import apply_block_mask
from repro.models.modules import act_fn, as_dtype, dense_init


class Routing(NamedTuple):
    expert_idx: jnp.ndarray    # (N, k) int32
    gate_w: jnp.ndarray        # (N, k) float — normalized top-k gates
    aux_loss: jnp.ndarray      # scalar load-balance loss
    # sorted dispatch order over the flattened (N*k,) assignment slots:
    sort_idx: jnp.ndarray      # (N*k,) permutation (stable by expert)
    pos_in_expert: jnp.ndarray  # (N*k,) position within expert, sorted order


def moe_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    dt = as_dtype(cfg.param_dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    E = cfg.moe.num_experts
    ks = jax.random.split(key, 5)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)

    def stack(k, din, dout, scale=0.02):
        w = jax.random.normal(k, (E, din, dout), jnp.float32) * scale
        return {"w": w.astype(dt)}

    p = {
        "router": dense_init(ks[0], d, E, dtype=jnp.float32),
        "w1": stack(ks[1], d, f),
        "w2": stack(ks[2], f, d, out_scale),
    }
    if cfg.ffn_gated:
        p["w3"] = stack(ks[3], d, f)
    if cfg.moe.num_shared_experts:
        from repro.models.ffn import ffn_init
        p["shared"] = ffn_init(ks[4], cfg, d_ff=f * cfg.moe.num_shared_experts)
    return p


def route(p: Dict, cfg: ModelConfig, x2: jnp.ndarray) -> Routing:
    """x2: (N, d) -> routing decision."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    logits = (x2.astype(jnp.float32) @ p["router"]["w"])       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, k)               # (N, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # GShard aux loss: E * sum_e f_e * P_e
    N = x2.shape[0]
    f_e = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0) / (N * k)
    P_e = probs.mean(axis=0)
    aux = E * jnp.sum(f_e * P_e) * m.router_aux_weight

    flat_e = expert_idx.reshape(-1)                            # (N*k,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e]
    return Routing(expert_idx, gate_w.astype(x2.dtype), aux, sort_idx, pos)


def _expert_mm(p: Dict, name: str, h: jnp.ndarray) -> jnp.ndarray:
    """h: (E, C, din) @ stacked expert weights (E, din, dout)."""
    w = p[name]["w"]
    masks = p.get("sasp_masks")
    if masks is not None and name in masks:
        w = apply_block_mask(w, masks[name])
    return jnp.einsum("ecd,edf->ecf", h, w.astype(h.dtype),
                      preferred_element_type=jnp.float32).astype(h.dtype)


def moe_ffn_local(p: Dict, cfg: ModelConfig, x: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Single-shard dispatch."""
    *lead, d = x.shape
    x2 = x.reshape(-1, d)
    N = x2.shape[0]
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    C = max(1, int(-(-N * k * m.capacity_factor // E)))        # ceil

    r = route(p, cfg, x2)
    token_of_slot = r.sort_idx // k                            # (N*k,)
    sorted_e = r.expert_idx.reshape(-1)[r.sort_idx]
    keep = r.pos_in_expert < C
    # dropped slots write to a scratch row (capacity C is row C of C+1)
    pos_c = jnp.where(keep, r.pos_in_expert, C)

    buf = jnp.zeros((E, C + 1, d), dtype=x2.dtype)
    buf = buf.at[sorted_e, pos_c].set(
        x2[token_of_slot], indices_are_sorted=True, unique_indices=True,
        mode="drop")
    buf = buf[:, :C]

    h = _expert_mm(p, "w1", buf)
    if cfg.ffn_gated:
        h = act_fn(cfg.act)(h) * _expert_mm(p, "w3", buf)
    else:
        h = act_fn(cfg.act)(h)
    out = _expert_mm(p, "w2", h)                               # (E, C, d)

    # combine: gather expert outputs back to (N*k, d) slots, weight, sum
    out_pad = jnp.concatenate(
        [out, jnp.zeros((E, 1, d), out.dtype)], axis=1)        # dropped -> 0
    y_slots = out_pad[sorted_e, pos_c]                         # sorted order
    inv = jnp.argsort(r.sort_idx, stable=True)
    y_flat = y_slots[inv].reshape(N, k, d)
    gates = r.gate_w[..., None].astype(y_flat.dtype)
    y = jnp.sum(y_flat * gates, axis=1)

    if "shared" in p:
        from repro.models.ffn import ffn_apply
        y = y + ffn_apply(p["shared"], cfg, x2)

    return y.reshape(*lead, d).astype(x.dtype), r.aux_loss
