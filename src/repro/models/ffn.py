"""Feed-forward layers — the paper's primary SASP surface.

Execution paths (DESIGN.md §4):
  * dense              — no SASP.
  * masked-dense       — params carry per-matrix block masks ("sasp_masks");
                         tiles are zeroed but the matmul stays dense. Used
                         in training and as the numerical reference.
  * bsr                — params carry BlockSparseWeight containers
                         ("sasp_bsr"); pruned tiles are *skipped*
                         (gathered-matmul), FLOPs/bytes ∝ (1 - sparsity).
  * kernel             — Pallas tile-skip kernel (TPU-native), same
                         container.
  * packed             — deployment containers from ``core.deploy``
                         (DESIGN.md §9): per-matrix "sasp_packed"
                         PackedSASPWeight (compact sorted block list,
                         bias+act fused into the kernel flush) or the
                         whole-FFN "sasp_fused" PackedFFN (one kernel
                         launch, no HBM (M, d_ff) intermediate). Zero
                         per-call repacking — the serving fast path.
  * quant              — weight-only INT8 (+ per-block scales); composes
                         with any of the above.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pruning import apply_block_mask
from repro.core.quantization import QuantizedWeight
from repro.core.sparse import BlockSparseWeight, bsr_matmul
from repro.models.modules import act_fn, as_dtype, dense_init


def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    dt = as_dtype(cfg.param_dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    p = {
        "w1": dense_init(ks[0], d, f, dtype=dt),
        "w2": dense_init(ks[1], f, d, dtype=dt, scale=out_scale),
    }
    if cfg.ffn_gated:
        p["w3"] = dense_init(ks[2], d, f, dtype=dt)
    return p


def _materialize(p: Dict, name: str, dtype) -> jnp.ndarray:
    """Resolve one weight matrix through the masked/quantized views."""
    entry = p[name]
    if isinstance(entry, dict) and "qw" in entry:       # int8 weight-only
        qw: QuantizedWeight = entry["qw"]
        bk, bn = qw.block
        K, N = qw.q.shape[-2:]
        KB, NB = K // bk, N // bn
        qb = qw.q.reshape(*qw.q.shape[:-2], KB, bk, NB, bn).astype(
            jnp.float32)
        w = (qb * qw.scale[..., :, None, :, None]).reshape(qw.q.shape)
    else:
        w = entry["w"]
    masks = p.get("sasp_masks")
    if masks is not None and name in masks:
        w = apply_block_mask(w, masks[name])
    return w.astype(dtype)


def _bsr_mm_sharded(x2d, w, cfg, kernel: bool):
    """Block-sparse matmul under an active mesh: shard_map over 'model'
    (each shard owns its NB-slice of blocks and computes its output
    columns locally — no gather collectives; the jnp gather path under
    plain GSPMD all-gathers x per k_max step, see EXPERIMENTS.md §Perf
    A iter 5)."""
    from jax.sharding import PartitionSpec as P

    from repro.distribution import context as dctx

    mesh = dctx.active_mesh()
    NB = w.idx.shape[-1]
    tp = dctx.axis_size("model")

    def compute(xx, ww):
        if kernel:
            from repro.kernels.sasp_gemm.ops import sasp_matmul
            return sasp_matmul(xx, ww)
        return bsr_matmul(xx, ww)

    if mesh is None or tp <= 1 or NB % tp:
        return compute(x2d, w)
    dp = dctx.dp_axes()
    M = x2d.shape[0]
    bax = dp if (dp and M % dctx.axis_size(dp) == 0 and M > 1) else None
    wspec = BlockSparseWeight(
        vals=P(None, "model", None, None), idx=P(None, "model"),
        shape=w.shape, block=w.block,
        scale=None if w.scale is None else P(None, "model"))

    def body(xx, ww):
        # local slice: same (K, sliced N) semantics
        w_loc = BlockSparseWeight(ww.vals, ww.idx,
                                  (w.shape[0], w.shape[1] // tp),
                                  w.block, ww.scale)
        return compute(xx, w_loc)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bax, None), wspec),
        out_specs=P(bax, "model"), check_vma=False)(x2d, w)


def _mm(p: Dict, name: str, x2d: jnp.ndarray, cfg: ModelConfig
        ) -> jnp.ndarray:
    """(M, K) @ weight[name] with whatever SASP view is attached."""
    bsr = p.get("sasp_bsr")
    if bsr is not None and name in bsr:
        w: BlockSparseWeight = bsr[name]
        return _bsr_mm_sharded(x2d, w, cfg, cfg.sasp.path == "kernel")
    w = _materialize(p, name, x2d.dtype)
    return x2d @ w


def _ffn_tp_rs_ag_int8(p: Dict, cfg: ModelConfig, x2: jnp.ndarray):
    """Dense FFN with the TP output reduction done as reduce-scatter
    (bf16) + INT8 all-gather of the reduced shards (per-row scales) —
    3 B/elem on the wire vs 4 B/elem for a ring all-reduce (0.75×), and
    the paper's quantization theme applied to the TP activation traffic
    that dominates dense-transformer training at TP=16 (§Roofline)."""
    from jax.sharding import PartitionSpec as P

    from repro.distribution import context as dctx

    mesh = dctx.active_mesh()
    dp = dctx.dp_axes()
    tp = dctx.axis_size("model")
    M, d = x2.shape
    f = p["w1"]["w"].shape[-1]
    bax = dp if (dp and M % dctx.axis_size(dp) == 0 and M > 1) else None

    def body(xx, w1, w2, w3):
        h = xx @ w1
        if cfg.ffn_gated:
            h = act_fn(cfg.act)(h) * (xx @ w3)
        else:
            h = act_fn(cfg.act)(h)
        y_part = h @ w2                          # (M, d) partial over tp
        y_rs = jax.lax.psum_scatter(y_part, "model", scatter_dimension=1,
                                    tiled=True)  # (M, d/tp) reduced
        # int8 the REDUCED shard (safe: no further accumulation), then
        # all-gather the int8 payload + per-row scales
        amax = jnp.max(jnp.abs(y_rs.astype(jnp.float32)), axis=1,
                       keepdims=True)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(y_rs.astype(jnp.float32) / scale), -127,
                     127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, "model", axis=1, tiled=True)
        sg = jax.lax.all_gather(scale, "model", axis=1, tiled=True)
        seg = jnp.repeat(sg, d // tp, axis=1)
        return (qg.astype(jnp.float32) * seg).astype(xx.dtype)

    w3 = p["w3"]["w"] if cfg.ffn_gated else p["w1"]["w"]
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bax, None), P(None, "model"), P("model", None),
                  P(None, "model")),
        out_specs=P(bax, None), check_vma=False,
    )(x2, p["w1"]["w"], p["w2"]["w"], w3)


def _can_rs_ag(p: Dict, cfg: ModelConfig, x2) -> bool:
    from repro.distribution import context as dctx

    if cfg.tp_comm != "rs_ag_int8" or cfg.moe is not None:
        return False
    mesh = dctx.active_mesh()
    if mesh is None:
        return False
    tp = dctx.axis_size("model")
    d = x2.shape[-1]
    f = p["w1"]["w"].shape[-1]
    return (tp > 1 and d % tp == 0 and f % tp == 0
            and "sasp_bsr" not in p and "sasp_masks" not in p
            and "sasp_packed" not in p and "sasp_fused" not in p
            and isinstance(p["w1"], dict) and "w" in p["w1"])


def _ffn_apply_packed(p: Dict, cfg: ModelConfig, x2: jnp.ndarray
                      ) -> Optional[jnp.ndarray]:
    """Deployment fast path: fused whole-FFN kernel if a PackedFFN is
    attached, else per-matrix packed GEMMs (w1 carries the activation as
    its flush epilogue, so no separate elementwise pass). Returns None
    when no packed container is present."""
    from repro.core.deploy import packed_ffn_apply, packed_matmul

    fused = p.get("sasp_fused")
    if fused is not None:
        return packed_ffn_apply(x2, fused)
    packed = p.get("sasp_packed")
    if packed is not None and "w1" in packed:
        h = packed_matmul(x2, packed["w1"])         # act fused in flush
        if cfg.ffn_gated and "w3" in packed:
            h = h * packed_matmul(x2, packed["w3"])
        return packed_matmul(h, packed["w2"])       # bias fused if any
    return None


def ffn_apply(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    *lead, d = x.shape
    x2 = x.reshape(-1, d)
    if "sasp_fused" in p or "sasp_packed" in p:
        y = _ffn_apply_packed(p, cfg, x2)
        if y is not None:
            return y.reshape(*lead, d).astype(x.dtype)
    if _can_rs_ag(p, cfg, x2):
        y = _ffn_tp_rs_ag_int8(p, cfg, x2)
        return y.reshape(*lead, d).astype(x.dtype)
    act = act_fn(cfg.act)
    h = _mm(p, "w1", x2, cfg)
    if cfg.ffn_gated:
        h = act(h) * _mm(p, "w3", x2, cfg)
    else:
        h = act(h)
    y = _mm(p, "w2", h, cfg)
    if "b" in p.get("w2", {}):
        y = y + p["w2"]["b"].astype(y.dtype)
    return y.reshape(*lead, d).astype(x.dtype)
