"""Feed-forward layers — the paper's primary SASP surface.

Execution paths (DESIGN.md §4):
  * dense              — no SASP.
  * masked-dense       — params carry per-matrix block masks ("sasp_masks");
                         tiles are zeroed but the matmul stays dense. Used
                         in training and as the numerical reference.
  * bsr                — params carry BlockSparseWeight containers
                         ("sasp_bsr"); pruned tiles are *skipped*
                         (gathered-matmul), FLOPs/bytes ∝ (1 - sparsity).
  * kernel             — Pallas tile-skip kernel (TPU-native), same
                         container.
  * packed             — deployment containers from ``core.deploy``
                         (DESIGN.md §9): per-matrix "sasp_packed"
                         PackedSASPWeight (compact sorted block list,
                         bias+act fused into the kernel flush) or the
                         whole-FFN "sasp_fused" PackedFFN (one kernel
                         launch, no HBM (M, d_ff) intermediate). Zero
                         per-call repacking — the serving fast path.
  * quant              — weight-only INT8 (+ per-block scales); composes
                         with any of the above.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pruning import apply_block_mask
from repro.core.quantization import QuantizedWeight
from repro.core.sparse import BlockSparseWeight, bsr_matmul
from repro.models.modules import act_fn, as_dtype, dense_init


def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    dt = as_dtype(cfg.param_dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    p = {
        "w1": dense_init(ks[0], d, f, dtype=dt),
        "w2": dense_init(ks[1], f, d, dtype=dt, scale=out_scale),
    }
    if cfg.ffn_gated:
        p["w3"] = dense_init(ks[2], d, f, dtype=dt)
    return p


def _materialize(p: Dict, name: str, dtype) -> jnp.ndarray:
    """Resolve one weight matrix through the masked/quantized views."""
    entry = p[name]
    if isinstance(entry, dict) and "qw" in entry:       # int8 weight-only
        qw: QuantizedWeight = entry["qw"]
        bk, bn = qw.block
        K, N = qw.q.shape[-2:]
        KB, NB = K // bk, N // bn
        qb = qw.q.reshape(*qw.q.shape[:-2], KB, bk, NB, bn).astype(
            jnp.float32)
        w = (qb * qw.scale[..., :, None, :, None]).reshape(qw.q.shape)
    else:
        w = entry["w"]
    masks = p.get("sasp_masks")
    if masks is not None and name in masks:
        w = apply_block_mask(w, masks[name])
    return w.astype(dtype)


def _bsr_mm_sharded(x2d, w, cfg, kernel: bool):
    """Block-sparse matmul under an active mesh: shard_map over 'model'
    (each shard owns its NB-slice of blocks and computes its output
    columns locally — no gather collectives; the jnp gather path under
    plain GSPMD all-gathers x per k_max step, see EXPERIMENTS.md §Perf
    A iter 5)."""
    from jax.sharding import PartitionSpec as P

    from repro.distribution import context as dctx

    mesh = dctx.active_mesh()
    NB = w.idx.shape[-1]
    tp = dctx.axis_size("model")

    def compute(xx, ww):
        if kernel:
            from repro.kernels.sasp_gemm.ops import sasp_matmul
            return sasp_matmul(xx, ww)
        return bsr_matmul(xx, ww)

    if mesh is None or tp <= 1 or NB % tp:
        return compute(x2d, w)
    bax = dctx.batch_axes(x2d.shape[0])
    wspec = BlockSparseWeight(
        vals=P(None, "model", None, None), idx=P(None, "model"),
        shape=w.shape, block=w.block,
        scale=None if w.scale is None else P(None, "model"))

    def body(xx, ww):
        # local slice: same (K, sliced N) semantics
        w_loc = BlockSparseWeight(ww.vals, ww.idx,
                                  (w.shape[0], w.shape[1] // tp),
                                  w.block, ww.scale)
        return compute(xx, w_loc)

    return dctx.shard_map(
        body, mesh=mesh,
        in_specs=(P(bax, None), wspec),
        out_specs=P(bax, "model"))(x2d, w)


def _mm(p: Dict, name: str, x2d: jnp.ndarray, cfg: ModelConfig
        ) -> jnp.ndarray:
    """(M, K) @ weight[name] with whatever SASP view is attached."""
    bsr = p.get("sasp_bsr")
    if bsr is not None and name in bsr:
        w: BlockSparseWeight = bsr[name]
        return _bsr_mm_sharded(x2d, w, cfg, cfg.sasp.path == "kernel")
    w = _materialize(p, name, x2d.dtype)
    return x2d @ w


def _rs_ag_int8(y_part: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """TP partial-sum reduction, inside a shard_map body over 'model':
    reduce-scatter (fp32) + INT8 all-gather of the reduced shards
    (per-row scales) — 3 B/elem on the wire vs 4 B/elem for a ring
    all-reduce (0.75×), the paper's quantization theme applied to the TP
    activation traffic (§Roofline). int8 happens AFTER the reduction, so
    no quantization error accumulates."""
    y_rs = jax.lax.psum_scatter(y_part, "model", scatter_dimension=1,
                                tiled=True)      # (M, d/tp) reduced
    amax = jnp.max(jnp.abs(y_rs.astype(jnp.float32)), axis=1,
                   keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(y_rs.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q, "model", axis=1, tiled=True)
    sg = jax.lax.all_gather(scale, "model", axis=1, tiled=True)
    seg = jnp.repeat(sg, y_rs.shape[1], axis=1)
    return (qg.astype(jnp.float32) * seg).astype(out_dtype)


def _tp_reduce(y_part: jnp.ndarray, cfg: Optional[ModelConfig],
               out_dtype) -> jnp.ndarray:
    """Cross-shard reduction of a partial (M, d): the rs+int8-ag wire
    format when the config opts in and d splits, else an exact psum."""
    if cfg is not None and cfg.tp_comm == "rs_ag_int8":
        from repro.distribution import context as dctx
        if y_part.shape[1] % dctx.axis_size("model") == 0:
            return _rs_ag_int8(y_part, out_dtype)
    return jax.lax.psum(y_part, "model").astype(out_dtype)


def _ffn_tp_rs_ag_int8(p: Dict, cfg: ModelConfig, x2: jnp.ndarray):
    """Dense FFN with the TP output reduction done as reduce-scatter
    (bf16) + INT8 all-gather of the reduced shards — see
    :func:`_rs_ag_int8`."""
    from jax.sharding import PartitionSpec as P

    from repro.distribution import context as dctx

    mesh = dctx.active_mesh()
    tp = dctx.axis_size("model")
    M, d = x2.shape
    f = p["w1"]["w"].shape[-1]
    bax = dctx.batch_axes(M)

    def body(xx, w1, w2, w3):
        h = xx @ w1
        if cfg.ffn_gated:
            h = act_fn(cfg.act)(h) * (xx @ w3)
        else:
            h = act_fn(cfg.act)(h)
        y_part = h @ w2                          # (M, d) partial over tp
        return _rs_ag_int8(y_part, xx.dtype)

    w3 = p["w3"]["w"] if cfg.ffn_gated else p["w1"]["w"]
    return dctx.shard_map(
        body, mesh=mesh,
        in_specs=(P(bax, None), P(None, "model"), P("model", None),
                  P(None, "model")),
        out_specs=P(bax, None),
    )(x2, p["w1"]["w"], p["w2"]["w"], w3)


def _can_rs_ag(p: Dict, cfg: ModelConfig, x2) -> bool:
    from repro.distribution import context as dctx

    if cfg.tp_comm != "rs_ag_int8" or cfg.moe is not None:
        return False
    mesh = dctx.active_mesh()
    if mesh is None:
        return False
    tp = dctx.axis_size("model")
    d = x2.shape[-1]
    f = p["w1"]["w"].shape[-1]
    return (tp > 1 and d % tp == 0 and f % tp == 0
            and "sasp_bsr" not in p and "sasp_masks" not in p
            and "sasp_packed" not in p and "sasp_fused" not in p
            and isinstance(p["w1"], dict) and "w" in p["w1"])


def _sq(arr, from_end: int):
    """Drop the size-1 shard axis at position ndim-from_end (the local
    view inside a shard_map body)."""
    return None if arr is None else jnp.squeeze(
        arr, axis=arr.ndim - from_end)


def _take(arr, s: int, from_end: int):
    return None if arr is None else jnp.take(
        arr, s, axis=arr.ndim - from_end)


def _pw_local(w, shape, *, with_bias: bool):
    """Shard-local view of a TP-sharded PackedSASPWeight whose arrays
    arrived in a shard_map body with the shard axis mapped (size 1)."""
    from repro.core.sparse import PackedSASPWeight
    return PackedSASPWeight(
        _sq(w.vals, 4), _sq(w.kn, 3), shape, w.block,
        scale=_sq(w.scale, 2),
        bias=_sq(w.bias, 2) if with_bias else None,
        act=w.act if with_bias else None)


def packed_mm_sharded(x2: jnp.ndarray, pw, cfg: Optional[ModelConfig]
                      ) -> jnp.ndarray:
    """TP-sharded packed tile-skip matmul (DESIGN.md §10): one shard_map
    over 'model', each rank running the kernel over its shard-LOCAL
    visit list — pruning savings stay local to the shard instead of
    being averaged away. col-sharded weights emit their output columns
    in place (out sharded over 'model'); row-sharded weights emit
    partials and reduce (psum, or rs+int8-ag when cfg opts in). Falls
    back to a sequential per-shard loop when no matching mesh is active
    (single-device parity / tests)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.deploy import packed_matmul
    from repro.core.sparse import PackedSASPWeight
    from repro.distribution import context as dctx

    K, N = pw.shape
    tp, kind = pw.shards, pw.shard_kind
    mesh = dctx.active_mesh()
    if mesh is None or dctx.axis_size("model") != tp:
        return _packed_mm_shard_loop(x2, pw)
    bax = dctx.batch_axes(x2.shape[0])

    from repro.distribution.sharding import axis_at

    def ax(arr, from_end):
        return None if arr is None else axis_at(arr.ndim, from_end,
                                                "model")

    wspec = PackedSASPWeight(
        ax(pw.vals, 4), ax(pw.kn, 3), pw.shape, pw.block,
        scale=ax(pw.scale, 2),
        bias=(ax(pw.bias, 2) if kind == "col"
              else None if pw.bias is None
              else P(*([None] * pw.bias.ndim))),
        act=pw.act, shards=tp, shard_kind=kind)

    if kind == "col":
        def body(xx, w):
            return packed_matmul(xx, _pw_local(w, (K, N // tp),
                                               with_bias=True))
        return dctx.shard_map(
            body, mesh=mesh, in_specs=(P(bax, None), wspec),
            out_specs=P(bax, "model"))(x2, pw)

    def body(xx, w):                    # row: partial over shards
        y = packed_matmul(xx, _pw_local(w, (K // tp, N),
                                        with_bias=False))
        y = _tp_reduce(y, cfg, xx.dtype)
        if w.bias is not None:
            y = y + w.bias.astype(y.dtype)
        return y

    return dctx.shard_map(
        body, mesh=mesh, in_specs=(P(bax, "model"), wspec),
        out_specs=P(bax, None))(x2, pw)


def _packed_mm_shard_loop(x2: jnp.ndarray, pw) -> jnp.ndarray:
    """Meshless reference for a TP-sharded container: run every shard's
    visit list sequentially and concatenate (col) / sum (row). Keeps
    sharded deployments loadable on a single device."""
    from repro.core.deploy import packed_matmul
    from repro.core.sparse import PackedSASPWeight

    K, N = pw.shape
    tp = pw.shards
    if tp <= 1:
        return packed_matmul(x2, pw)
    outs = []
    for s in range(tp):
        if pw.shard_kind == "col":
            loc = PackedSASPWeight(
                _take(pw.vals, s, 4), _take(pw.kn, s, 3), (K, N // tp),
                pw.block, scale=_take(pw.scale, s, 2),
                bias=_take(pw.bias, s, 2), act=pw.act)
            outs.append(packed_matmul(x2, loc))
        else:
            ks = K // tp
            loc = PackedSASPWeight(
                _take(pw.vals, s, 4), _take(pw.kn, s, 3), (ks, N),
                pw.block, scale=_take(pw.scale, s, 2), bias=None,
                act=None)
            outs.append(packed_matmul(x2[:, s * ks:(s + 1) * ks], loc))
    if pw.shard_kind == "col":
        return jnp.concatenate(outs, axis=-1)
    y = sum(outs[1:], outs[0])
    if pw.bias is not None:
        y = y + pw.bias.astype(y.dtype)
    return y


def _packed_ffn_fused_sharded(x2: jnp.ndarray, pf,
                              cfg: ModelConfig) -> jnp.ndarray:
    """TP-sharded fused gated-FFN (DESIGN.md §10): each rank runs the
    single-launch fused kernel over its contiguous d_ff visit shard,
    then partials reduce across 'model' (psum or rs+int8-ag). b2 is
    added once, after the reduction."""
    from jax.sharding import PartitionSpec as P

    from repro.core.sparse import PackedFFN
    from repro.distribution import context as dctx
    from repro.kernels.sasp_gemm import ops as sasp_ops

    tp = pf.shards
    mesh = dctx.active_mesh()
    d = pf.d_model

    def run_local(xx, w1v, w3v, w2v, b1, b3, scales):
        return sasp_ops.fused_ffn_matmul(
            xx, w1v, w3v, w2v, b1, b3,
            jnp.zeros((d,), jnp.float32), scales=scales, act=pf.act)

    if mesh is None or dctx.axis_size("model") != tp:
        parts = []
        for s in range(tp):
            sc = None if pf.s1 is None else (
                _take(pf.s1, s, 2), _take(pf.s3, s, 2),
                _take(pf.s2, s, 2))
            parts.append(run_local(
                x2, _take(pf.w1v, s, 4), _take(pf.w3v, s, 4),
                _take(pf.w2v, s, 4), _take(pf.b1, s, 3),
                _take(pf.b3, s, 3), sc))
        return sum(parts[1:], parts[0]) + pf.b2.astype(x2.dtype)

    bax = dctx.batch_axes(x2.shape[0])

    from repro.distribution.sharding import axis_at

    def ax(arr, from_end):
        return None if arr is None else axis_at(arr.ndim, from_end,
                                                "model")

    pfspec = PackedFFN(
        ax(pf.w1v, 4), ax(pf.w3v, 4), ax(pf.w2v, 4),
        ax(pf.b1, 3), ax(pf.b3, 3), P(*([None] * pf.b2.ndim)),
        d_model=pf.d_model, d_ff=pf.d_ff, block_f=pf.block_f,
        act=pf.act, s1=ax(pf.s1, 2), s3=ax(pf.s3, 2), s2=ax(pf.s2, 2),
        shards=tp, jv=ax(pf.jv, 2))

    def body(xx, w):
        sc = None if w.s1 is None else (
            _sq(w.s1, 2), _sq(w.s3, 2), _sq(w.s2, 2))
        y = run_local(xx, _sq(w.w1v, 4), _sq(w.w3v, 4), _sq(w.w2v, 4),
                      _sq(w.b1, 3), _sq(w.b3, 3), sc)
        y = _tp_reduce(y, cfg, xx.dtype)
        return y + w.b2.astype(y.dtype)

    return dctx.shard_map(
        body, mesh=mesh, in_specs=(P(bax, None), pfspec),
        out_specs=P(bax, None))(x2, pf)


def _ffn_apply_packed(p: Dict, cfg: ModelConfig, x2: jnp.ndarray
                      ) -> Optional[jnp.ndarray]:
    """Deployment fast path: fused whole-FFN kernel if a PackedFFN is
    attached, else per-matrix packed GEMMs (w1 carries the activation as
    its flush epilogue, so no separate elementwise pass). TP-sharded
    containers (``shards > 1``) route through the shard_map drivers.
    Returns None when no packed container is present."""
    from repro.core.deploy import packed_ffn_apply, packed_matmul

    fused = p.get("sasp_fused")
    if fused is not None:
        if fused.shards > 1:
            return _packed_ffn_fused_sharded(x2, fused, cfg)
        return packed_ffn_apply(x2, fused)
    packed = p.get("sasp_packed")
    if packed is not None and "w1" in packed:
        if packed["w1"].shards > 1:
            h = packed_mm_sharded(x2, packed["w1"], cfg)  # act in flush
            if cfg.ffn_gated and "w3" in packed:
                h = h * packed_mm_sharded(x2, packed["w3"], cfg)
            return packed_mm_sharded(h, packed["w2"], cfg)
        h = packed_matmul(x2, packed["w1"])         # act fused in flush
        if cfg.ffn_gated and "w3" in packed:
            h = h * packed_matmul(x2, packed["w3"])
        return packed_matmul(h, packed["w2"])       # bias fused if any
    return None


def ffn_apply(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    *lead, d = x.shape
    x2 = x.reshape(-1, d)
    if "sasp_fused" in p or "sasp_packed" in p:
        y = _ffn_apply_packed(p, cfg, x2)
        if y is not None:
            return y.reshape(*lead, d).astype(x.dtype)
    if _can_rs_ag(p, cfg, x2):
        y = _ffn_tp_rs_ag_int8(p, cfg, x2)
        return y.reshape(*lead, d).astype(x.dtype)
    act = act_fn(cfg.act)
    h = _mm(p, "w1", x2, cfg)
    if cfg.ffn_gated:
        h = act(h) * _mm(p, "w3", x2, cfg)
    else:
        h = act(h)
    y = _mm(p, "w2", h, cfg)
    if "b" in p.get("w2", {}):
        y = y + p["w2"]["b"].astype(y.dtype)
    return y.reshape(*lead, d).astype(x.dtype)
