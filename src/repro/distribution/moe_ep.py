"""Expert-parallel MoE: per-shard routing + all_to_all dispatch.

Experts are sharded over the ``data`` axis (EP) and each expert's d_ff
over ``model`` (TP); tokens are sharded over (pod, data). Every
(pod, data, model) shard routes ITS tokens locally (local top-k + sort —
no global argsort, which under plain GSPMD becomes a catastrophic global
sort, see EXPERIMENTS.md §Perf iteration log), then a pair of
``all_to_all`` collectives over ``data`` carries tokens to their experts
and back. Pods route to their own expert replicas; gradients for the
replicated expert weights sum across pods in the backward all-reduce.

Per-source-shard per-expert capacity:
    C = ceil(n_local · top_k · capacity_factor / E)
so the dispatch buffers are (E, C, d) on the source and
(E_local, ep · C, d) on the expert shard. Overflow drops (standard GShard
semantics) now apply per (source-shard, expert) pair — slightly stricter
than the global-batch capacity of the local path; tests bound the
difference.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.pruning import apply_block_mask
from repro.distribution import context as dctx
from repro.models.modules import act_fn


def _local_route(x2, wr, cfg: ModelConfig, C: int):
    """Local top-k routing + capacity positions (same math as
    models.moe.route but per shard)."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    N = x2.shape[0]
    logits = x2.astype(jnp.float32) @ wr
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    f_e = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0) / (N * k)
    aux = E * jnp.sum(f_e * probs.mean(0)) * m.router_aux_weight

    flat_e = expert_idx.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)
    return expert_idx, gate_w, aux, sort_idx, sorted_e, pos_c


def can_use_ep(cfg: ModelConfig, x_shape, mesh: Optional[Mesh]) -> bool:
    if mesh is None or cfg.moe is None or "data" not in mesh.axis_names:
        return False
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = _axis(mesh, dp)
    ep = mesh.shape["data"]
    B, S = x_shape[0], x_shape[1]
    f_ok = cfg.d_ff % mesh.shape.get("model", 1) == 0
    return (ep > 1 and cfg.moe.num_experts % ep == 0
            and (B * S) % dp_total == 0 and B >= dp_total and f_ok)


def moe_ffn_ep(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh: Mesh
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) sharded P(dp, None, None). Returns (y, aux)."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    d = cfg.d_model
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    ep = mesh.shape["data"]
    E_loc = E // ep
    assert E % ep == 0, (E, ep)

    B, S, _ = x.shape
    n_local = (B * S) // _axis(mesh, dp)
    C = max(1, -(-n_local * k * int(100 * m.capacity_factor) // (100 * E)))

    w1, w3, w2 = p["w1"]["w"], p.get("w3", {}).get("w"), p["w2"]["w"]
    masks = p.get("sasp_masks", {})

    def body(x_loc, wr, w1_l, w3_l, w2_l, m1, m3, m2):
        # x_loc: (b, S, d); w*_l: (E_loc, d, f_loc) / (E_loc, f_loc, d)
        x2 = x_loc.reshape(-1, d)
        expert_idx, gate_w, aux, sort_idx, sorted_e, pos_c = \
            _local_route(x2, wr, cfg, C)
        tok = sort_idx // k
        buf = jnp.zeros((E, C + 1, d), x2.dtype)
        buf = buf.at[sorted_e, pos_c].set(
            x2[tok], indices_are_sorted=True, unique_indices=True,
            mode="drop")[:, :C]                               # (E, C, d)

        # ---- dispatch: source-major -> expert-major over 'data' ----
        send = buf.reshape(ep, E_loc, C, d)
        recv = jax.lax.all_to_all(send, "data", split_axis=0,
                                  concat_axis=0, tiled=False)
        xe = jnp.moveaxis(recv, 0, 1).reshape(E_loc, ep * C, d)

        def emm(w, mask, h):
            if mask is not None:
                w = apply_block_mask(w, mask)
            return jnp.einsum("ecd,edf->ecf", h, w.astype(h.dtype),
                              preferred_element_type=jnp.float32
                              ).astype(h.dtype)

        h = emm(w1_l, m1, xe)
        if cfg.ffn_gated:
            h = act_fn(cfg.act)(h) * emm(w3_l, m3, xe)
        else:
            h = act_fn(cfg.act)(h)
        ye = emm(w2_l, m2, h)                                # partial (f TP)
        if "model" in mesh.axis_names:
            ye = jax.lax.psum(ye, "model")

        # ---- return path ----
        back = jnp.moveaxis(ye.reshape(E_loc, ep, C, d), 1, 0)
        out = jax.lax.all_to_all(back, "data", split_axis=0,
                                 concat_axis=0, tiled=False)
        out = out.reshape(E, C, d)
        out_pad = jnp.concatenate([out, jnp.zeros((E, 1, d), out.dtype)],
                                  axis=1)
        y_slots = out_pad[sorted_e, pos_c]
        inv = jnp.argsort(sort_idx, stable=True)
        y = (y_slots[inv].reshape(-1, k, d)
             * gate_w[..., None].astype(out.dtype)).sum(axis=1)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(x_loc.shape), aux

    in_specs = (
        P(dp, None, None),                 # x
        P(None, None),                     # router (replicated)
        P("data", None, "model"),          # w1
        P("data", None, "model"),          # w3
        P("data", "model", None),          # w2
        P("data", None, None),             # masks (E, KB, NB) or dummy
        P("data", None, None),
        P("data", None, None),
    )
    out_specs = (P(dp, None, None), P())

    def mask_or_dummy(name):
        mk = masks.get(name)
        if mk is not None:
            return mk
        return jnp.zeros((E, 1, 1), jnp.int8)      # placeholder

    has = {n: (n in masks) for n in ("w1", "w3", "w2")}

    def body_wrap(x_loc, wr, w1_l, w3_l, w2_l, d1, d3, d2):
        return body(x_loc, wr, w1_l, w3_l, w2_l,
                    d1 if has["w1"] else None,
                    d3 if has["w3"] else None,
                    d2 if has["w2"] else None)

    fn = dctx.shard_map(body_wrap, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    y, aux = fn(x, p["router"]["w"].astype(jnp.float32),
                w1, w3 if w3 is not None else jnp.zeros_like(w1),
                w2, mask_or_dummy("w1"), mask_or_dummy("w3"),
                mask_or_dummy("w2"))

    if "shared" in p:
        from repro.models.ffn import ffn_apply
        y = y + ffn_apply(p["shared"], cfg, x)
    return y, aux


def moe_ffn_dp(p: Dict, cfg: ModelConfig, x: jnp.ndarray, mesh: Mesh
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-DP MoE: weights replicated, tokens sharded over EVERY mesh
    axis, per-shard local dispatch (shard_map stops GSPMD from turning
    the routing argsort into a global sort). The small-model profile."""
    from repro.models.moe import moe_ffn_local

    axes = tuple(mesh.axis_names)
    B = x.shape[0]
    if B % _axis(mesh, axes) != 0:
        return moe_ffn_local(p, cfg, x)

    def body(x_loc, p_loc):
        y, aux = moe_ffn_local(p_loc, cfg, x_loc)
        return y, jax.lax.pmean(aux, axes)

    fn = dctx.shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None, None), P()),
        out_specs=(P(axes, None, None), P()))
    return fn(x, p)


def _axis(mesh: Mesh, names) -> int:
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n
