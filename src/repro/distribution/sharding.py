"""Sharding rules: path-pattern → PartitionSpec.

Mesh axes (launch/mesh.py): ``pod`` (slow inter-pod links), ``data``
(DP; also EP for MoE experts and SP for long-context KV), ``model`` (TP).

Rules operate on jax key-paths of the param pytree. Stacked leading dims
(the scan-over-layers ``repeat`` dim; the MoE expert dim) are detected from
rank and padded with None / mapped to EP. Dims that do not divide the axis
size fall back to replication (never silently uneven — see `_fits`).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sparse import PackedFFN, PackedSASPWeight


def dp_axes(mesh: Mesh, profile: str = "tp") -> Tuple[str, ...]:
    if profile == "dp_only":
        return tuple(mesh.axis_names)
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_submeshes(mesh: Mesh, profile: str = "tp"):
    """One submesh per DP rank — the per-rank placement for the sharded
    scheduler (``serve/scheduler.py``, DESIGN.md §11). Each submesh
    keeps every axis name but collapses the DP axes ('pod'/'data'; ALL
    axes under the dp_only profile) to size 1, so rank r's engine shard
    puts its params, KV-cache slots, and decode state on exactly its
    slice of devices while the 'model' axis — and with it the TP
    shard_map packed drivers — keeps working inside the rank."""
    import itertools

    names = mesh.axis_names
    dp = dp_axes(mesh, profile)
    dims = [i for i, a in enumerate(names) if a in dp]
    if not dims or all(mesh.shape[a] == 1 for a in dp):
        return [mesh]
    subs = []
    for idx in itertools.product(*(range(mesh.devices.shape[d])
                                   for d in dims)):
        slicer = [slice(None)] * mesh.devices.ndim
        for d, i in zip(dims, idx):
            slicer[d] = slice(i, i + 1)
        subs.append(Mesh(mesh.devices[tuple(slicer)], names))
    return subs


def prefill_bucket_table(cache_len: int, n_buckets: int = 4,
                         min_len: int = 16) -> Tuple[int, ...]:
    """Prefill-length buckets for the jitted admission (DESIGN.md §12):
    geometric halving down from ``cache_len`` so the longest bucket
    always covers every cacheable prompt. Padding a prompt of length L
    to the smallest bucket ≥ L bounds the admission jit cache at
    O(n_buckets) programs (vs one per distinct padded length) at the
    cost of ≤ 2× extra masked prefill columns."""
    out = []
    b = int(cache_len)
    while len(out) < n_buckets and b >= min_len:
        out.append(b)
        b //= 2
    return tuple(sorted(out)) if out else (int(cache_len),)


def rank_bucket_tables(ranks: int, cache_len: int, n_buckets: int = 4,
                       min_len: int = 16) -> Tuple[Tuple[int, ...], ...]:
    """One bucket table per DP-rank engine shard (``serve/scheduler.py``
    pairs these with ``dp_submeshes``). Every rank gets the same table —
    a request must compile the same admission program no matter which
    rank serves it, so re-routing (failover, load) never pays a fresh
    compile — but the table rides per-rank so a heterogeneous-rank
    policy has one place to diverge."""
    table = prefill_bucket_table(cache_len, n_buckets, min_len)
    return tuple(table for _ in range(ranks))


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= axis_size(mesh, a)
        return n
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return axis is not None and dim % axis_size(mesh, axis) == 0


def _maybe(dim: int, mesh: Mesh, axis):
    """axis if it divides dim, else None (replicate)."""
    return axis if _fits(dim, mesh, axis) else None


def path_str(path: Tuple) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
        for k in path)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (regex on path, spec builder fn(shape, mesh) -> P). First match wins.
def param_rules(cfg: ModelConfig):
    def col(shape, mesh):     # (..., d_in, d_out) -> shard d_out on model
        lead = (None,) * (len(shape) - 2)
        return P(*lead, None, _maybe(shape[-1], mesh, "model"))

    def row(shape, mesh):     # (..., d_in, d_out) -> shard d_in on model
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe(shape[-2], mesh, "model"), None)

    def vocab(shape, mesh):   # (V, d) embedding
        return P(_maybe(shape[-2], mesh, "model"), None)

    def expert_col(shape, mesh):   # (..., E, d_in, d_out)
        lead = (None,) * (len(shape) - 3)
        return P(*lead, _maybe(shape[-3], mesh, "data"), None,
                 _maybe(shape[-1], mesh, "model"))

    def expert_row(shape, mesh):
        lead = (None,) * (len(shape) - 3)
        return P(*lead, _maybe(shape[-3], mesh, "data"),
                 _maybe(shape[-2], mesh, "model"), None)

    def conv(shape, mesh):         # (..., K, conv_dim) depthwise taps
        lead = (None,) * (len(shape) - 2)
        return P(*lead, None, _maybe(shape[-1], mesh, "model"))

    def vec_model(shape, mesh):    # (..., conv_dim)-like per-channel vec
        lead = (None,) * (len(shape) - 1)
        return P(*lead, _maybe(shape[-1], mesh, "model"))

    def repl(shape, mesh):
        return P()

    def bsr_vals(shape, mesh):     # (L, k_max, NB, bk, bn)
        lead = (None,) * (len(shape) - 4)
        return P(*lead, None, _maybe(shape[-3], mesh, "model"), None,
                 None)

    def bsr_idx(shape, mesh):      # (L, k_max, NB) / scale same
        lead = (None,) * (len(shape) - 2)
        return P(*lead, None, _maybe(shape[-1], mesh, "model"))

    return [
        (r"sasp_bsr/w\d/vals$", bsr_vals),
        (r"sasp_bsr/w\d/(idx|scale)$", bsr_idx),
        (r"sasp_bsr/", repl),
        (r"(embed|lm_head)/emb$", vocab),
        # attention
        (r"mixer/(wq|wk|wv)/w$", col),
        (r"mixer/(wq|wk|wv)/b$", vec_model),
        (r"mixer/wo/w$", row),
        (r"mixer/(q_norm|k_norm)$", repl),
        # MoE experts (E-leading stacks) — EP on data, TP on model
        (r"ffn/w1/w$", expert_col if cfg.moe else col),
        (r"ffn/w3/w$", expert_col if cfg.moe else col),
        (r"ffn/w2/w$", expert_row if cfg.moe else row),
        (r"ffn/router/w$", repl),
        (r"ffn/shared/w(1|3)/w$", col),
        (r"ffn/shared/w2/w$", row),
        # mamba
        (r"mixer/(in_z|in_xbc)/w$", col),
        (r"mixer/in_dt/w$", col),
        (r"mixer/conv_w$", conv),
        (r"mixer/conv_b$", vec_model),
        (r"mixer/norm$", vec_model),
        (r"mixer/out_proj/w$", row),
        (r"mixer/(A_log|D|dt_bias)$", repl),
        # norms / everything else
        (r".*", repl),
    ]


def spec_for_param(cfg: ModelConfig, path: Tuple, shape: Tuple[int, ...],
                   mesh: Mesh) -> P:
    # jamba dense-FFN slots inside a MoE config have 2-D ffn mats: treat
    # per-rank, not per-config: a (…, d, f) under ffn/w1 with rank-2 core.
    s = path_str(path)
    for pat, fn in param_rules(cfg):
        if re.search(pat, s):
            spec = fn(shape, mesh)
            # rank-correct: pattern fns assume canonical rank; a MoE rule
            # applied to a dense 2-D slot falls back to col/row semantics.
            if len(spec) != len(shape):
                spec = _rerank(spec, shape)
            return spec
    return P()


def _rerank(spec: P, shape: Tuple[int, ...]) -> P:
    names = [a for a in spec if a is not None]
    n = len(shape)
    if not names:
        return P(*(None,) * n)
    # keep trailing alignment
    tail = list(spec)[-n:] if len(spec) > n else list(spec)
    while len(tail) < n:
        tail.insert(0, None)
    return P(*tail)


# ---------------------------------------------------------------------------
# Packed deployment containers (core.deploy, DESIGN.md §10)
# ---------------------------------------------------------------------------


def axis_at(rank: int, from_end: int, axis) -> P:
    """P with ``axis`` at position rank-from_end, None elsewhere — the
    one place that encodes 'the shard axis sits from_end dims before the
    trailing visit dims' for packed containers (also used by the
    shard_map drivers in models/ffn.py)."""
    spec = [None] * rank
    spec[rank - from_end] = axis
    return P(*spec)


def packed_sharding(node, mesh: Mesh):
    """Sharding pytree (same container type, NamedSharding leaves) for a
    TP-sharded PackedSASPWeight / PackedFFN: the shard axis maps onto the
    mesh 'model' axis so each TP rank holds exactly its shard-local visit
    list. Containers whose ``shards`` does not match the mesh replicate
    (the drivers fall back to a per-shard loop there)."""
    repl = NamedSharding(mesh, P())
    t = node.shards
    if t <= 1 or axis_size(mesh, "model") != t:
        return jax.tree.map(lambda _: repl, node)

    def at(arr, from_end):
        if arr is None:
            return None
        return NamedSharding(mesh, axis_at(arr.ndim, from_end, "model"))

    if isinstance(node, PackedSASPWeight):
        return PackedSASPWeight(
            vals=at(node.vals, 4),          # (…, tp, nnz, bk, bn)
            kn=at(node.kn, 3),              # (…, tp, 2, nnz)
            shape=node.shape, block=node.block,
            scale=at(node.scale, 2),        # (…, tp, nnz)
            bias=(at(node.bias, 2)          # col: (…, tp, N/tp)
                  if node.shard_kind == "col" else
                  None if node.bias is None else repl),  # row: whole (…, N)
            act=node.act, shards=node.shards,
            shard_kind=node.shard_kind)
    assert isinstance(node, PackedFFN), type(node)
    return PackedFFN(
        w1v=at(node.w1v, 4), w3v=at(node.w3v, 4),   # (…, tp, nv, d|bf, …)
        w2v=at(node.w2v, 4),
        b1=at(node.b1, 3), b3=at(node.b3, 3),       # (…, tp, nv, bf)
        b2=None if node.b2 is None else repl,       # whole (…, d)
        d_model=node.d_model, d_ff=node.d_ff, block_f=node.block_f,
        act=node.act, s1=at(node.s1, 2), s3=at(node.s3, 2),
        s2=at(node.s2, 2), shards=node.shards, jv=at(node.jv, 2))


_PACKED_TYPES = (PackedSASPWeight, PackedFFN)


def param_shardings(cfg: ModelConfig, params_shape, mesh: Mesh,
                    profile: str = "tp"):
    """Map a params eval_shape pytree -> NamedSharding pytree.
    profile='dp_only': replicate everything (pure data parallelism —
    the small-model profile; see EXPERIMENTS.md §Perf C). Packed
    deployment containers (``sasp_packed`` / ``sasp_fused``) are handled
    whole by :func:`packed_sharding` — their shard axis carries the
    shard-local visit lists onto 'model'."""
    if profile == "dp_only":
        return jax.tree.map(lambda _: NamedSharding(mesh, P()),
                            params_shape)

    def fn(path, leaf):
        if isinstance(leaf, _PACKED_TYPES):
            return packed_sharding(leaf, mesh)
        spec = spec_for_param(cfg, path, leaf.shape, mesh)
        # drop axes that don't divide (safety)
        fixed = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (
                len(leaf.shape) - len(spec))):
            fixed.append(ax if _fits(dim, mesh, ax) else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(
        fn, params_shape,
        is_leaf=lambda x: isinstance(x, _PACKED_TYPES))


# ---------------------------------------------------------------------------
# Activation / batch / cache rules
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, batch: int) -> P:
    dp = dp_axes(mesh)
    if batch % axis_size(mesh, dp) == 0:
        return P(dp, None)
    return P(None, None)


def data_shardings(mesh: Mesh, batch: int, with_embeds: bool,
                   d_model: int = 0):
    dp = dp_axes(mesh)
    ok = batch % axis_size(mesh, dp) == 0
    tok = NamedSharding(mesh, P(dp, None) if ok else P())
    out = {"tokens": tok}
    if with_embeds:
        out["embeds"] = NamedSharding(
            mesh, P(dp, None, None) if ok else P())
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int,
                    caches_shape):
    """KV ring caches: batch over DP when it divides, else capacity over
    (data×model) — the sequence-parallel long-context layout. SSM states:
    heads over model."""
    dp = dp_axes(mesh)
    big_batch = batch % axis_size(mesh, dp) == 0 and batch > 1

    def fn(path, leaf):
        s = path_str(path)
        shape = leaf.shape
        if "conv" in s:                       # (R, B, K-1, conv_dim)
            spec = [None] * len(shape)
            if big_batch:
                spec[1] = dp
            if _fits(shape[-1], mesh, "model"):
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        if "state" in s:                      # (R, B, H, P, N)
            spec = [None] * len(shape)
            if big_batch:
                spec[1] = dp
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        # KVCache fields: k/v (R, B, C, KH, D); pos (R, B, C)
        spec = [None] * len(shape)
        if big_batch:
            spec[1] = dp
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
        else:
            seq_axes = ("data", "model") if _fits(
                shape[2], mesh, ("data", "model")) else (
                "data",) if _fits(shape[2], mesh, "data") else None
            if seq_axes:
                spec[2] = seq_axes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(fn, caches_shape)


def pool_shardings(cfg: ModelConfig, mesh: Mesh, pool_shape):
    """Paged KV page-pool placement (serve/memory.py, DESIGN.md §13).

    Pool leaves are (R, P, page_len, …): the physical page dim P shards
    over the DP axes when it divides them — each DP rank's engine owns
    its OWN pool, so on a scheduler rank's submesh (DP collapsed to 1)
    this degrades to replication and only the trailing dims shard — and
    KV heads (axis 3 of k/v/scale leaves) shard over 'model' when they
    divide it, matching the contiguous cache layout so the TP SDPA path
    sees the same head placement with paging on or off."""
    dp = dp_axes(mesh)

    def fn(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if axis_size(mesh, dp) > 1 and _fits(shape[1], mesh, dp):
            spec[1] = dp
        if len(shape) >= 4 and _fits(shape[3], mesh, "model"):
            spec[3] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(fn, pool_shape)


def constraint(x, mesh: Mesh, *spec):
    """with_sharding_constraint that degrades to no-op off-mesh."""
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    except Exception:
        return x
