"""Active-mesh context: lets model code place sharding constraints and
select distributed implementations (EP MoE, SP attention) without
threading the mesh through every call signature. No mesh set → every
helper is a no-op and models run single-process (smoke tests, QoS tier).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None
# 'tp' (default): weights TP-sharded over 'model'. 'dp_only': weights
# replicated, batch sharded over EVERY mesh axis — the right profile for
# small models where TP collectives dominate (EXPERIMENTS.md §Perf C).
_PROFILE: str = "tp"


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def sharding_profile() -> str:
    return _PROFILE


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], profile: str = "tp"):
    global _ACTIVE_MESH, _PROFILE
    prev, prev_p = _ACTIVE_MESH, _PROFILE
    _ACTIVE_MESH = mesh
    _PROFILE = profile
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev
        _PROFILE = prev_p


def dp_axes() -> Tuple[str, ...]:
    if _ACTIVE_MESH is None:
        return ()
    if _PROFILE == "dp_only":
        return tuple(_ACTIVE_MESH.axis_names)
    return tuple(a for a in _ACTIVE_MESH.axis_names
                 if a in ("pod", "data"))


def axis_size(name) -> int:
    if _ACTIVE_MESH is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= axis_size(a)
        return n
    return _ACTIVE_MESH.shape.get(name, 1)


def maybe_shard(x, *spec):
    """with_sharding_constraint if a mesh is active and every named dim
    divides; otherwise identity."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    fixed = []
    used = set()
    for i, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        if used & set(names):          # axis already consumed (dp_only)
            fixed.append(None)
            continue
        size = axis_size(ax)
        if i < x.ndim and size > 1 and x.shape[i] % size == 0:
            fixed.append(ax)
            used |= set(names)
        else:
            fixed.append(None)
    fixed += [None] * (x.ndim - len(fixed))
    if not any(a is not None for a in fixed):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def shard_batch(x):
    """Shard dim 0 (batch) over the DP axes."""
    dp = dp_axes()
    if not dp:
        return x
    return maybe_shard(x, dp, *([None] * (x.ndim - 1)))
