"""Active-mesh context: lets model code place sharding constraints and
select distributed implementations (EP MoE, SP attention) without
threading the mesh through every call signature. No mesh set → every
helper is a no-op and models run single-process (smoke tests, QoS tier).

Also home of the ``shard_map`` compat shim: JAX moved shard_map from
``jax.experimental.shard_map`` (kwarg ``check_rep``, ≤0.5) to
``jax.shard_map`` (kwarg ``check_vma``, 0.6+). Every call site in this
repo routes through :func:`shard_map` below so the supported-version
window is one line wide (DESIGN.md §10).

How the packed containers engage (DESIGN.md §9–§10 — format spec in
``core/sparse.py``): a TP-sharded ``PackedSASPWeight`` / ``PackedFFN``
carries one shard-LOCAL visit list per rank (an extra shard axis right
before the visit dims, every (layer × shard) list padded to one shared
static nnz via dup-last-visit). The drivers in ``models/ffn.py`` /
``models/attention.py`` check ``active_mesh()`` at trace time: when
the mesh's 'model' axis size equals the container's ``shards``, they
wrap the kernel in :func:`shard_map` with the shard axis mapped onto
'model', so each rank DMAs and visits only its own blocks —
``shard_kind="col"`` outputs concatenate in place, ``"row"``/fused
partials reduce (psum or rs+int8-ag). No mesh (or a mismatched one) →
a sequential per-shard loop reproduces the same math on one device.
This is why serving code never threads the mesh through call
signatures: ``Engine``/``ShardedScheduler`` enter ``use_mesh`` (each
scheduler rank its own submesh) and the same model code routes
itself.
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _resolve_shard_map():
    """(impl, replication-check kwarg name) for the running JAX."""
    impl = getattr(jax, "shard_map", None)           # 0.6+ public API
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    try:
        kwargs = inspect.signature(impl).parameters
        kw = "check_vma" if "check_vma" in kwargs else "check_rep"
    except (TypeError, ValueError):                  # exotic wrappers
        kw = "check_rep"
    return impl, kw


_SHARD_MAP_IMPL, _CHECK_KW = _resolve_shard_map()


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map``.

    ``check`` maps to ``check_rep`` (JAX ≤0.5) / ``check_vma`` (0.6+);
    the repo's bodies use untracked collectives (psum_scatter epilogues,
    all_to_all dispatch), so they pass False everywhere.
    """
    return _SHARD_MAP_IMPL(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_CHECK_KW: check})


_ACTIVE_MESH: Optional[Mesh] = None
# 'tp' (default): weights TP-sharded over 'model'. 'dp_only': weights
# replicated, batch sharded over EVERY mesh axis — the right profile for
# small models where TP collectives dominate (EXPERIMENTS.md §Perf C).
_PROFILE: str = "tp"


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def sharding_profile() -> str:
    return _PROFILE


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], profile: str = "tp"):
    global _ACTIVE_MESH, _PROFILE
    prev, prev_p = _ACTIVE_MESH, _PROFILE
    _ACTIVE_MESH = mesh
    _PROFILE = profile
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev
        _PROFILE = prev_p


def dp_axes() -> Tuple[str, ...]:
    if _ACTIVE_MESH is None:
        return ()
    if _PROFILE == "dp_only":
        return tuple(_ACTIVE_MESH.axis_names)
    return tuple(a for a in _ACTIVE_MESH.axis_names
                 if a in ("pod", "data"))


def axis_size(name) -> int:
    if _ACTIVE_MESH is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= axis_size(a)
        return n
    return _ACTIVE_MESH.shape.get(name, 1)


def batch_axes(m: int) -> Optional[Tuple[str, ...]]:
    """DP axes safe for the batch dim of a shard_map whose WEIGHTS
    shard over 'model'. Excludes 'model' (under the dp_only profile
    ``dp_axes()`` folds every axis in, and splitting the batch over the
    axis that carries the weight shards makes the cross-shard psum mix
    DIFFERENT batch rows — silently wrong) and requires divisibility.
    Returns None when the batch should stay unsharded."""
    dp = tuple(a for a in dp_axes() if a != "model")
    if dp and m % axis_size(dp) == 0 and m > 1:
        return dp
    return None


def maybe_shard(x, *spec):
    """with_sharding_constraint if a mesh is active and every named dim
    divides; otherwise identity."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    fixed = []
    used = set()
    for i, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        if used & set(names):          # axis already consumed (dp_only)
            fixed.append(None)
            continue
        size = axis_size(ax)
        if i < x.ndim and size > 1 and x.shape[i] % size == 0:
            fixed.append(ax)
            used |= set(names)
        else:
            fixed.append(None)
    fixed += [None] * (x.ndim - len(fixed))
    if not any(a is not None for a in fixed):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def shard_batch(x):
    """Shard dim 0 (batch) over the DP axes."""
    dp = dp_axes()
    if not dp:
        return x
    return maybe_shard(x, dp, *([None] * (x.ndim - 1)))
