"""Train-step factory: loss → grad → AdamW, SASP-overlay aware.

Under GSPMD jit the DP gradient reduction is implicit in autodiff of the
batch-sharded loss; TP reductions come from the sharded einsums. The
returned step is pure — jit/donation/shardings are applied by the caller
(launch/train.py, launch/dryrun.py, tests)."""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sasp import merge_overlay
from repro.models import lm
from repro.train.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    overlay: Optional[Any] = None,
                    lr_schedule: Optional[Callable] = None,
                    n_microbatches: int = 1,
                    accum_dtype=None):
    """Returns step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``overlay`` (SASP masks) is closed over — masks are applied
    straight-through so gradients flow to surviving tiles only.

    ``n_microbatches > 1``: gradient accumulation via lax.scan over batch
    slices — activation live-set (incl. the scan carry stack) shrinks
    ∝ 1/K at the cost of K sequential passes. ``accum_dtype`` defaults to
    f32; very large models can use bf16 accumulators to halve grad memory.
    """

    def loss_of(p, batch):
        pv = merge_overlay(p, overlay) if overlay is not None else p
        return lm.loss_fn(pv, cfg, batch)

    def step(params, opt_state: AdamWState, batch: Dict):
        if n_microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            K = n_microbatches
            adt = accum_dtype or jnp.float32

            def split(x):
                b = x.shape[0]
                return jnp.moveaxis(
                    x.reshape(K, b // K, *x.shape[1:]), 0, 0)

            micro = {k: split(v) for k, v in batch.items()}

            def mb_body(acc, mb):
                (l, m), g = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                g_acc, l_acc = acc
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(adt), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss_sum), ms = jax.lax.scan(
                mb_body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / K).astype(g.dtype), grads)
            loss = loss_sum / K
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        lr_scale = lr_schedule(opt_state.step) if lr_schedule else 1.0
        new_params, new_opt = adamw_update(grads, opt_state, params,
                                           opt_cfg, lr_scale=lr_scale)
        out = dict(metrics)
        out["loss"] = loss
        out["grad_norm"] = global_norm(grads)
        return new_params, new_opt, out

    return step


def make_eval_step(cfg: ModelConfig, overlay: Optional[Any] = None):
    def step(params, batch):
        pv = merge_overlay(params, overlay) if overlay is not None \
            else params
        loss, metrics = lm.loss_fn(pv, cfg, batch)
        return {**metrics, "loss": loss}

    return step


def init_train_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig):
    params = lm.init_params(key, cfg)
    opt_state = adamw_init(params, opt_cfg)
    return params, opt_state
