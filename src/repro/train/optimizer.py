"""AdamW with ZeRO-sharded, optionally INT8-quantized moments.

No optax in this container — implemented from scratch as (init, update)
pure functions over the param pytree.

* **ZeRO**: moment/master tensors get the param's TP spec *plus* a 'data'
  shard on the largest remaining dim that divides (distribution/sharding
  .opt_state_shardings) — optimizer memory scales with the full mesh, not
  just the model axis.
* **INT8 moments** (``quantized=True``): m and v are stored int8 with
  per-(last-dim-block) fp32 scales — the paper's quantization theme applied
  to distributed training state (8-bit-Adam-style; beyond-paper). Moments
  are dequantized, updated in fp32, and requantized each step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
QBLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized: bool = False       # int8 moments


class QMoment(NamedTuple):
    q: jnp.ndarray                # int8, param shape
    scale: jnp.ndarray            # fp32, shape[:-1] + (ceil(last/QBLOCK),)


def _qblocks(shape) -> Tuple[int, ...]:
    last = shape[-1] if shape else 1
    nb = -(-last // QBLOCK)
    return tuple(shape[:-1]) + (nb,)


def _quantize_moment(x: jnp.ndarray) -> QMoment:
    shape = x.shape
    last = shape[-1] if shape else 1
    nb = -(-last // QBLOCK)
    pad = nb * QBLOCK - last
    xf = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1)
                 + [(0, pad)]) if x.ndim else x.reshape(1)
    xb = xf.reshape(*shape[:-1], nb, QBLOCK) if x.ndim else \
        xf.reshape(1, 1)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127
                 ).astype(jnp.int8)
    q = q.reshape(*shape[:-1], nb * QBLOCK)[..., :last] if x.ndim else \
        q.reshape(())
    return QMoment(q=q, scale=scale)


def _dequantize_moment(m: QMoment, shape) -> jnp.ndarray:
    if not shape:
        return m.q.astype(jnp.float32) * m.scale.reshape(())
    last = shape[-1]
    nb = m.scale.shape[-1]
    pad = nb * QBLOCK - last
    q = jnp.pad(m.q.astype(jnp.float32), [(0, 0)] * (len(shape) - 1)
                + [(0, pad)])
    qb = q.reshape(*shape[:-1], nb, QBLOCK)
    x = qb * m.scale[..., None]
    return x.reshape(*shape[:-1], nb * QBLOCK)[..., :last]


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Params
    v: Params


def adamw_init(params: Params, cfg: AdamWConfig) -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize_moment(z) if cfg.quantized else z

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zero_like, params),
        v=jax.tree.map(zero_like, params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 cfg: AdamWConfig, lr_scale=1.0
                 ) -> Tuple[Params, AdamWState]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _dequantize_moment(m, p.shape) if cfg.quantized else m
        vf = _dequantize_moment(v, p.shape) if cfg.quantized else v
        mf = cfg.b1 * mf + (1.0 - cfg.b1) * g
        vf = cfg.b2 * vf + (1.0 - cfg.b2) * jnp.square(g)
        mh = mf / b1c
        vh = vf / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.quantized:
            return new_p, _quantize_moment(mf), _quantize_moment(vf)
        return new_p, mf, vf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------------
# ZeRO sharding for optimizer state
# ---------------------------------------------------------------------------


def zero_spec_from_param_spec(spec, shape, mesh) -> "PartitionSpec":
    """Extend the param's spec with a 'data' shard on the largest dim not
    already sharded (ZeRO-1 flavor)."""
    from jax.sharding import PartitionSpec as P

    from repro.distribution.sharding import axis_size

    axes = list(spec) + [None] * (len(shape) - len(spec))
    if "data" in [a for ax in axes if ax for a in
                  (ax if isinstance(ax, tuple) else (ax,))]:
        return P(*axes)
    dsz = axis_size(mesh, "data")
    cands = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in cands:
        if axes[i] is None and shape[i] % dsz == 0:
            axes[i] = "data"
            break
    return P(*axes)


def opt_state_shardings(cfg, params_shape, mesh, opt_cfg: AdamWConfig,
                        param_shardings_tree):
    """Shardings pytree matching adamw_init's output structure."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(ps, sh):
        spec = zero_spec_from_param_spec(sh.spec, ps.shape, mesh)
        if not opt_cfg.quantized:
            return NamedSharding(mesh, spec)
        # QMoment: q follows param spec; scale drops last-dim sharding
        axes = list(spec) + [None] * (len(ps.shape) - len(spec))
        return QMoment(
            q=NamedSharding(mesh, P(*axes)),
            scale=NamedSharding(mesh, P(*axes[:-1], None)),
        )

    moments = jax.tree.map(one, params_shape, param_shardings_tree)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=moments, v=moments,
    )
