"""INT8 error-feedback gradient compression for the slow (cross-pod) DP
axis — the paper's quantization theme applied to distributed training
(DESIGN.md §6, beyond-paper).

``compressed_psum(x, axis, residual)``: quantize (x + residual) to int8
with per-block scales, all-reduce the int8 payload + scales, dequantize;
the quantization error is carried in ``residual`` (error feedback), so
the compression bias vanishes over steps. Pod-to-pod DCN bytes drop ~4×
(int8 payload + 1/256-dense fp32 scales vs fp32 grads).

Usage inside a shard_map over ('pod', ...):
    g_glob, res = compressed_psum(g_local, 'pod', res)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    pad = (-n) % QBLOCK
    fb = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    amax = jnp.max(jnp.abs(fb), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(fb / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, n: int,
                shape) -> jnp.ndarray:
    x = q.astype(jnp.float32) * scale[:, None]
    return x.reshape(-1)[:n].reshape(shape)


def compressed_psum(x: jnp.ndarray, axis: str,
                    residual: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce(mean) over ``axis`` with int8 payloads + error feedback.
    Returns (mean-reduced x, new residual). Call inside shard_map.

    Protocol: (1) pmax the per-block amax (fp32, 1/256 of the payload) so
    every pod quantizes against a SHARED scale; (2) psum the int8 payload
    (as int32 to avoid overflow — on the wire this is the int8 tensor);
    (3) dequantize with the shared scale. Exact up to the shared-scale
    quantization error, which error feedback carries to the next step.
    """
    if residual is None:
        residual = jnp.zeros_like(x, dtype=jnp.float32)
    v = x.astype(jnp.float32) + residual
    flat = v.reshape(-1)
    n = flat.size
    pad = (-n) % QBLOCK
    fb = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    amax = jnp.max(jnp.abs(fb), axis=-1)
    amax = jax.lax.pmax(amax, axis)              # shared scale (tiny)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(fb / scale[:, None]), -127, 127
                 ).astype(jnp.int8)
    sent = _dequantize(q, scale, n, x.shape)
    new_residual = v - sent                      # error feedback
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
    npods = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = _dequantize(q_sum, scale, n, x.shape) / npods
    return mean.astype(x.dtype), new_residual


def compressed_allreduce_tree(grads, axis: str, residuals=None):
    """Tree-mapped compressed_psum."""
    if residuals is None:
        residuals = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        gg, rr = compressed_psum(g, axis, r)
        out_g.append(gg)
        out_r.append(rr)
    return treedef.unflatten(out_g), treedef.unflatten(out_r)
