"""Fault-tolerant checkpointing.

Design (DESIGN.md §6):
  * atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
    ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint;
  * self-describing: a JSON manifest carries step, flat key list, shapes,
    dtypes and a CRC32 per array + config fingerprint;
  * resharding restore: arrays are saved as full logical tensors
    (host-gathered) and re-laid-out on ANY mesh at restore —
    elastic scale-up/down (512→256 chips) is a restore with a different
    mesh, nothing else changes;
  * async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread so the train loop isn't blocked;
  * retention: ``keep`` most recent checkpoints are retained.

Format: one ``.npz`` per checkpoint + ``manifest.json`` (zlib-crc'd).
For multi-host deployments the same layout shards per host
(``arrays.<host>.npz``) — single-process here, one shard.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
import ml_dtypes

PathLeaf = Tuple[str, np.ndarray]

# numpy's savez cannot round-trip ml_dtypes customs (bfloat16, fp8);
# store them as same-width uint views + the true dtype in the manifest.
_EXOTIC = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, getattr(ml_dtypes, "float8_e4m3fn",
                                        None)),
    "float8_e5m2": (np.uint8, getattr(ml_dtypes, "float8_e5m2", None)),
}


def _to_storable(a: np.ndarray) -> np.ndarray:
    name = str(a.dtype)
    if name in _EXOTIC:
        return a.view(_EXOTIC[name][0])
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return a.view(_EXOTIC[dtype_name][1])
    return a


def _flatten_with_names(tree) -> List[PathLeaf]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).view(np.uint8).tobytes())


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Synchronous atomic save of a pytree of (possibly sharded)
        arrays. Gathers to host — callers on real clusters would use a
        per-host shard writer; the format supports it via shard files."""
        host = jax.tree.map(lambda x: np.asarray(x), state)
        self._write(step, host, extra or {})

    def save_async(self, step: int, state: Any,
                   extra: Optional[Dict] = None):
        """Snapshot synchronously (device→host copy), write in the
        background. Joins any previous in-flight save first (at most one
        outstanding — bounds host memory)."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:       # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ------------------------------------------------------------------
    def _write(self, step: int, host_tree: Any, extra: Dict):
        leaves = _flatten_with_names(host_tree)
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = {f"a{i}": _to_storable(a)
                  for i, (_, a) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.0.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra,
            "leaves": [
                {"name": n, "key": f"a{i}", "shape": list(a.shape),
                 "dtype": str(a.dtype), "crc32": _crc(_to_storable(a))}
                for i, (n, a) in enumerate(leaves)
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True
                ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like``. ``shardings`` (same
        structure, NamedSharding leaves) re-lays arrays on a possibly
        DIFFERENT mesh than the one that saved them — elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.0.npz"))
        by_name = {l["name"]: l for l in manifest["leaves"]}

        names = [n for n, _ in _flatten_with_names(like)]
        treedef = jax.tree_util.tree_structure(like)
        flat_like = jax.tree_util.tree_leaves(like)
        flat_sh = (jax.tree_util.tree_leaves(shardings)
                   if shardings is not None else [None] * len(flat_like))

        out = []
        for name, ref, sh in zip(names, flat_like, flat_sh):
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            meta = by_name[name]
            a = data[meta["key"]]
            a = _from_storable(a, meta["dtype"])
            if verify and _crc(_to_storable(a)) != meta["crc32"]:
                raise IOError(f"CRC mismatch for {name!r} (corrupt "
                              f"checkpoint step {step})")
            if tuple(a.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {name!r}: ckpt {a.shape} vs "
                    f"model {ref.shape}")
            if sh is not None:
                out.append(jax.device_put(a.astype(ref.dtype), sh))
            else:
                out.append(jax.numpy.asarray(a, dtype=ref.dtype))
        return treedef.unflatten(out), manifest["extra"]
