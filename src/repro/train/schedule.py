"""LR schedules + straggler watchdog + preemption hook."""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional

import jax.numpy as jnp


def warmup_cosine(warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1) -> Callable:
    """Returns lr_scale(step) in [min_ratio, 1]."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
            jnp.pi * t))
        return warm * cos

    return fn


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time monitor (DESIGN.md §6). On SPMD there is no work
    re-balancing to do inside a step; the actionable mitigations are:
    flag slow steps (logging/alerting → replace the node), and tighten
    checkpoint cadence when variance rises so a straggler-turned-failure
    loses less work."""

    alpha: float = 0.05
    threshold: float = 2.0           # step flagged if > threshold × EWMA
    ewma: float = 0.0
    ewvar: float = 0.0
    slow_steps: int = 0
    total_steps: int = 0

    def observe(self, step_time_s: float) -> bool:
        self.total_steps += 1
        if self.ewma == 0.0:
            self.ewma = step_time_s
            return False
        slow = step_time_s > self.threshold * self.ewma
        if slow:
            self.slow_steps += 1
        d = step_time_s - self.ewma
        self.ewma += self.alpha * d
        self.ewvar = (1 - self.alpha) * (self.ewvar + self.alpha * d * d)
        return slow

    @property
    def cv(self) -> float:
        """Coefficient of variation — rising CV ⇒ tighten ckpt cadence."""
        return (self.ewvar ** 0.5 / self.ewma) if self.ewma else 0.0

    def checkpoint_every(self, base: int, floor: int = 10) -> int:
        """Adaptive cadence: halve the interval when CV doubles."""
        scale = max(1.0, self.cv / 0.1)
        return max(floor, int(base / scale))


class PreemptionHook:
    """SIGTERM → request an immediate checkpoint at the next step
    boundary (cloud TPU preemption notice pattern)."""

    def __init__(self):
        self.requested = False
        try:
            signal.signal(signal.SIGTERM, self._handler)
        except ValueError:
            pass                      # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True
