"""Post-SPMD HLO text parsing: collective-byte accounting.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

Collectives inside ``while`` bodies (``lax.scan`` over layers / chunks)
are multiplied by the loop trip count, recovered from the loop-condition
computation's comparison constant — XLA CPU reports while bodies once,
both in cost_analysis and in a naive text scan.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(
    r"^(?:ENTRY )?(%[\w\.\-]+|[\w\.\-]+) \(.*\) -> .*\{\s*$", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?condition=(%?[\w\.\-]+), body=(%?[\w\.\-]+)")
_COLL_RE = re.compile(
    r"= (\([^)]*\)|\w+\[[\d,]*\]\S*) (all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute)(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def split_computations(hlo_text: str) -> Dict[str, str]:
    """{computation name: body text}. HLO text format: computations are
    top-level blocks 'name (params) -> type {' ... '}'."""
    comps: Dict[str, str] = {}
    lines = hlo_text.splitlines()
    cur_name, buf = None, []
    for ln in lines:
        m = _COMP_HDR.match(ln)
        if m:
            cur_name = m.group(1).lstrip("%")
            buf = []
        elif ln.startswith("}"):
            if cur_name is not None:
                comps[cur_name] = "\n".join(buf)
            cur_name = None
        elif cur_name is not None:
            buf.append(ln)
    return comps


def _trip_count(cond_text: str) -> int:
    """Scan conditions compare the induction var against a constant;
    take the max integer constant as the trip count (≥1)."""
    consts = [int(c) for c in re.findall(r"constant\((\d+)\)", cond_text)]
    return max(consts) if consts else 1


def _direct_collectives(text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(text):
        shape_part, kind, start = m.groups()
        if shape_part.startswith("("):
            sizes = [_shape_bytes(sm.group(1), sm.group(2))
                     for sm in _SHAPE_RE.finditer(shape_part)]
            total = max(sizes) if sizes else 0      # async: dest buffer
        else:
            sm = _SHAPE_RE.search(shape_part)
            total = _shape_bytes(sm.group(1), sm.group(2)) if sm else 0
        out[kind] += total
    return out


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Trip-count-aware per-kind collective bytes (per device)."""
    comps = split_computations(hlo_text)
    memo: Dict[str, Dict[str, int]] = {}

    def comp_cost(name: str) -> Dict[str, int]:
        name = name.lstrip("%")
        if name in memo:
            return memo[name]
        memo[name] = {}                 # cycle guard
        text = comps.get(name, "")
        total = defaultdict(int, _direct_collectives(text))
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(1).lstrip("%"), wm.group(2).lstrip("%")
            trips = _trip_count(comps.get(cond, ""))
            for k, v in comp_cost(body).items():
                total[k] += v * trips
        # non-while calls (fusion computations may hold collectives—rare)
        memo[name] = dict(total)
        return memo[name]

    # entry computation: the one named ...main... or the largest
    entry = None
    for n in comps:
        if "main" in n or n.startswith("ENTRY"):
            entry = n
            break
    m = re.search(r"ENTRY (%?[\w\.\-]+)", hlo_text)
    if m:
        entry = m.group(1).lstrip("%")
    if entry is None or entry not in comps:
        # fall back: flat scan (undercounts loops)
        return dict(_direct_collectives(hlo_text))
    return comp_cost(entry)


def total_collective_bytes(hlo_text: str) -> int:
    return sum(collective_bytes(hlo_text).values())


def cpu_f32_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> int:
    """XLA CPU artifact: bf16 dot operands are upcast to f32 and the
    convert of whole stacked carry buffers is hoisted out of loops,
    inflating temp memory vs a native-bf16 TPU compile. Detect large f32
    tensors whose exact dims also appear as a bf16 tensor and return
    their total bytes (to subtract from the CPU memory_analysis)."""
    f32 = set(re.findall(r"f32\[([\d,]+)\]", hlo_text))
    bf16 = set(re.findall(r"bf16\[([\d,]+)\]", hlo_text))
    total = 0
    for dims in f32 & bf16:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def collective_f32_twin_bytes(hlo_text: str,
                              min_bytes: int = 1 << 22) -> int:
    """Bytes of f32 collectives whose dims also exist as bf16 tensors —
    the CPU-backend upcast artifact applied to TP activation all-reduces
    (bf16-native on TPU, so half these bytes are accounting inflation).
    Trip-count aware."""
    comps = split_computations(hlo_text)
    bf16_dims = set(re.findall(r"bf16\[([\d,]+)\]", hlo_text))
    memo: Dict[str, int] = {}

    def comp_cost(name: str) -> int:
        name = name.lstrip("%")
        if name in memo:
            return memo[name]
        memo[name] = 0
        text = comps.get(name, "")
        total = 0
        for m in _COLL_RE.finditer(text):
            shape_part = m.group(1)
            sm = _SHAPE_RE.search(shape_part)
            if sm and sm.group(1) == "f32" and sm.group(2) in bf16_dims:
                b = _shape_bytes("f32", sm.group(2))
                if b >= min_bytes:
                    total += b
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(1).lstrip("%"), wm.group(2).lstrip("%")
            total += comp_cost(body) * _trip_count(comps.get(cond, ""))
        memo[name] = total
        return total

    m = re.search(r"ENTRY (%?[\w\.\-]+)", hlo_text)
    if not m or m.group(1).lstrip("%") not in comps:
        return 0
    return comp_cost(m.group(1))


def count_ops(hlo_text: str, *names: str) -> Dict[str, int]:
    return {n: len(re.findall(rf"\b{re.escape(n)}", hlo_text))
            for n in names}
