"""Analytic FLOP / HBM-byte counters per (config × shape).

WHY ANALYTIC: XLA *CPU* ``cost_analysis()`` counts each ``while`` body
once (not × trip count), so scan-over-layers models report ~L× too few
FLOPs; the CPU backend also materializes f32 upcasts of bf16 buffers
(native-bf16 on TPU). The dry-run therefore contributes what only it can
— sharding validity, per-device memory, the collective schedule — while
FLOPs/bytes come from these closed-form counters. The formulas are
validated against ``cost_analysis()`` on small UNROLLED (scan-free)
configs in tests/test_counters.py, where XLA counts correctly.

All numbers are GLOBAL per step (divide by chips for per-device).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.configs.base import (
    ATTN_LOCAL,
    FFN_MOE,
    MIXER_ATTN,
    ModelConfig,
    ShapeConfig,
)


@dataclass
class StepCosts:
    flops: float          # total FLOPs for the step
    bytes_hbm: float      # HBM traffic estimate
    flops_fwd: float      # forward-only part
    weight_bytes: float   # parameter bytes touched (one read)
    kv_bytes: float       # decode: cache bytes read per step
    detail: Dict[str, float]


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.compute_dtype == "bfloat16" else 4


def layer_flops_fwd(cfg: ModelConfig, T: int, ctx: int, layer_idx: int,
                    sparsity: float = 0.0, tp: int = 1,
                    full_seq: bool = True) -> Dict[str, float]:
    """Forward FLOPs of one layer processing T tokens with attention
    context ``ctx`` (= T for training/prefill; cache length for decode).
    SASP ``sparsity`` scales the FFN GEMMs (tile-skip kernel).
    ``tp``: when head counts don't divide the model axis, full-sequence
    SDPA is replicated across it (models/attention.py) — the redundant
    compute is charged here so the roofline stays honest."""
    d = cfg.d_model
    mix = cfg.layer_mixer_kinds()[layer_idx]
    att = cfg.layer_attn_kinds()[layer_idx]
    ffn = cfg.layer_ffn_kinds()[layer_idx]
    out: Dict[str, float] = {}

    if mix == MIXER_ATTN:
        hd = cfg.attn_head_dim
        h, kvh = cfg.num_heads, cfg.num_kv_heads
        out["attn_proj"] = 2.0 * T * d * (h * hd + 2 * kvh * hd) \
            + 2.0 * T * (h * hd) * d
        eff_ctx = min(ctx, cfg.sliding_window) if (
            att == ATTN_LOCAL and cfg.sliding_window) else ctx
        # chunked online softmax computes full (not causal-half) scores
        out["attn_sdpa"] = 2.0 * 2.0 * T * eff_ctx * h * hd
    else:
        s = cfg.ssm
        di, H = s.d_inner(d), s.num_heads(d)
        G, N, P = s.ngroups, s.state_dim, s.head_dim
        conv_dim = di + 2 * G * N
        out["ssm_proj"] = 2.0 * T * d * (di + conv_dim + H) \
            + 2.0 * T * di * d
        out["ssm_conv"] = 2.0 * T * conv_dim * s.conv_kernel
        if T == 1 or ctx != T:
            # decode recurrence: state update + readout per token
            out["ssm_scan"] = T * (6.0 * H * P * N)
        else:
            Q = min(s.chunk_size, T)
            # intra-chunk quadratic + inter-chunk state path
            out["ssm_scan"] = T * (2.0 * Q * (G * N + H * P)
                                   + 4.0 * H * P * N)

    n_mats = 3 if cfg.ffn_gated else 2
    keep = 1.0 - sparsity
    if ffn == FFN_MOE:
        rows = T * cfg.moe.top_k * cfg.moe.capacity_factor
        out["ffn"] = n_mats * 2.0 * rows * d * cfg.d_ff * keep
        out["router"] = 2.0 * T * d * cfg.moe.num_experts
        if cfg.moe.num_shared_experts:
            out["ffn"] += n_mats * 2.0 * T * d * cfg.d_ff \
                * cfg.moe.num_shared_experts
    else:
        out["ffn"] = n_mats * 2.0 * T * d * cfg.d_ff * keep
    return out


def step_costs(cfg: ModelConfig, shape: ShapeConfig,
               sparsity: float = 0.0,
               weight_quant_bytes: int = 0, tp: int = 16) -> StepCosts:
    """FLOPs + HBM bytes for one step of the given kind.

    train: fwd + bwd(2×fwd) + remat recompute (1×fwd if cfg.remat)
    prefill: fwd
    decode: fwd over 1 token/sequence with ctx = seq_len cache
    """
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype_bytes(cfg)
    wbytes_unit = weight_quant_bytes or dt
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    if shape.kind == "decode":
        T_layer = B                      # one token per sequence
        ctx = S
    else:
        T_layer = B * S
        ctx = S

    detail: Dict[str, float] = {}
    fwd = 0.0
    full_seq = shape.kind != "decode"
    for li in range(cfg.num_layers):
        lf = layer_flops_fwd(cfg, T_layer, ctx, li, sparsity, tp=tp,
                             full_seq=full_seq)
        for k, v in lf.items():
            detail[k] = detail.get(k, 0.0) + v
            fwd += v
    # lm head (+ final norm negligible)
    head = 2.0 * T_layer * cfg.d_model * cfg.vocab_size
    detail["head"] = head
    fwd += head

    if shape.kind == "train":
        mult = 3.0 + (1.0 if cfg.remat != "none" else 0.0)
        flops = fwd * mult
    else:
        flops = fwd

    # ---- HBM bytes ----
    act_unit = T_layer * cfg.d_model * dt          # one activation tensor
    L = cfg.num_layers
    if shape.kind == "train":
        # weights: read fwd + bwd + remat; grads written+read; opt state rw
        w_traffic = n_params * wbytes_unit * (3.0 if cfg.remat != "none"
                                              else 2.0)
        w_traffic += n_params * (dt * 2.0)          # grads w+r
        w_traffic += n_params * (2.0 * 2.06)        # int8 m,v rw + scales
        act_traffic = act_unit * L * 10.0           # r/w through layers,
        #                                             both passes (napkin)
        kv = 0.0
        byt = w_traffic + act_traffic
    elif shape.kind == "prefill":
        w_traffic = n_params * wbytes_unit
        act_traffic = act_unit * L * 4.0
        kvh, hd = cfg.num_kv_heads, cfg.attn_head_dim
        kv = 0.0
        for li, (mk, ak) in enumerate(zip(cfg.layer_mixer_kinds(),
                                          cfg.layer_attn_kinds())):
            if mk == MIXER_ATTN:
                cap = min(S, cfg.sliding_window) if (
                    ak == ATTN_LOCAL and cfg.sliding_window) else S
                kv += B * cap * kvh * hd * 2 * dt   # cache write
        byt = w_traffic + act_traffic + kv
    else:  # decode
        # MoE: only routed experts' weights are touched when the batch is
        # small; bounded by min(1, B·top_k / E) coverage per MoE layer.
        w_traffic = 0.0
        moe_w = 0.0
        if cfg.moe is not None:
            cover = min(1.0, B * cfg.moe.top_k / cfg.moe.num_experts)
            n_moe = sum(1 for k in cfg.layer_ffn_kinds() if k == FFN_MOE)
            n_mats = 3 if cfg.ffn_gated else 2
            moe_all = n_moe * cfg.moe.num_experts * n_mats * \
                cfg.d_model * cfg.d_ff
            moe_w = moe_all * wbytes_unit
            w_traffic = (n_params - moe_all) * wbytes_unit \
                + moe_w * cover
        else:
            w_traffic = n_params * wbytes_unit
        w_traffic *= (1.0 - sparsity) if sparsity else 1.0
        kvh, hd = cfg.num_kv_heads, cfg.attn_head_dim
        # int8 KV cache: 1 B/elem + per-(slot,head) fp32 scale
        kv_unit = (1.0 + 4.0 / hd) if (cfg.kv_quant and hd) else dt
        kv = 0.0
        for li, (mk, ak) in enumerate(zip(cfg.layer_mixer_kinds(),
                                          cfg.layer_attn_kinds())):
            if mk == MIXER_ATTN:
                cap = min(S, cfg.sliding_window) if (
                    ak == ATTN_LOCAL and cfg.sliding_window) else S
                kv += B * cap * kvh * hd * 2 * kv_unit  # read full ring
            else:
                s = cfg.ssm
                kv += B * s.num_heads(cfg.d_model) * s.head_dim \
                    * s.state_dim * 4 * 2           # state rw (f32)
        act_traffic = act_unit * L * 4.0
        byt = w_traffic + act_traffic + kv

    return StepCosts(
        flops=flops, bytes_hbm=byt, flops_fwd=fwd,
        weight_bytes=n_params * wbytes_unit, kv_bytes=kv, detail=detail)
