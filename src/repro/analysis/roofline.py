"""Three-term roofline per (arch × shape × mesh) from the dry-run.

Sources (see DESIGN.md §2 + counters.py docstring):
  * FLOPs / HBM bytes — analytic counters (XLA CPU cost_analysis counts
    while bodies once; validated vs cost_analysis on unrolled configs);
    raw cost_analysis numbers are kept in the report for reference.
  * collective bytes — parsed from the compiled (post-SPMD) HLO with
    while-trip multiplication (analysis/hlo.py).
  * per-device memory — compiled.memory_analysis(), with the CPU-backend
    f32-upcast artifact subtracted (bf16 is native on TPU).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Optional

from repro.analysis.counters import step_costs
from repro.analysis.hlo import (
    collective_bytes,
    collective_f32_twin_bytes,
    cpu_f32_upcast_bytes,
)
from repro.core.tpu_model import (
    HBM_BYTES,
    RooflineTerms,
    model_flops,
    roofline,
)


@dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                  # analytic, global per step
    bytes_hbm: float              # analytic, global per step
    bytes_coll: float             # HLO-parsed, global (= per-device × chips)
    coll_breakdown: Dict[str, int]
    peak_memory_per_device: int   # corrected for CPU f32-upcast artifact
    peak_memory_raw: int
    compute_s: float
    memory_s: float
    collective_s: float
    bound_s: float
    bottleneck: str
    model_flops: float            # 6·N_active·D (train) / 2·N·D (serve)
    useful_flops_frac: float      # MODEL_FLOPS / step FLOPs
    fits_hbm: bool
    xla_raw_flops: float = 0.0    # cost_analysis (while-once; reference)
    xla_raw_bytes: float = 0.0
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def analyze_compiled(arch: str, shape, mesh_name: str, chips: int,
                     compiled, cfg, note: str = "",
                     sparsity: float = 0.0,
                     weight_quant_bytes: int = 0) -> CellReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    # CPU backend upcasts bf16 TP-activation all-reduces to f32; on TPU
    # they run in bf16 — subtract half of the affected bytes.
    f32_twin = collective_f32_twin_bytes(hlo)
    coll_global = (float(sum(coll.values())) - 0.5 * f32_twin) * chips

    ma = compiled.memory_analysis()
    raw_peak = sum(int(getattr(ma, a, 0) or 0) for a in
                   ("temp_size_in_bytes", "argument_size_in_bytes",
                    "output_size_in_bytes"))
    # donated args alias outputs — don't double count
    raw_peak -= min(int(getattr(ma, "output_size_in_bytes", 0) or 0),
                    int(getattr(ma, "argument_size_in_bytes", 0) or 0))
    upcast = cpu_f32_upcast_bytes(hlo)
    peak = max(raw_peak - upcast, 0)

    costs = step_costs(cfg, shape, sparsity=sparsity,
                       weight_quant_bytes=weight_quant_bytes)
    terms = roofline(costs.flops, costs.bytes_hbm, coll_global, chips)
    mf = model_flops(cfg, shape)
    return CellReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops=costs.flops, bytes_hbm=costs.bytes_hbm,
        bytes_coll=coll_global,
        coll_breakdown={k: int(v) for k, v in coll.items()},
        peak_memory_per_device=peak, peak_memory_raw=raw_peak,
        compute_s=terms.compute_s, memory_s=terms.memory_s,
        collective_s=terms.collective_s, bound_s=terms.bound_s,
        bottleneck=terms.bottleneck,
        model_flops=mf,
        useful_flops_frac=(mf / costs.flops) if costs.flops else 0.0,
        fits_hbm=peak <= HBM_BYTES,
        xla_raw_flops=raw_flops, xla_raw_bytes=raw_bytes,
        note=note,
    )


def format_row(r: CellReport) -> str:
    return (f"{r.arch:26s} {r.shape:12s} {r.mesh:8s} "
            f"cmp={r.compute_s*1e3:9.3f}ms mem={r.memory_s*1e3:9.3f}ms "
            f"col={r.collective_s*1e3:9.3f}ms [{r.bottleneck:10s}] "
            f"useful={min(r.useful_flops_frac, 9.99):5.1%} "
            f"peak={r.peak_memory_per_device/2**30:6.2f}GiB "
            f"fits={'Y' if r.fits_hbm else 'N'}")
