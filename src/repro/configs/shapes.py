"""Assigned input shapes. ``decode_*`` / ``long_*`` lower ``serve_step``
(one new token against a KV cache of seq_len), NOT ``train_step``.
``long_500k`` is only run for sub-quadratic archs (ssm / hybrid / 5:1
local:global) — see ModelConfig.supports_long_context + DESIGN.md §5."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", "train", seq_len=4_096, global_batch=256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", seq_len=32_768,
                          global_batch=32)
DECODE_32K = ShapeConfig("decode_32k", "decode", seq_len=32_768,
                         global_batch=128)
LONG_500K = ShapeConfig("long_500k", "decode", seq_len=524_288,
                        global_batch=1)

ALL_SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def get_shape(name: str) -> ShapeConfig:
    return ALL_SHAPES[name]


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    """The shape cells applicable to this arch (all are decoder-only LMs,
    so decode shapes always apply; long_500k gated on sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(cfg: ModelConfig) -> List[str]:
    return [] if cfg.supports_long_context else [LONG_500K.name]
