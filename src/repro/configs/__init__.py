from repro.configs import archs as _archs  # noqa: F401  (registers archs)
from repro.configs.archs import ASSIGNED_ARCHS
from repro.configs.base import (
    MIXER_ATTN,
    MIXER_MAMBA,
    ATTN_GLOBAL,
    ATTN_LOCAL,
    FFN_DENSE,
    FFN_MOE,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    MULTI_POD,
    SASPConfig,
    ShapeConfig,
    SINGLE_POD,
    SSMConfig,
    get_config,
    list_archs,
    reduced,
    register,
    with_sasp,
)
from repro.configs.shapes import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    get_shape,
    shapes_for,
    skipped_shapes_for,
)

__all__ = [
    "ASSIGNED_ARCHS", "ALL_SHAPES", "MeshConfig", "ModelConfig", "MoEConfig",
    "MULTI_POD", "SASPConfig", "ShapeConfig", "SINGLE_POD", "SSMConfig",
    "get_config", "get_shape", "list_archs", "reduced", "register",
    "shapes_for", "skipped_shapes_for", "with_sasp",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "MIXER_ATTN", "MIXER_MAMBA", "ATTN_GLOBAL", "ATTN_LOCAL",
    "FFN_DENSE", "FFN_MOE",
]
