"""The 10 assigned architectures (one factory per arch) + the paper's own
ESPnet ASR encoder rows (Table 1). Exact hyper-parameters from the
assignment block; ``source`` carries the citation tier."""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    register,
)

# ---------------------------------------------------------------------------
# LM-family transformers
# ---------------------------------------------------------------------------


@register("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    # Decoder-only over EnCodec tokens; audio frontend is a stub that feeds
    # precomputed frame embeddings (DESIGN.md §5).
    return ModelConfig(
        name="musicgen-medium", family="audio",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        head_dim=64, d_ff=6144, vocab_size=2048, act="gelu",
        ffn_gated=False, frontend="audio_stub",
        source="arXiv:2306.05284; hf",
    )


@register("qwen3-32b")
def qwen3_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=25600, vocab_size=151_936,
        qk_norm=True, rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-8B; hf",
    )


@register("qwen2.5-32b")
def qwen25_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=27648, vocab_size=152_064,
        qkv_bias=True, rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen2.5-0.5B; hf",
    )


@register("command-r-35b")
def command_r_35b() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22528, vocab_size=256_000,
        rope_theta=8_000_000.0,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )


@register("gemma3-4b")
def gemma3_4b() -> ModelConfig:
    # 5:1 local:global interleave, 1024-token sliding window on local
    # layers, 128k context => sub-quadratic enough for long_500k decode
    # (only 1-in-6 layers reads the full KV; see DESIGN.md §5).
    return ModelConfig(
        name="gemma3-4b", family="dense",
        num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
        head_dim=256, d_ff=10240, vocab_size=262_144, act="gelu",
        sliding_window=1024, local_global_period=6,
        rope_theta=1_000_000.0, logit_softcap=30.0,
        supports_long_context=True,
        source="hf:google/gemma-3-1b-pt; unverified",
    )


@register("granite-moe-1b-a400m")
def granite_moe_1b() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49_155,
        moe=MoEConfig(num_experts=32, top_k=8),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    )


@register("moonshot-v1-16b-a3b")
def moonshot_v1_16b() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=163_840,
        moe=MoEConfig(num_experts=64, top_k=6),
        source="hf:moonshotai/Moonlight-16B-A3B; hf",
    )


@register("mamba2-780m")
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50_280,
        ssm=SSMConfig(state_dim=128, expand=2, head_dim=64, conv_kernel=4),
        supports_long_context=True,
        source="arXiv:2405.21060; unverified",
    )


@register("jamba-1.5-large-398b")
def jamba_15_large() -> ModelConfig:
    # Mamba+attn 1:7 interleave (1 attn per 8-layer group) and MoE on
    # alternating layers (16e top-2); 72 layers = 9 scan super-blocks.
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=24576, vocab_size=65_536,
        moe=MoEConfig(num_experts=16, top_k=2), moe_period=2,
        ssm=SSMConfig(state_dim=128, expand=2, head_dim=64, conv_kernel=4),
        hybrid_attn_period=8, hybrid_attn_offset=4,
        supports_long_context=True,
        source="arXiv:2403.19887; hf",
    )


@register("chameleon-34b")
def chameleon_34b() -> ModelConfig:
    # Early-fusion VLM over VQ image tokens; modality frontend is a stub
    # providing precomputed patch-token embeddings.
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=65_536,
        qk_norm=True, frontend="vlm_stub",
        source="arXiv:2405.09818; unverified",
    )


# ---------------------------------------------------------------------------
# The paper's own models (Table 1) — used by the QoS reproduction tier.
# These are *encoders*; the QoS harness adds a per-position classification
# head (token error rate ≙ WER).
# ---------------------------------------------------------------------------


@register("paper-espnet-asr")
def paper_espnet_asr() -> ModelConfig:
    return ModelConfig(
        name="paper-espnet-asr", family="dense",
        num_layers=18, d_model=512, num_heads=4, num_kv_heads=4,
        head_dim=128, d_ff=2048, vocab_size=5000, act="gelu",
        ffn_gated=False,
        source="paper Table 1 row 1 (ESPnet ASR, LibriSpeech)",
    )


@register("paper-espnet2-asr")
def paper_espnet2_asr() -> ModelConfig:
    return ModelConfig(
        name="paper-espnet2-asr", family="dense",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=5000, act="gelu",
        ffn_gated=False,
        source="paper Table 1 row 2 (ESPnet2 ASR, LibriSpeech)",
    )


@register("paper-espnet2-mt")
def paper_espnet2_mt() -> ModelConfig:
    return ModelConfig(
        name="paper-espnet2-mt", family="dense",
        num_layers=6, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=1024, vocab_size=8000, act="gelu",
        ffn_gated=False,
        source="paper Table 1 row 3 (ESPnet2 MT, MuST-C)",
    )


ASSIGNED_ARCHS = [
    "musicgen-medium", "qwen3-32b", "qwen2.5-32b", "command-r-35b",
    "gemma3-4b", "granite-moe-1b-a400m", "moonshot-v1-16b-a3b",
    "mamba2-780m", "jamba-1.5-large-398b", "chameleon-34b",
]
