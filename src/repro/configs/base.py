"""Config dataclasses + registry for the SASP framework.

Every assigned architecture is a `ModelConfig` produced by a factory in its
own module (``src/repro/configs/<id>.py``) and registered here under its
``--arch`` id.  Shapes (train_4k / prefill_32k / decode_32k / long_500k) are
`ShapeConfig` rows in ``shapes.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# SASP — the paper's technique as a first-class config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SASPConfig:
    """Systolic-Array Structured Pruning configuration (paper §3.1).

    block_k/block_n: pruning tile = (block_k, block_n) over a (K, N) weight
      matrix — matched to the accelerator tile (paper: systolic array size;
      TPU: MXU/VMEM block, multiples of 128).
    sparsity: global fraction of tiles zeroed, chosen by lowest L1 norm
      *across the whole model* (heterogeneous per-layer rates fall out).
    scope: which GEMMs are prunable. The paper targets feed-forward GEMMs.
    quantize: weight-only INT8 (per-block symmetric scales) — the paper's
      FP32_INT8 hybrid-multiplier setting.
    path: execution path — "masked" (dense ⊙ mask; training + fallback),
      "bsr" (gathered block-compressed jnp; FLOP/byte savings visible to
      XLA), "kernel" (Pallas tile-skip kernel; TPU-native).
    """

    enabled: bool = False
    block_k: int = 128
    block_n: int = 128
    sparsity: float = 0.0
    scope: str = "ffn"            # "ffn" | "all"
    quantize: bool = False
    path: str = "masked"          # "masked" | "bsr" | "kernel"

    def __post_init__(self):
        assert self.scope in ("ffn", "all"), self.scope
        assert self.path in ("masked", "bsr", "kernel"), self.path
        assert 0.0 <= self.sparsity < 1.0, self.sparsity


# ---------------------------------------------------------------------------
# Sub-configs per family
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Router jitter/aux-loss weight (GShard-style load balancing).
    router_aux_weight: float = 0.01
    # If >0, this many always-on shared experts (DeepSeek-style).
    num_shared_experts: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD — state space duality, arXiv:2405.21060)."""

    state_dim: int = 128
    expand: int = 2
    head_dim: int = 64            # SSD P (channels per head)
    conv_kernel: int = 4
    ngroups: int = 1
    chunk_size: int = 256         # SSD chunked-scan block length
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


# ---------------------------------------------------------------------------
# The model config
# ---------------------------------------------------------------------------

# Per-layer mixer kinds (hybrid archs interleave these).
MIXER_ATTN = 0
MIXER_MAMBA = 1

# Per-layer attention kinds (gemma3 interleaves these).
ATTN_GLOBAL = 0
ATTN_LOCAL = 1

# Per-layer FFN kinds (jamba interleaves these).
FFN_DENSE = 0
FFN_MOE = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    num_heads: int = 0            # 0 for attention-free archs
    num_kv_heads: int = 0
    head_dim: int = 0             # explicit (gemma/qwen use != d_model/heads)
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # >0 => local layers use this window
    # pattern period for local:global interleave (gemma3: 6 => 5 local + 1
    # global per group; 0 => all layers global).
    local_global_period: int = 0
    # --- ffn ---
    d_ff: int = 0
    act: str = "silu"             # silu | gelu
    ffn_gated: bool = True        # SwiGLU/GeGLU (False: plain 2-matrix MLP)
    moe: Optional[MoEConfig] = None
    # --- ssm / hybrid ---
    ssm: Optional[SSMConfig] = None
    # period for mamba:attn interleave (jamba: 8 => 1 attn + 7 mamba per
    # group; 0 => homogeneous family).
    hybrid_attn_period: int = 0
    hybrid_attn_offset: int = 4   # index of the attn layer inside a group
    # period for dense:moe FFN interleave (jamba: 2 => alternate; 0 => all
    # layers share one FFN kind given by `moe is None`).
    moe_period: int = 0
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    frontend: str = "none"        # none | audio_stub | vlm_stub
    supports_long_context: bool = False
    # --- serving ---
    kv_quant: bool = False        # int8 KV cache (beyond-paper)
    # TP FFN output reduction: "ar" (GSPMD all-reduce) | "rs_ag_int8"
    # (reduce-scatter bf16 + int8 all-gather: 0.75x wire bytes;
    # beyond-paper — see EXPERIMENTS.md §Perf B iter 5)
    tp_comm: str = "ar"
    # --- SASP ---
    sasp: SASPConfig = field(default_factory=SASPConfig)
    # --- numerics ---
    param_dtype: str = "float32"  # master dtype (smoke/QoS tests)
    compute_dtype: str = "bfloat16"
    # scan-over-layers remat policy: "none"|"full"|"dots"
    remat: str = "full"
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    # Derived helpers
    # ------------------------------------------------------------------
    @property
    def attn_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def layer_mixer_kinds(self) -> List[int]:
        """Per-layer mixer: MIXER_ATTN / MIXER_MAMBA."""
        if self.family in ("ssm",):
            return [MIXER_MAMBA] * self.num_layers
        if self.hybrid_attn_period:
            return [
                MIXER_ATTN
                if (i % self.hybrid_attn_period) == self.hybrid_attn_offset
                else MIXER_MAMBA
                for i in range(self.num_layers)
            ]
        return [MIXER_ATTN] * self.num_layers

    def layer_attn_kinds(self) -> List[int]:
        """Per-layer attention locality: ATTN_GLOBAL / ATTN_LOCAL."""
        if self.local_global_period and self.sliding_window:
            # gemma3 style: (period-1) local layers then 1 global.
            return [
                ATTN_GLOBAL
                if (i % self.local_global_period) == self.local_global_period - 1
                else ATTN_LOCAL
                for i in range(self.num_layers)
            ]
        return [ATTN_GLOBAL] * self.num_layers

    def layer_ffn_kinds(self) -> List[int]:
        if self.moe is None:
            return [FFN_DENSE] * self.num_layers
        if self.moe_period:
            return [
                FFN_MOE if (i % self.moe_period) == 1 else FFN_DENSE
                for i in range(self.num_layers)
            ]
        return [FFN_MOE] * self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer), used for
        MODEL_FLOPS = 6·N·D and memory napkin math."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        hd = self.attn_head_dim
        ffn_mats = 3 if self.ffn_gated else 2
        mixers = self.layer_mixer_kinds()
        ffns = self.layer_ffn_kinds()
        for mk, fk in zip(mixers, ffns):
            if mk == MIXER_ATTN:
                n += d * (self.num_heads * hd)          # q
                n += 2 * d * (self.num_kv_heads * hd)   # k, v
                n += (self.num_heads * hd) * d          # o
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * hd
            else:
                s = self.ssm
                di = s.d_inner(d)
                nh = s.num_heads(d)
                n += d * (2 * di + 2 * s.ngroups * s.state_dim + nh)  # in_proj
                n += s.conv_kernel * (di + 2 * s.ngroups * s.state_dim)
                n += nh * 2                             # A_log, D
                n += di                                  # dt bias ~ nh; norm
                n += di * d                              # out_proj
            if fk == FFN_MOE:
                e = self.moe.num_experts + self.moe.num_shared_experts
                n += e * ffn_mats * d * self.d_ff        # (gate/)up/down
                n += d * self.moe.num_experts            # router
            else:
                n += ffn_mats * d * self.d_ff
            n += 2 * d                                   # 2 norms
        n += d                                           # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d, m = self.d_model, self.moe
        fm = 3 if self.ffn_gated else 2
        n_moe_layers = sum(1 for k in self.layer_ffn_kinds() if k == FFN_MOE)
        all_e = (m.num_experts + m.num_shared_experts) * fm * d * self.d_ff
        act_e = (m.top_k + m.num_shared_experts) * fm * d * self.d_ff
        return full - n_moe_layers * (all_e - act_e)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa: F401
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)


def with_sasp(cfg: ModelConfig, **kw) -> ModelConfig:
    return replace(cfg, sasp=replace(cfg.sasp, enabled=True, **kw))


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 128, seq: int = 32) -> ModelConfig:
    """Family-preserving shrink: same structure, tiny dims. Used by the
    per-arch smoke tests; the FULL configs are only ever lowered via the
    dry-run (ShapeDtypeStruct, no allocation)."""
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        vocab_size=vocab,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.num_heads:
        heads = max(2, min(4, cfg.num_heads))
        kvh = max(1, min(cfg.num_kv_heads, heads))
        while heads % kvh:
            kvh -= 1
        kw.update(num_heads=heads, num_kv_heads=kvh, head_dim=d_model // heads)
    if cfg.d_ff:
        kw.update(d_ff=d_model * 2 if cfg.moe is None else d_model)
    if cfg.moe is not None:
        kw.update(moe=replace(cfg.moe, num_experts=4,
                              top_k=min(2, cfg.moe.top_k)))
    if cfg.ssm is not None:
        kw.update(ssm=replace(cfg.ssm, state_dim=16, head_dim=16,
                              chunk_size=16))
    if cfg.sliding_window:
        kw.update(sliding_window=16, local_global_period=min(
            cfg.local_global_period, layers) or 0)
    if cfg.hybrid_attn_period:
        p = min(cfg.hybrid_attn_period, max(2, layers))
        kw.update(hybrid_attn_period=p, hybrid_attn_offset=p - 1,
                  moe_period=cfg.moe_period and 2)
    return replace(cfg, **kw)
