"""Sharded request scheduler with continuous batching (DESIGN.md §11).

The layer above ``serve.engine`` that turns one-process batch inference
into a serving loop with independent request lifetimes:

* **Admission control** — a bounded front queue: ``submit`` rejects
  (returns False) once the waiting backlog exceeds ``max_queue``
  BEYOND current free slot capacity (a burst that free slots will
  absorb on the next step is never shed), so overload sheds new
  traffic instead of growing tail latency without bound.
  Within a rank's queue the admission *policy* orders requests: FCFS
  (arrival order) or SJF (shortest remaining work first — prompt +
  decode budget — which minimizes mean latency under backlog at the
  cost of long-request starvation).
* **Per-DP-rank engine shards** — one :class:`~repro.serve.engine.Engine`
  per DP rank, each owning its OWN slice of the KV-cache slots. Under a
  mesh, rank r's engine is built on the r-th submesh from
  ``distribution.sharding.dp_submeshes`` (the 'data'/'pod' axes collapse
  to size 1, the full 'model' axis is kept), so its params and cache
  slots live on exactly that rank's devices and the TP shard_map packed
  drivers still engage inside the rank. Ranks step independently — a
  rank with an empty queue and free slots costs nothing.
* **Continuous batching** — each engine refills slots freed by EOS or
  budget exhaustion from its queue mid-decode (left-padded re-prefill
  into the freed slot; ``serve/engine.py``), instead of draining the
  whole batch. ``SchedulerConfig(drain=True)`` switches every shard to
  the drain-batch baseline for A/B measurement
  (``benchmarks/bench_engine.py`` throughput-under-load rows).

Routing is least-outstanding-work: a submitted request goes to the rank
whose queue + occupied slots carry the fewest pending tokens (ties to
the lowest rank id). Because slots are isolated bit-exactly (DESIGN.md
§7), the scheduler preserves the engine's contract: every request's
greedy stream is bit-identical to running it alone through a
single-batch engine, regardless of which rank/slot served it or what
traffic it shared the batch with.
"""
from __future__ import annotations

import bisect
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.serve.engine import Engine, Request

POLICIES = ("fcfs", "sjf")


@dataclass
class SchedulerConfig:
    slots_per_rank: int = 4
    cache_len: int = 512
    # reject once this many requests wait beyond free slot capacity
    # (None = unbounded admission)
    max_queue: Optional[int] = None
    policy: str = "fcfs"              # queue order: "fcfs" | "sjf"
    drain: bool = False               # drain-batch baseline (ablation)
    rng_seed: int = 0


class ShardedScheduler:
    """Admission-controlled request queue over per-DP-rank engine shards.

    ``mesh``: build one engine shard per DP rank on its submesh (see
    module docstring). ``ranks``: shard count when meshless (testing /
    single-device DP emulation). ``profile`` is forwarded to each
    engine's sharding rules.
    """

    def __init__(self, params, cfg, *, sched: Optional[SchedulerConfig]
                 = None, mesh=None, ranks: Optional[int] = None,
                 profile: str = "tp"):
        self.sched = sched or SchedulerConfig()
        assert self.sched.policy in POLICIES, self.sched.policy
        if mesh is not None:
            from repro.distribution import sharding as shd
            submeshes = shd.dp_submeshes(mesh, profile)
            if ranks is not None and ranks != len(submeshes):
                raise ValueError(
                    f"ranks={ranks} conflicts with the mesh's "
                    f"{len(submeshes)} DP rank(s) — under a mesh the DP "
                    f"axis decides; omit ranks")
        else:
            submeshes = [None] * (ranks or 1)
        admission = "drain" if self.sched.drain else "continuous"
        self.shards = [
            Engine(params, cfg, batch_slots=self.sched.slots_per_rank,
                   cache_len=self.sched.cache_len,
                   rng_seed=self.sched.rng_seed + r, mesh=sub,
                   profile=profile, admission=admission, rank=r)
            for r, sub in enumerate(submeshes)]
        self.rejected: List[Request] = []
        self.n_submitted = 0
        self.n_accepted = 0

    # ------------------------------------------------------------------
    @property
    def ranks(self) -> int:
        return len(self.shards)

    def queued(self) -> int:
        """Requests admitted but not yet occupying a slot."""
        return sum(len(e.queue) for e in self.shards)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.shards)

    def _route(self, req: Request) -> Engine:
        """Least outstanding work, ties to the lowest rank id."""
        return min(self.shards, key=lambda e: (e.outstanding_tokens(),
                                               e.rank))

    def submit(self, req: Request) -> bool:
        """Admission control + routing. False = rejected (queue full).
        The cap counts WAITING work net of free slots: requests a free
        slot will absorb on the next step are not load."""
        self.n_submitted += 1
        cap = self.sched.max_queue
        if cap is not None:
            free = sum(e.n_free() for e in self.shards)
            if self.queued() - free >= cap:
                self.rejected.append(req)
                return False
        self.n_accepted += 1
        eng = self._route(req)
        index = None
        if self.sched.policy == "sjf":
            # bisect_right: FCFS among equal-cost requests
            index = bisect.bisect_right(
                [q.cost_estimate() for q in eng.queue],
                req.cost_estimate())
        eng.submit(req, index=index)
        return True

    def step(self) -> List[Request]:
        """One decode step on every rank that has work; returns the
        requests retired this step (any rank)."""
        finished: List[Request] = []
        for eng in self.shards:
            if eng.has_work():
                finished.extend(eng.step())
        return finished

    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[float]] = None) -> List[Request]:
        """Serve ``requests`` to completion. ``arrivals`` (seconds from
        start, e.g. Poisson offsets) submits each request when its time
        comes — the open-loop load pattern of the throughput bench;
        omitted, everything is submitted up front. Rejected requests are
        collected on ``self.rejected`` and not waited for."""
        timed = arrivals is not None      # (not truth-tested: numpy ok)
        order = sorted(range(len(requests)),
                       key=lambda i: arrivals[i] if timed else 0.0)
        t0 = time.monotonic()
        done: List[Request] = []
        i = 0
        while i < len(order) or self.has_work():
            now = time.monotonic() - t0
            while i < len(order) and (
                    not timed or arrivals[order[i]] <= now):
                self.submit(requests[order[i]])
                i += 1
            if not self.has_work():
                if i < len(order):      # idle until the next arrival
                    time.sleep(max(0.0, arrivals[order[i]] - now))
                continue
            done.extend(self.step())
        return done

    def stats(self) -> Dict:
        """Per-rank serving counters + global admission counters."""
        return {
            "ranks": self.ranks,
            "submitted": self.n_submitted,
            "accepted": self.n_accepted,
            "rejected": len(self.rejected),
            "per_rank": [dict(e.stats, queue=len(e.queue),
                              free_slots=e.n_free(),
                              slots=e.slot_states())
                         for e in self.shards],
        }
