"""Sharded request scheduler with continuous batching and QoS
(DESIGN.md §11–§12).

The layer above ``serve.engine`` that turns one-process batch inference
into a serving loop with independent request lifetimes:

* **Admission control** — a bounded front queue: ``submit`` rejects
  (returns False) once the waiting backlog exceeds ``max_queue``
  BEYOND current free slot capacity (a burst that free slots will
  absorb on the next step is never shed), so overload sheds new
  traffic instead of growing tail latency without bound.
  Within a rank's queue the admission *policy* orders requests: FCFS
  (arrival order), SJF (shortest remaining work first — prompt +
  decode budget — which minimizes mean latency under backlog at the
  cost of long-request starvation), or EDF (earliest effective
  deadline first — the QoS policy; see below).
* **SLO classes + aging (QoS, DESIGN.md §12)** — each request carries
  an SLO class (``interactive``/``batch``) and a latency target;
  ``submit`` stamps the absolute deadline (request ``deadline`` or the
  class default from ``slo_latency``). Under ``policy="edf"`` queues
  order by *effective* deadline ``t_deadline - aging * wait``: pure
  EDF at ``aging=0``; any ``aging > 0`` drifts a waiting request's key
  earlier relative to fresh arrivals, so neither EDF nor SJF (same
  credit, in tokens) can starve long/late-deadline requests forever.
* **Preemption** — with ``preempt=True``, a rank whose slots are all
  busy and whose best-waiting request is interactive-class with an
  earlier effective deadline than the worst-running batch-class
  request preempts that victim at step granularity
  (``Engine.preempt_slot``): KV snapshot (default, one gather) or
  re-prefill resume — either way the victim's greedy stream stays
  bit-identical across the preempt/resume cycle. ``max_preemptions``
  bounds thrash; victims re-enter the queue and age like everyone
  else. Meaningful under a priority-ordered queue (``edf``/``sjf``).
* **Per-DP-rank engine shards** — one :class:`~repro.serve.engine.Engine`
  per DP rank, each owning its OWN slice of the KV-cache slots. Under a
  mesh, rank r's engine is built on the r-th submesh from
  ``distribution.sharding.dp_submeshes`` (the 'data'/'pod' axes collapse
  to size 1, the full 'model' axis is kept), so its params and cache
  slots live on exactly that rank's devices and the TP shard_map packed
  drivers still engage inside the rank. Ranks step independently — a
  rank with an empty queue and free slots costs nothing.
* **Failure containment** — a rank whose step raises is marked dead:
  its in-flight requests fail (``Request.status == "failed"``, error
  attached, collected on ``scheduler.failed``), its QUEUED requests
  re-route to live ranks, and the serving loop neither deadlocks nor
  re-dispatches to the dead shard.
* **Paged-KV admission (DESIGN.md §13)** — with
  ``SchedulerConfig(kv_pages=…)`` each rank engine backs its slots
  with a shared page pool; the ``max_queue`` cap counts
  ``Engine.admission_capacity()`` (free slots ∩ pool headroom), so a
  rank whose pool is exhausted sheds instead of queueing onto phantom
  free slots, and per-rank ``stats()`` carry the pool's
  ``MemoryStats``. ``shed="deadline"`` evicts the waiting request
  least likely to meet its deadline (batch before interactive) on
  overflow instead of rejecting the newcomer.
  ``revive_rank`` rebuilds a dead shard (fresh caches/page pool) and
  re-admits it to routing; ``prompt_length_histogram`` feeds
  ``tools/suggest_buckets.py``.
* **Continuous batching** — each engine refills slots freed by EOS or
  budget exhaustion from its queue mid-decode (left-padded re-prefill
  into the freed slot; ``serve/engine.py``), instead of draining the
  whole batch. ``SchedulerConfig(drain=True)`` switches every shard to
  the drain-batch baseline for A/B measurement
  (``benchmarks/bench_engine.py`` throughput-under-load rows).
* **Streaming** — ``run(..., on_token=fn)`` calls ``fn(request,
  token)`` the moment each token is sampled on any rank;
  ``stream(requests)`` is the iterator form, yielding ``(rid, token)``
  pairs as decode steps retire. Per-rank bucket tables
  (``SchedulerConfig(buckets=...)``,
  ``distribution.sharding.rank_bucket_tables``) bound the admission
  jit cache under randomized traffic.

Routing is latency-aware least-outstanding-work: batch requests go to
the rank with the fewest pending tokens overall; interactive requests
key on pending INTERACTIVE tokens first (batch backlog on a rank does
not repel interactive traffic — EDF ordering and preemption leapfrog
it), total load as tie-break, ties to the lowest rank id. Because
slots are isolated bit-exactly (DESIGN.md §7), the scheduler preserves
the engine's contract: every request's greedy stream is bit-identical
to running it alone through a single-batch engine, regardless of which
rank/slot served it, what traffic it shared the batch with, or whether
it was preempted and resumed along the way.
"""
from __future__ import annotations

import threading
import time
from collections import Counter
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple

from repro.serve.engine import Engine, Request
from repro.serve.telemetry import Telemetry

POLICIES = ("fcfs", "sjf", "edf")
PREEMPT_MODES = ("kv", "reprefill")
SHED_POLICIES = ("count", "deadline")
# default per-class latency targets (seconds) when a request carries no
# explicit deadline
DEFAULT_SLO_LATENCY = {"interactive": 0.5, "batch": 30.0}


@dataclass
class SchedulerConfig:
    slots_per_rank: int = 4
    cache_len: int = 512
    # reject once this many requests wait beyond free slot capacity
    # (None = unbounded admission)
    max_queue: Optional[int] = None
    policy: str = "fcfs"              # "fcfs" | "sjf" | "edf"
    drain: bool = False               # drain-batch baseline (ablation)
    rng_seed: int = 0
    # --- QoS (DESIGN.md §12) -----------------------------------------
    # anti-starvation credit per second waited, in the policy's native
    # unit (seconds of deadline for edf, tokens of cost for sjf);
    # 0 = pure EDF/SJF
    aging: float = 0.0
    # per-class default latency targets; None = DEFAULT_SLO_LATENCY
    slo_latency: Optional[Dict[str, float]] = None
    preempt: bool = False             # interactive may evict batch
    preempt_mode: str = "kv"          # "kv" snapshot | "reprefill"
    max_preemptions: int = 4          # per-request preemption cap
    preempt_margin: float = 0.0       # required deadline gap (seconds)
    # prefill shape bucketing: an int builds the geometric table per
    # rank (distribution.sharding.rank_bucket_tables); a sequence is an
    # explicit table of lengths; None = exact shapes
    buckets: Optional[object] = None
    # overload shedding once max_queue overflows: "count" rejects the
    # newcomer (PR-4 behavior); "deadline" sheds the waiting request
    # LEAST likely to meet its deadline — batch class before
    # interactive, then smallest slack per unit of remaining work — so
    # interactive SLO attainment holds under overload
    shed: str = "count"
    # --- failure recovery (DESIGN.md §14) -----------------------------
    # a dead rank's IN-FLIGHT requests requeue to live ranks with their
    # emitted-token snapshot armed for an exact re-prefill resume
    # (False = the PR-4 terminal-fail behavior); max_requeues bounds how
    # often one request may survive a rank death before it fails for
    # real (a poison request that kills every rank it lands on must not
    # take the whole tier down with it)
    requeue_inflight: bool = True
    max_requeues: int = 2
    # --- paged KV (DESIGN.md §13) -------------------------------------
    # device pages per rank engine (None = contiguous per-slot rings);
    # page length in tokens (None = tile-aligned default); high-
    # watermark fraction of device pages that may stay resident; host-
    # RAM spill pool size in pages
    kv_pages: Optional[int] = None
    kv_page_len: Optional[int] = None
    kv_watermark: float = 1.0
    kv_host_pages: int = 0
    # --- prefix sharing (DESIGN.md §16) -------------------------------
    # refcounted prefix sharing over the paged pool: admission maps a
    # new prompt's full pages onto already-resident identical pages and
    # prefills only the suffix; min_pages gates how many whole pages
    # must match before sharing is worth the bookkeeping
    kv_share: bool = False
    kv_share_min_pages: int = 1
    # --- speculative decoding (DESIGN.md §17) -------------------------
    # self-speculation over the sparsity ladder: each rank engine packs
    # a drafter from the SAME weights at draft_sparsity (optionally
    # int8) and runs draft-k/verify-1 rounds on greedy requests.
    # Speculation engages for batch-class SLOs only by default (the
    # draft round adds per-step latency variance interactive traffic
    # should not pay); draft_interactive opts interactive in too.
    draft_sparsity: Optional[float] = None
    draft_k: int = 4
    draft_int8: bool = False
    draft_interactive: bool = False
    # periodic cross-request dedup sweep (0 = off; needs kv_share)
    kv_dedup_every: int = 0


class ShardedScheduler:
    """Admission-controlled request queue over per-DP-rank engine shards.

    ``mesh``: build one engine shard per DP rank on its submesh (see
    module docstring). ``ranks``: shard count when meshless (testing /
    single-device DP emulation). ``profile`` is forwarded to each
    engine's sharding rules.
    """

    def __init__(self, params, cfg, *, sched: Optional[SchedulerConfig]
                 = None, mesh=None, ranks: Optional[int] = None,
                 profile: str = "tp",
                 telemetry: Optional[Telemetry] = None):
        # one registry/tracer per scheduler: rank engines share it (the
        # rank label disambiguates), but two schedulers (= two hosts in
        # the cluster frontend) never share counter scopes
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry()
        self.sched = sched or SchedulerConfig()
        assert self.sched.policy in POLICIES, self.sched.policy
        assert self.sched.preempt_mode in PREEMPT_MODES, \
            self.sched.preempt_mode
        assert self.sched.shed in SHED_POLICIES, self.sched.shed
        if mesh is not None:
            from repro.distribution import sharding as shd
            submeshes = shd.dp_submeshes(mesh, profile)
            if ranks is not None and ranks != len(submeshes):
                raise ValueError(
                    f"ranks={ranks} conflicts with the mesh's "
                    f"{len(submeshes)} DP rank(s) — under a mesh the DP "
                    f"axis decides; omit ranks")
        else:
            submeshes = [None] * (ranks or 1)
        self.bucket_tables = self._resolve_buckets(len(submeshes))
        # kept for engine-raise recovery (revive_rank rebuilds a shard)
        self._params = params
        self._cfg = cfg
        self._profile = profile
        self._submeshes = submeshes
        self._sink: Optional[Callable[[Request, int], None]] = None
        self.shards = [self._build_engine(r)
                       for r in range(len(submeshes))]
        # guards the shared mutable state below (counters, terminal
        # lists, histogram) against the cluster frontend's threads —
        # heartbeat/reader threads call submit/step/stats concurrently.
        # Reentrant: step() -> _on_rank_failure() -> submit() re-enters.
        self._lock = threading.RLock()
        self.rejected: List[Request] = []
        self.failed: List[Request] = []
        self.n_submitted = 0
        self.n_accepted = 0
        self.n_shed = 0                 # victims evicted by shed policy
        self.n_revived = 0
        self.n_requeued = 0             # in-flight survivors of a rank death
        # observed prompt-length histogram (tools/suggest_buckets.py
        # fits a bucket table to this — ROADMAP: continuous bucket
        # tuning, first half)
        self.prompt_hist: Counter = Counter()

    def _build_engine(self, r: int) -> Engine:
        s = self.sched
        eng = Engine(self._params, self._cfg,
                     batch_slots=s.slots_per_rank,
                     cache_len=s.cache_len, rng_seed=s.rng_seed + r,
                     mesh=self._submeshes[r], profile=self._profile,
                     admission="drain" if s.drain else "continuous",
                     rank=r, buckets=self.bucket_tables[r],
                     kv_pages=s.kv_pages, kv_page_len=s.kv_page_len,
                     kv_watermark=s.kv_watermark,
                     kv_host_pages=s.kv_host_pages,
                     kv_share=s.kv_share,
                     kv_share_min_pages=s.kv_share_min_pages,
                     draft_sparsity=s.draft_sparsity,
                     draft_k=s.draft_k, draft_int8=s.draft_int8,
                     draft_interactive=s.draft_interactive,
                     kv_dedup_every=s.kv_dedup_every,
                     telemetry=self.telemetry)
        eng.on_token = self._sink
        return eng

    def revive_rank(self, rank: int) -> Engine:
        """Engine-raise recovery (ROADMAP): rebuild a dead rank's engine
        shard — fresh caches/page pool on the same submesh, params
        re-placed — and re-admit it to the routing set. In-flight
        requests the dead shard failed stay failed (already resolved) —
        the frontend replays the retryable ones (DESIGN.md §14); new
        traffic routes to the revived shard immediately. The revived
        engine inherits the dead one's cumulative serving counters
        (plus a bumped ``deaths`` count), so per-rank stats stay
        continuous across the outage instead of resetting to zero."""
        with self._lock:
            old = self.shards[rank]
            if not old.dead:
                raise ValueError(f"rank {rank} is alive — refusing to "
                                 f"rebuild a serving engine shard")
            assert not old.queue, "dead rank still holds queued requests"
            eng = self._build_engine(rank)
            # stats continuity: cumulative counters (incl. the death
            # that took the shard down) carry over; the stale "memory"
            # snapshot does not (the new pool reports its own)
            eng.stats.update({k: v for k, v in old.stats.items()
                              if isinstance(v, int)})
            self.shards[rank] = eng
            self.n_revived += 1
            self.telemetry.tracer.instant("revive_rank", tid=rank)
            return self.shards[rank]

    def _resolve_buckets(self, ranks: int
                         ) -> Tuple[Optional[Tuple[int, ...]], ...]:
        b = self.sched.buckets
        if b is None:
            return (None,) * ranks
        from repro.distribution import sharding as shd
        if isinstance(b, int):
            return shd.rank_bucket_tables(ranks, self.sched.cache_len,
                                          n_buckets=b)
        table = tuple(sorted(int(x) for x in b))
        return (table,) * ranks

    # ------------------------------------------------------------------
    @property
    def ranks(self) -> int:
        return len(self.shards)

    def _live(self) -> List[Engine]:
        return [e for e in self.shards if not e.dead]

    def queued(self) -> int:
        """Requests admitted but not yet occupying a slot."""
        return sum(len(e.queue) for e in self.shards)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self._live())

    def outstanding_tokens(self, slo: Optional[str] = None) -> int:
        """Host-level load: total pending work across live ranks — the
        cluster frontend's routing key (serve/frontend.py)."""
        return sum(e.outstanding_tokens(slo) for e in self._live())

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a request from whichever rank holds it (queued or
        mid-decode), releasing its slot/pages. Status is left to the
        caller — the frontend's watchdog marks it failed, a drain
        hand-off requeues it elsewhere. None if no rank holds ``rid``."""
        with self._lock:
            for e in self.shards:
                req = e.cancel(rid)
                if req is not None:
                    return req
            return None

    def set_on_token(self, fn: Optional[Callable[[Request, int], None]]):
        """Install a streaming sink OUTSIDE run()/stream() — for callers
        (the cluster frontend) that drive step() directly. The sink
        survives rank revives."""
        self._set_sink(fn)

    # -- QoS priorities ------------------------------------------------
    def _slo_target(self, req: Request) -> float:
        if req.deadline is not None:
            return req.deadline
        lat = self.sched.slo_latency or DEFAULT_SLO_LATENCY
        return lat.get(req.slo, DEFAULT_SLO_LATENCY["batch"])

    def _deadline_key(self, req: Request, now: float) -> float:
        """Effective deadline: absolute deadline minus aging credit for
        time already waited. Used by EDF ordering AND the preemption
        test (whatever the queue policy)."""
        sub = req.t_submit if req.t_submit is not None else now
        dl = req.t_deadline if req.t_deadline is not None \
            else sub + self._slo_target(req)
        return dl - self.sched.aging * max(0.0, now - sub)

    def _priority(self, req: Request, now: float) -> float:
        """Queue-ordering key (smaller = sooner) for the active policy."""
        p = self.sched.policy
        if p == "sjf":
            sub = req.t_submit if req.t_submit is not None else now
            return req.cost_estimate() \
                - self.sched.aging * max(0.0, now - sub)
        if p == "edf":
            return self._deadline_key(req, now)
        return req.t_submit if req.t_submit is not None else now

    def _route(self, req: Request) -> Engine:
        """Latency-aware least outstanding work (ties to lowest rank),
        steered by page-pool residency: a paged rank whose headroom
        below the spill watermark cannot cover this request's prefill
        is mid-spill (or one admission away from it) — admitting there
        buys a host-RAM round-trip per cold page, so such ranks lose to
        ANY rank with headroom regardless of queue depth (ROADMAP:
        spill-aware routing). Contiguous ranks have no spill pressure
        and always count as having headroom."""
        live = self._live()
        need = len(req.prompt) + max(0, len(req.out_tokens) - 1)

        def pressed(e: Engine) -> int:
            h = e.route_headroom_tokens()
            return 0 if h is None or h >= need else 1

        if req.slo == "interactive":
            return min(live, key=lambda e: (
                pressed(e), e.outstanding_tokens("interactive"),
                e.outstanding_tokens(), e.rank))
        return min(live, key=lambda e: (pressed(e),
                                        e.outstanding_tokens(), e.rank))

    def submit(self, req: Request) -> bool:
        """Admission control + routing. False = rejected (queue full or
        no live rank). The cap counts WAITING work net of ABSORBABLE
        capacity — free slots, further capped by page-pool headroom on
        paged-KV engines (a free slot with no pages behind it absorbs
        nothing). Under ``shed="deadline"`` an overflow evicts the
        waiting request least likely to meet its deadline instead of
        always rejecting the newcomer."""
        with self._lock:
            self.n_submitted += 1
            self.prompt_hist[len(req.prompt)] += 1
            now = time.monotonic()
            if req.t_submit is None:
                req.t_submit = now
            if req.t_deadline is None:
                req.t_deadline = req.t_submit + self._slo_target(req)
            if not self._live():
                req.status = "failed"
                req.error = "no live engine shards"
                req._kv = None          # release any snapshot memory
                self.failed.append(req)
                return False
            cap = self.sched.max_queue
            if cap is not None:
                free = sum(e.admission_capacity() for e in self._live())
                if self.queued() - free >= cap:
                    victim = req
                    if self.sched.shed == "deadline":
                        victim = self._shed_victim(req, now)
                    if victim is req:
                        req.status = "rejected"
                        self.rejected.append(req)
                        return False
                    # evict the queued victim, admit the newcomer
                    for e in self._live():
                        if victim in e.queue:
                            e.queue.remove(victim)
                            break
                    victim.status = "rejected"
                    victim._kv = None
                    self.rejected.append(victim)
                    self.n_shed += 1
            self.n_accepted += 1
            self._route(req).submit(req)
            return True

    def _shed_victim(self, incoming: Request, now: float) -> Request:
        """Deadline-aware shedding (ROADMAP): among every WAITING
        request (each live rank's queue, plus the newcomer), pick the
        one least likely to meet its deadline — batch class sheds
        before interactive, then smallest slack per unit of remaining
        work (a request that will blow its deadline anyway wastes the
        least SLO value when dropped)."""
        cands = [r for e in self._live() for r in e.queue
                 if r._resume_pos is None]      # never shed mid-decode
        cands.append(incoming)

        def key(r: Request):
            dl = r.t_deadline if r.t_deadline is not None \
                else now + self._slo_target(r)
            slack = dl - now
            return (0 if r.slo == "batch" else 1,
                    slack / max(1, r.cost_estimate()))

        return min(cands, key=key)

    # -- preemption (DESIGN.md §12) ------------------------------------
    def _maybe_preempt(self, eng: Engine, now: float):
        """Evict the worst-running batch-class request when an
        interactive request with a strictly earlier effective deadline
        waits and no slot is free. At most one eviction per rank per
        step; victims re-queue (and re-sort) like fresh arrivals."""
        if not self.sched.preempt or not eng.queue or eng.n_free() > 0:
            return
        head = min(eng.queue, key=lambda r: self._deadline_key(r, now))
        if head.slo != "interactive":
            return
        cands = [(i, r) for i, r in enumerate(eng.slot_req)
                 if r is not None and r.slo == "batch"
                 and r.preemptions < self.sched.max_preemptions]
        if not cands:
            return
        slot, victim = max(cands,
                           key=lambda c: self._deadline_key(c[1], now))
        if (self._deadline_key(head, now) + self.sched.preempt_margin
                < self._deadline_key(victim, now)):
            # the freed slot must go to the triggering head, not to
            # whatever sits at queue[0] under the active policy — move
            # it to the front, and the victim to the back
            i = next(i for i, r in enumerate(eng.queue) if r is head)
            eng.queue.insert(0, eng.queue.pop(i))
            eng.queue.append(eng.preempt_slot(
                slot, keep_kv=self.sched.preempt_mode == "kv"))

    # -- failure containment -------------------------------------------
    def _fail(self, req: Request, error: str):
        req.status = "failed"
        req.error = error
        req.t_done = time.monotonic()
        req._kv = None                  # release any snapshot memory
        self.failed.append(req)

    def _on_rank_failure(self, eng: Engine, err: BaseException
                         ) -> List[Request]:
        """Contain a raising shard. Its QUEUED (not-yet-started)
        requests re-route to live ranks; its IN-FLIGHT requests requeue
        there too with an exact re-prefill resume armed
        (``requeue_inflight``, DESIGN.md §14 — a host death becomes a
        latency blip, not a terminal error), unless a request has
        already survived ``max_requeues`` rank deaths (poison
        containment) or requeueing is disabled — those fail terminally
        with the error attached. Returns requests that had already
        COMPLETED at admission inside the raising step — they are done,
        not casualties."""
        eng.dead = True
        eng.stats["deaths"] += 1
        self.telemetry.tracer.instant(
            "rank_death", tid=eng.rank, error=type(err).__name__)
        done_at_admission = list(eng._finished_at_admission)
        eng._finished_at_admission = []
        requeue, eng.queue = list(eng.queue), []
        if self.sched.requeue_inflight:
            for req in eng.evacuate_inflight():
                req.requeues += 1
                if req.requeues <= self.sched.max_requeues:
                    self.n_requeued += 1
                    requeue.append(req)
                else:
                    self._fail(req, f"rank {eng.rank} died "
                               f"({type(err).__name__}: {err}); "
                               f"{self.sched.max_requeues} requeue(s) "
                               "exhausted")
                    eng.stats["failed"] += 1
        else:
            self.failed.extend(eng.fail_inflight(err))
        live = self._live()
        for req in requeue:
            if live:
                # a KV snapshot taken on the dead rank's caches cannot
                # restore elsewhere — drop it; _resume_pos survives, so
                # the new rank resumes by re-prefill (still bit-exact)
                req._kv = None
                self._route(req).submit(req)
            else:
                self._fail(req, f"rank {eng.rank} died "
                           f"({type(err).__name__}: {err}); "
                           "no live shards to re-route to")
        return done_at_admission

    def step(self) -> List[Request]:
        """One decode step on every live rank that has work; returns the
        requests retired this step (any rank). Applies queue policy
        (re-sorting time-varying priorities) and preemption first."""
        with self._lock:
            finished: List[Request] = []
            now = time.monotonic()
            for eng in self.shards:
                if eng.dead:
                    continue
                try:
                    if self.sched.policy != "fcfs" \
                            and len(eng.queue) > 1:
                        eng.queue.sort(
                            key=lambda r: self._priority(r, now))
                    # inside the containment: the KV snapshot in
                    # preempt_slot is a device op and can raise like a
                    # step
                    self._maybe_preempt(eng, now)
                    if not eng.has_work():
                        continue
                    finished.extend(eng.step())
                except Exception as err:  # noqa: BLE001 — containment
                    finished.extend(self._on_rank_failure(eng, err))
            return finished

    # -- serving loops -------------------------------------------------
    def _set_sink(self, fn: Optional[Callable[[Request, int], None]]):
        self._sink = fn                 # revived shards inherit the sink
        for e in self.shards:
            e.on_token = fn

    def _serve_loop(self, requests: Sequence[Request],
                    arrivals: Optional[Sequence[float]]
                    ) -> Iterator[List[Request]]:
        """Shared arrival/step loop: submits each request when its time
        comes (``arrivals`` in seconds from start, e.g. Poisson offsets;
        omitted = everything up front), yields the requests retired by
        each step. Stops when nothing is pending or every rank died."""
        timed = arrivals is not None      # (not truth-tested: numpy ok)
        order = sorted(range(len(requests)),
                       key=lambda i: arrivals[i] if timed else 0.0)
        t0 = time.monotonic()
        i = 0
        while i < len(order) or self.has_work():
            if not self._live():
                # total failure: the not-yet-submitted arrivals must
                # still resolve — submit routes them to self.failed
                while i < len(order):
                    self.submit(requests[order[i]])
                    i += 1
                return
            now = time.monotonic() - t0
            while i < len(order) and (
                    not timed or arrivals[order[i]] <= now):
                self.submit(requests[order[i]])
                i += 1
            if not self.has_work():
                if i < len(order):      # idle until the next arrival
                    time.sleep(max(0.0, arrivals[order[i]] - now))
                continue
            yield self.step()

    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[float]] = None,
            on_token: Optional[Callable[[Request, int], None]] = None
            ) -> List[Request]:
        """Serve ``requests`` to completion; returns the COMPLETED ones.
        Rejected requests land on ``self.rejected``, failed ones (dead
        rank) on ``self.failed`` — neither is waited for. ``on_token``
        streams every sampled token as ``fn(request, token)``."""
        self._set_sink(on_token)
        try:
            done: List[Request] = []
            for finished in self._serve_loop(requests, arrivals):
                done.extend(finished)
            return done
        finally:
            self._set_sink(None)

    def stream(self, requests: Sequence[Request],
               arrivals: Optional[Sequence[float]] = None
               ) -> Iterator[Tuple[int, int]]:
        """Per-token iterator over the whole sharded serving loop:
        yields ``(rid, token)`` in sampling order as decode steps retire
        across ranks. Completed/rejected/failed requests are found where
        :meth:`run` leaves them (the request objects themselves,
        ``self.rejected``, ``self.failed``)."""
        buf: List[Tuple[int, int]] = []
        self._set_sink(lambda req, tok: buf.append((req.rid, tok)))
        try:
            for _ in self._serve_loop(requests, arrivals):
                while buf:
                    yield buf.pop(0)
        finally:
            self._set_sink(None)

    def prompt_length_histogram(self) -> Dict[int, int]:
        """Observed prompt lengths (all submissions, admitted or not) —
        the input ``tools/suggest_buckets.py`` fits a bucket table to."""
        with self._lock:
            return dict(self.prompt_hist)

    # -- owner methods for frontend bookkeeping ------------------------
    def drain_failed(self) -> List[Request]:
        """Hand terminal failures off to the caller (the cluster
        frontend escalates them into its retry ladder) and clear the
        list — under the scheduler's lock, so a concurrent submit's
        no-live-shards failure is either in this batch or the next,
        never lost."""
        with self._lock:
            out, self.failed[:] = list(self.failed), []
            return out

    def retract_request(self, req: Request) -> bool:
        """Withdraw a non-admitted request's terminal bookkeeping
        (``rejected`` or ``failed``) because the CALLER owns its fate —
        the cluster frontend re-routes or resolves it itself. Returns
        True if the request was found on either list."""
        with self._lock:
            if req in self.rejected:
                self.rejected.remove(req)
                return True
            if req in self.failed:
                self.failed.remove(req)
                return True
            return False

    def stats(self) -> Dict:
        """Per-rank serving counters + global admission/QoS counters.
        Paged-KV ranks carry a ``memory`` dict (MemoryStats)."""
        def rank_stats(e: Engine) -> Dict:
            d = dict(e.stats, queue=len(e.queue),
                     free_slots=e.n_free(),
                     slots=e.slot_states(), dead=e.dead)
            mem = e.memory_stats()
            if mem is not None:
                d["memory"] = mem.as_dict()
            return d

        with self._lock:
            headrooms = [e.route_headroom_tokens()
                         for e in self._live()]
            return {
                "ranks": self.ranks,
                "live_ranks": len(self._live()),
                "submitted": self.n_submitted,
                "accepted": self.n_accepted,
                "rejected": len(self.rejected),
                "shed": self.n_shed,
                "revived": self.n_revived,
                "requeued": self.n_requeued,
                "failed": len(self.failed),
                "prompt_lengths_seen": sum(self.prompt_hist.values()),
                "preemptions": sum(e.stats["preemptions"]
                                   for e in self.shards),
                # host-level aggregates the cluster frontend routes on
                "outstanding_tokens": self.outstanding_tokens(),
                "inflight": sum(e.B - e.n_free()
                                for e in self._live()),
                "headroom_tokens": (None if all(h is None
                                                for h in headrooms)
                                    else sum(h for h in headrooms
                                             if h is not None)),
                # TTFT (t_first - t_submit) quantiles per SLO class,
                # observed by the engines at first-token stamp time
                "ttft": self.telemetry.ttft_stats(),
                "per_rank": [rank_stats(e) for e in self.shards],
            }
