"""Serving telemetry (DESIGN.md §18): one measurement substrate for the
whole serving stack.

Three pieces, one facade:

* :class:`MetricsRegistry` — counters (declare-if-absent, exposed to the
  engine as the backward-compatible :class:`CounterView` mapping that
  ``Engine.stats`` has always looked like), gauges (values or callables,
  sampled at export), and fixed-bucket :class:`Histogram`\\ s whose
  :class:`HistSnapshot`\\ s merge associatively — per-rank snapshots can
  be combined in any order and nearest-rank quantiles read off the
  merged bucket counts. Exports Prometheus text exposition.
* :class:`SpanTracer` — a bounded ring buffer (``deque(maxlen=…)``) of
  host-side events: submit/queue/admit/prefill/preempt/spill/resume/
  draft-verify round/token emission/host death/revive. Timestamps are
  ``time.monotonic()`` taken on the host — the tracer never touches a
  device value, never forces a sync, and never consumes RNG, so greedy
  streams are bit-identical with tracing on or off. Exports Chrome
  trace-event JSON (the ``traceEvents`` array format) loadable in
  Perfetto / ``chrome://tracing``.
* Per-path gauges the ROADMAP waits on: rolling tok/s per execution
  path (dense/masked/bsr/kernel/packed/int8/draft — item 4's
  SLO-conditioned autotuner keys fidelity choices on these) and the
  spec-decode acceptance EMA (item 3's adaptive draft-k input).

Every hook is gated so a disabled tracer costs one attribute check, and
nothing here imports JAX — the analyzer's ``telemetry`` pass imports
this module for :data:`DECLARED_STATS` and must stay device-free.

The shared nearest-rank quantile helpers (:func:`nearest_rank`,
:func:`pcts_ms`) replace the copies that used to live in
``benchmarks/bench_engine.py`` and ``launch/serve.py``.
"""
from __future__ import annotations

import json
import threading
import time
from bisect import bisect_left
from collections import deque
from collections.abc import MutableMapping
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, \
    Tuple

# ---------------------------------------------------------------------------
# declared counter keys (the analyzer's TELEMETRY-DECLARED contract)
# ---------------------------------------------------------------------------

# Every string key incremented/assigned through a ``stats[...]``
# subscript anywhere under ``src/repro/serve/`` must appear here —
# ``tools/analyze/telemetry.py`` fails the CI gate otherwise. This is
# the registry's declaration table: an undeclared key is metric drift
# (a counter nothing exports, or a typo silently splitting a metric).
DECLARED_STATS = frozenset({
    # engine lifecycle
    "decode_steps", "admitted", "continuous_refills",
    "prefill_tokens", "prefill_tokens_skipped", "reprefill_tokens",
    "generated_tokens",
    # preemption / failure containment
    "preemptions", "resumes", "failed", "requeued", "cancelled",
    "deaths",
    # speculative decoding (DESIGN.md §17)
    "spec_rounds", "spec_draft_tokens", "spec_accepted_tokens",
    "spec_fallbacks",
    # non-counter side objects surfaced through the same mapping
    "memory",
})

# execution-path labels for the rolling tok/s gauges
PATH_LABELS = ("dense", "masked", "bsr", "kernel", "packed", "int8",
               "draft")


# ---------------------------------------------------------------------------
# nearest-rank quantiles (shared by benches, launch CLI, histograms)
# ---------------------------------------------------------------------------

def nearest_rank(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank quantile of an ASCENDING-sorted sequence: the value
    at index ``min(n-1, int(n*q))`` — exactly the clamped formula the
    bench/CLI percentile helpers always used, so dedup does not move
    any reported number."""
    n = len(sorted_vals)
    if n == 0:
        raise ValueError("nearest_rank of an empty sequence")
    return sorted_vals[min(n - 1, int(n * q))]


def pcts_ms(lats: Sequence[float]) -> Tuple[float, float]:
    """(p50, p95) in milliseconds from ASCENDING-sorted latencies in
    seconds (nearest-rank, clamped)."""
    return (nearest_rank(lats, 0.5) * 1e3,
            nearest_rank(lats, 0.95) * 1e3)


# ---------------------------------------------------------------------------
# fixed-bucket histograms with mergeable snapshots
# ---------------------------------------------------------------------------

# default TTFT bucket bounds (seconds): log-spaced from 1 ms to 30 s,
# the +inf overflow bucket is implicit
TTFT_BOUNDS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


@dataclass(frozen=True)
class HistSnapshot:
    """Immutable histogram state: per-bucket counts (the last slot is
    the +inf overflow bucket) plus count/sum/min/max. ``merge`` is an
    element-wise add, hence associative AND commutative — per-rank (or
    per-host) snapshots combine in any order to the same result, which
    is what lets scheduler/frontend stats aggregate without a total
    order on when each shard was sampled."""
    bounds: Tuple[float, ...]
    counts: Tuple[int, ...]          # len(bounds) + 1
    count: int
    total: float
    vmin: float                       # +inf when empty
    vmax: float                       # -inf when empty

    @staticmethod
    def empty(bounds: Tuple[float, ...]) -> "HistSnapshot":
        return HistSnapshot(bounds, (0,) * (len(bounds) + 1), 0, 0.0,
                            float("inf"), float("-inf"))

    def merge(self, other: "HistSnapshot") -> "HistSnapshot":
        if self.bounds != other.bounds:
            raise ValueError(
                f"merging histograms with different bucket bounds: "
                f"{self.bounds} vs {other.bounds}")
        return HistSnapshot(
            self.bounds,
            tuple(a + b for a, b in zip(self.counts, other.counts)),
            self.count + other.count, self.total + other.total,
            min(self.vmin, other.vmin), max(self.vmax, other.vmax))

    def quantile(self, q: float) -> Optional[float]:
        """Nearest-rank quantile resolved to a bucket bound: the upper
        bound of the bucket holding rank ``min(n-1, int(n*q))`` (the
        same clamped rank as :func:`nearest_rank`), with the overflow
        bucket answering ``vmax`` (the only exact value it knows).
        None when empty."""
        if self.count == 0:
            return None
        rank = min(self.count - 1, int(self.count * q))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if rank < seen:
                return (self.bounds[i] if i < len(self.bounds)
                        else self.vmax)
        return self.vmax                                # unreachable

    def as_dict(self) -> Dict:
        return {"count": self.count, "total": self.total,
                "min": None if self.count == 0 else self.vmin,
                "max": None if self.count == 0 else self.vmax,
                "p50": self.quantile(0.5), "p95": self.quantile(0.95)}


class Histogram:
    """Fixed-bucket histogram. ``observe`` is a bisect + two adds —
    cheap enough for per-request paths; snapshots are taken under the
    registry lock so a concurrent observe never tears one."""

    def __init__(self, bounds: Sequence[float] = TTFT_BOUNDS_S):
        b = tuple(float(x) for x in bounds)
        if list(b) != sorted(set(b)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing, got {bounds}")
        self.bounds = b
        self._counts = [0] * (len(b) + 1)
        self._count = 0
        self._total = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float) -> None:
        self._counts[bisect_left(self.bounds, v)] += 1
        self._count += 1
        self._total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    def snapshot(self) -> HistSnapshot:
        return HistSnapshot(self.bounds, tuple(self._counts),
                            self._count, self._total, self._min,
                            self._max)


# ---------------------------------------------------------------------------
# rolling rates + EMA (the autotuner-facing gauges)
# ---------------------------------------------------------------------------

class RollingRate:
    """Windowed events/sec: a deque of (monotonic t, n) pairs trimmed
    to the window on read. ``add`` is an append; ``per_s`` divides the
    surviving event mass by the window."""

    def __init__(self, window_s: float = 5.0):
        self.window_s = float(window_s)
        self._events: deque = deque()

    def add(self, n: int, t: Optional[float] = None) -> None:
        if n:
            self._events.append((time.monotonic() if t is None else t,
                                 n))

    def per_s(self, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        horizon = now - self.window_s
        ev = self._events
        while ev and ev[0][0] < horizon:
            ev.popleft()
        return sum(n for _, n in ev) / self.window_s


class Ema:
    """Exponential moving average; ``value`` is None until the first
    update (so a never-speculating engine reports no acceptance rather
    than a fake 0)."""

    def __init__(self, alpha: float = 0.2):
        self.alpha = float(alpha)
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = (x if self.value is None
                      else self.alpha * x
                      + (1.0 - self.alpha) * self.value)
        return self.value


# ---------------------------------------------------------------------------
# counters: the backward-compatible Engine.stats view
# ---------------------------------------------------------------------------

class CounterView(MutableMapping):
    """A registry-backed mapping with the exact surface the ad-hoc
    ``Engine.stats`` dict used to have: ``stats["k"] += 1``,
    ``stats.update(...)``, ``dict(stats, extra=...)``, int values, plus
    the one non-int entry (``stats["memory"]``) routed to an object
    side-store so Prometheus export only sees scalars.

    ``declare`` is declare-IF-ABSENT: re-declaring (a revived rank
    rebuilding its engine against the same scoped view) never zeroes
    counters that survived the outage — ``ShardedScheduler.revive_rank``
    depends on that continuity."""

    def __init__(self, labels: Tuple[Tuple[str, str], ...] = ()):
        self.labels = labels
        self._ints: Dict[str, int] = {}
        self._objs: Dict[str, object] = {}

    def declare(self, keys: Iterable[str]) -> "CounterView":
        for k in keys:
            self._ints.setdefault(k, 0)
        return self

    def __getitem__(self, k):
        if k in self._objs:
            return self._objs[k]
        return self._ints[k]

    def __setitem__(self, k, v):
        if isinstance(v, int) and not isinstance(v, bool):
            self._objs.pop(k, None)
            self._ints[k] = v
        else:
            self._ints.pop(k, None)
            self._objs[k] = v

    def __delitem__(self, k):
        if k in self._objs:
            del self._objs[k]
        else:
            del self._ints[k]

    def __iter__(self):
        yield from self._ints
        yield from self._objs

    def __len__(self):
        return len(self._ints) + len(self._objs)

    def __repr__(self):
        return f"CounterView({dict(self)!r})"

    def int_items(self) -> List[Tuple[str, int]]:
        return list(self._ints.items())


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _labels_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Unified registry: counter scopes, gauges, histograms, and
    export-time collectors; renders Prometheus text exposition. The
    lock guards STRUCTURE (creating scopes/series at declare time and
    snapshotting at export time) — per-event increments on an existing
    CounterView/Histogram are plain dict/list ops under the GIL, which
    keeps the hot path at dictionary-increment cost."""

    def __init__(self):
        self._lock = threading.Lock()
        self._scopes: Dict[Tuple, CounterView] = {}
        self._gauges: Dict[Tuple[str, Tuple], object] = {}
        self._hists: Dict[Tuple[str, Tuple], Histogram] = {}
        self._collectors: Dict[object,
                               Callable[[], Dict[str, float]]] = {}

    # -- counters ------------------------------------------------------
    def counter_scope(self, **labels) -> CounterView:
        """The CounterView for this label set, created on first use and
        RETURNED AGAIN on every later call — a revived rank's rebuilt
        engine re-acquires the same live counters its predecessor
        incremented."""
        key = _labels_key(labels)
        with self._lock:
            if key not in self._scopes:
                self._scopes[key] = CounterView(key)
            return self._scopes[key]

    # -- gauges --------------------------------------------------------
    def gauge(self, name: str, fn_or_value, **labels) -> None:
        """Register a gauge: a number, or a zero-arg callable sampled at
        export time (rolling rates / EMAs export through callables so
        the value is always current)."""
        with self._lock:
            self._gauges[(name, _labels_key(labels))] = fn_or_value

    # -- histograms ----------------------------------------------------
    def histogram(self, name: str,
                  bounds: Sequence[float] = TTFT_BOUNDS_S,
                  **labels) -> Histogram:
        key = (name, _labels_key(labels))
        with self._lock:
            if key not in self._hists:
                self._hists[key] = Histogram(bounds)
            return self._hists[key]

    def histogram_snapshots(self, name: str
                            ) -> Dict[Tuple[Tuple[str, str], ...],
                                      HistSnapshot]:
        with self._lock:
            return {lk: h.snapshot() for (n, lk), h in
                    self._hists.items() if n == name}

    # -- collectors ----------------------------------------------------
    def register_collector(self, fn: Callable[[], Dict[str, float]],
                           key: Optional[object] = None) -> None:
        """``fn() -> {prometheus_line_head: value}`` merged at export —
        the pool/scheduler/frontend attribute counters export through
        these without giving up their lock-checked attributes. A
        ``key`` makes registration idempotent: re-registering (a
        revived rank rebuilding its engine) REPLACES the predecessor's
        collector instead of exporting a dead object forever."""
        with self._lock:
            self._collectors[key if key is not None else object()] = fn

    # -- export --------------------------------------------------------
    def prometheus(self) -> str:
        """Prometheus text exposition of everything registered. Counter
        keys render as ``serve_<key>_total``; gauges and collector
        entries render under their registered names; histograms emit
        the standard ``_bucket``/``_sum``/``_count`` triplet."""
        with self._lock:
            scopes = list(self._scopes.items())
            gauges = list(self._gauges.items())
            hists = [(k, h.snapshot()) for k, h in self._hists.items()]
            collectors = list(self._collectors.values())
        out: List[str] = []
        seen_types = set()

        def head(name: str, kind: str) -> None:
            if name not in seen_types:
                seen_types.add(name)
                out.append(f"# TYPE {name} {kind}")

        for _key, view in scopes:
            for k, v in sorted(view.int_items()):
                name = f"serve_{k}_total"
                head(name, "counter")
                out.append(f"{name}{_fmt_labels(view.labels)} {v}")
        for (name, lk), fv in sorted(gauges):
            v = fv() if callable(fv) else fv
            if v is None:
                continue
            head(name, "gauge")
            out.append(f"{name}{_fmt_labels(lk)} {v}")
        for (name, lk), snap in sorted(hists, key=lambda kv: kv[0]):
            head(name, "histogram")
            cum = 0
            for b, c in zip(snap.bounds, snap.counts):
                cum += c
                out.append(f'{name}_bucket{_fmt_labels(lk + (("le", repr(b)),))} {cum}')
            out.append(f'{name}_bucket{_fmt_labels(lk + (("le", "+Inf"),))} {snap.count}')
            out.append(f"{name}_sum{_fmt_labels(lk)} {snap.total}")
            out.append(f"{name}_count{_fmt_labels(lk)} {snap.count}")
        for fn in collectors:
            for line_head, v in sorted(fn().items()):
                out.append(f"{line_head} {v}")
        return "\n".join(out) + "\n"

    def summary(self) -> Dict[str, object]:
        """Small plain-dict view for periodic console dumps
        (``--metrics-interval``): aggregated counters + sampled
        gauges."""
        with self._lock:
            scopes = list(self._scopes.values())
            gauges = list(self._gauges.items())
        counters: Dict[str, int] = {}
        for view in scopes:
            for k, v in view.int_items():
                counters[k] = counters.get(k, 0) + v
        sampled = {}
        for (name, lk), fv in gauges:
            v = fv() if callable(fv) else fv
            if v is not None:
                sampled[name + _fmt_labels(lk)] = v
        return {"counters": counters, "gauges": sampled}


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

class SpanTracer:
    """Bounded ring buffer of host-side trace events. Disabled (the
    default) every hook returns after ONE attribute check, and ``t0``
    skips the clock read entirely — the hot path stays free. Enabled,
    an event is a clock read + a tuple append into a ``deque(maxlen)``
    (the bound: memory can never grow past ``capacity`` events however
    long the server runs — oldest events fall off).

    Events carry monotonic timestamps only; nothing here reads a device
    value or forces a sync. Export is Chrome trace-event JSON
    (``ph="X"`` complete spans, ``ph="i"`` instants with global scope)
    — load the file in Perfetto (ui.perfetto.dev) or
    ``chrome://tracing``. pid = host, tid = rank, so a cluster run lays
    out as one row per rank grouped by host."""

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.buf: deque = deque(maxlen=self.capacity)
        self.dropped = 0                 # events pushed out of the ring

    # -- hot-path hooks ------------------------------------------------
    def t0(self) -> float:
        """Span start stamp; 0.0 (never read) when disabled."""
        return time.monotonic() if self.enabled else 0.0

    def instant(self, name: str, *, pid: int = 0, tid: int = 0,
                cat: str = "serve", **args) -> None:
        if not self.enabled:
            return
        if len(self.buf) == self.capacity:
            self.dropped += 1
        self.buf.append(("i", name, cat, time.monotonic(), 0.0, pid,
                         tid, args))

    def complete(self, name: str, t0: float, *, pid: int = 0,
                 tid: int = 0, cat: str = "serve", **args) -> None:
        """A ``ph="X"`` span from ``t0`` (a :meth:`t0` stamp) to now."""
        if not self.enabled:
            return
        if len(self.buf) == self.capacity:
            self.dropped += 1
        self.buf.append(("X", name, cat, t0, time.monotonic() - t0,
                         pid, tid, args))

    # -- export --------------------------------------------------------
    def __len__(self) -> int:
        return len(self.buf)

    def events(self) -> List[Dict]:
        """Chrome trace-event dicts (timestamps/durations in µs)."""
        out = []
        for ph, name, cat, ts, dur, pid, tid, args in list(self.buf):
            ev = {"name": name, "ph": ph, "cat": cat,
                  "ts": ts * 1e6, "pid": pid, "tid": tid,
                  "args": dict(args)}
            if ph == "X":
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "g"            # instants: global scope
            out.append(ev)
        return out

    def chrome(self) -> Dict:
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the event count."""
        trace = self.chrome()
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(trace, fh)
        return len(trace["traceEvents"])


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

class Telemetry:
    """One measurement context shared down a serving stack: the
    frontend, its hosts' schedulers, their rank engines, and each
    engine's page pool all hold the SAME Telemetry, so counters land in
    one registry and spans in one ring buffer. An Engine built without
    one creates a private default (tracing off) — solo engines stay
    zero-config."""

    def __init__(self, *, trace: bool = False,
                 trace_capacity: int = 65536,
                 rate_window_s: float = 5.0, ema_alpha: float = 0.2):
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer(capacity=trace_capacity,
                                 enabled=trace)
        self._rates: Dict[str, RollingRate] = {}
        self._rate_window_s = float(rate_window_s)
        self.accept_ema = Ema(alpha=ema_alpha)
        self.registry.gauge("serve_spec_accept_ema",
                            lambda: self.accept_ema.value)

    # -- engine counters -----------------------------------------------
    def engine_stats(self, rank: int = 0) -> CounterView:
        return self.registry.counter_scope(rank=rank)

    # -- per-path throughput gauges ------------------------------------
    def note_tokens(self, path: str, n: int) -> None:
        """Credit ``n`` freshly emitted tokens to an execution path —
        the rolling per-path tok/s gauges the runtime autotuner
        (ROADMAP item 4) consumes."""
        r = self._rates.get(path)
        if r is None:
            r = self._rates[path] = RollingRate(self._rate_window_s)
            self.registry.gauge("serve_path_tok_s",
                                (lambda rr=r: rr.per_s()), path=path)
        r.add(n)

    def tok_s(self, path: str) -> float:
        r = self._rates.get(path)
        return 0.0 if r is None else r.per_s()

    # -- speculative acceptance ----------------------------------------
    def note_spec_round(self, accepted: int, drafted: int) -> None:
        if drafted > 0:
            self.accept_ema.update(accepted / drafted)

    # -- TTFT ----------------------------------------------------------
    def observe_ttft(self, slo: str, seconds: float) -> None:
        self.registry.histogram("serve_ttft_seconds",
                                TTFT_BOUNDS_S, slo=slo) \
            .observe(seconds)

    def ttft_stats(self) -> Dict[str, Dict]:
        """{slo_class: {count, p50_ms, p95_ms}} from the merged TTFT
        histogram snapshots (merge order irrelevant — associative)."""
        return merged_ttft_stats([self])

    # -- convenience ---------------------------------------------------
    def prometheus(self) -> str:
        return self.registry.prometheus()

    def write_trace(self, path: str) -> int:
        return self.tracer.write(path)


def merged_ttft_stats(telemetries: Iterable["Telemetry"]
                      ) -> Dict[str, Dict]:
    """Merge TTFT histograms across any number of Telemetry instances
    (per-host registries in the cluster frontend) into
    ``{slo: {count, p50_ms, p95_ms}}``. Snapshot merge is associative
    and commutative, so host/visit order cannot change the answer."""
    by_slo: Dict[str, HistSnapshot] = {}
    for tel in telemetries:
        snaps = tel.registry.histogram_snapshots("serve_ttft_seconds")
        for lk, snap in snaps.items():
            slo = dict(lk).get("slo", "unknown")
            prev = by_slo.get(slo)
            by_slo[slo] = snap if prev is None else prev.merge(snap)
    out: Dict[str, Dict] = {}
    for slo, snap in by_slo.items():
        p50, p95 = snap.quantile(0.5), snap.quantile(0.95)
        out[slo] = {"count": snap.count,
                    "p50_ms": None if p50 is None else p50 * 1e3,
                    "p95_ms": None if p95 is None else p95 * 1e3}
    return out
