"""Batched serving engine: prefill + greedy/temperature decode over a
fixed ring-cache budget, with slot-based continuous batching.

The engine keeps B slots. Each slot holds one sequence (its own cache
rows — caches are batched pytrees, so slot i is index i of every cache
leaf). Finished sequences free their slot; queued requests prefill into
free slots. Decode steps run over the full batch every iteration (idle
slots are masked). SASP-deployed weights (masked / BSR / kernel /
packed paths) serve through the same code — the paper's tile-skip
savings apply to every decode GEMM.

Serving fast path (DESIGN.md §9):

* **Batched multi-slot prefill** — when several slots free up at once,
  their prompts prefill in ONE left-padded forward pass (per-batch
  positions mask the pad columns out of attention and out of the KV
  cache). Attention-only stacks only; hybrid/SSM stacks fall back to
  per-request prefill (a padded prefix would corrupt the recurrent
  state).
* **Prefill shape bucketing** (DESIGN.md §12) — ``buckets=(…)`` pads
  every admission group to a fixed group size (all B slots; rows for
  slots not being admitted are masked out of the cache scatter) and
  pads the padded length S up to the smallest bucket ≥ S, so the
  jitted admission compiles O(len(buckets)) programs instead of
  O(distinct prompt lengths × group sizes) under diverse traffic.
  Greedy streams are bit-identical to the unbucketed path: extra pad
  columns carry negative positions (masked from attention, written to
  disjoint ring slots with pos = -1) and masked rows rewrite each
  untouched slot's existing cache rows verbatim.
* **On-device sampling** — greedy argmax and temperature sampling
  (``jax.random.categorical``) run inside the jitted decode step, so
  only the sampled token ids (B int32) and done flags cross to the
  host. The full (B, vocab) logits never leave the device.
* **Device-side length/EOS masking** — per-slot remaining-token budgets
  and EOS ids live in device arrays; the decode step returns done flags
  and zeros the sampled token of idle slots.
* **Mesh-native serving** (DESIGN.md §10) — ``Engine(..., mesh=...)``
  places params (incl. TP-sharded packed containers) and KV caches with
  NamedShardings and runs every prefill/decode under the active-mesh
  context, so the shard_map packed drivers and SDPA/TP paths engage.
  Greedy streams are bit-identical to the single-device packed path.

Slot lifecycle (DESIGN.md §11): each slot moves FREE -> PREFILL ->
DECODE -> FREE. PREFILL is transient inside :meth:`Engine._admit` (the
prompt's cache rows are written and the first token sampled in the same
host call); from the next :meth:`Engine.step` on the slot participates
in the batched decode, where the ``active`` mask hides FREE slots —
slots admitted at different times decode side by side. Under
``admission="continuous"`` (default) a slot freed by EOS/budget is
refilled from the queue at the very next step; ``admission="drain"`` is
the classic batch-inference baseline that only admits when EVERY slot
is free (used as the benchmark control for continuous batching).

Preemption (DESIGN.md §12): :meth:`Engine.preempt_slot` moves a
DECODE-state request back to the queue at step granularity. Two resume
modes: ``keep_kv=True`` snapshots the slot's cache rows (one on-device
gather) and resume restores them with one scatter — exact by
construction; ``keep_kv=False`` drops the KV and resume RE-PREFILLS
``prompt + out_tokens[:-1]`` through the normal admission path (no new
token is sampled — the preempted request's last token was already
emitted), trading a prefill pass for cache memory. Either way the
greedy stream across a preempt/resume cycle is bit-identical to an
uninterrupted decode. Requests carry a ``status`` field
(new/queued/running/done/failed/rejected) so schedulers and callers
observe the lifecycle.

Paged KV (DESIGN.md §13): ``Engine(..., kv_pages=N)`` replaces the
per-slot contiguous ring with a shared device page pool plus per-slot
block tables (``serve/memory.py``): decode gathers each slot's pages
into the exact ring layout (streams bit-identical to the contiguous
cache), admission allocates just the prompt's pages and decode grows
by one page at a boundary crossing, EOS frees. Slots become
oversubscribable: admission defers (instead of pinning a full ring)
when the pool is exhausted, preemption unmaps pages instead of copying
a snapshot, and a high-watermark policy spills cold (preempted) pages
to a host-RAM pool, faulting them back on resume.

Streaming: ``Engine.on_token`` (a ``(request, token) -> None`` sink) is
called for every token the moment it is sampled — prefill first tokens
and decode tokens alike; ``Engine.stream(requests)`` wraps it as a
``(rid, token)`` iterator. The sharded scheduler fans the same sink
across its ranks (``serve/scheduler.py``).

One Engine is one *engine shard*: in the sharded scheduler
(``serve/scheduler.py``) each DP rank owns an Engine whose caches —
hence slots — live on that rank's submesh, so ranks serve independent
traffic.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MIXER_ATTN, ModelConfig
from repro.models import lm
from repro.serve.telemetry import Telemetry

ADMISSION_MODES = ("continuous", "drain")
SLO_CLASSES = ("interactive", "batch")
# request lifecycle states surfaced on Request.status
STATUSES = ("new", "queued", "running", "done", "failed", "rejected")


@dataclass(eq=False)                    # identity semantics: a Request
class Request:                          # is a mutable in-flight object
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    eos_id: Optional[int] = None    # stop token (device-side check)
    # QoS (DESIGN.md §12): SLO class + latency target. ``deadline`` is
    # RELATIVE seconds from submission (None = the scheduler's default
    # for the class); the scheduler stamps the absolute ``t_deadline``.
    slo: str = "batch"              # "interactive" | "batch"
    deadline: Optional[float] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    status: str = "new"             # see STATUSES
    error: Optional[str] = None     # set when status == "failed"
    # serving metadata (filled by Engine / ShardedScheduler)
    rank: Optional[int] = None      # engine shard that served the request
    t_submit: Optional[float] = None   # time.monotonic() at submission
    t_first: Optional[float] = None    # first token sampled (prefill)
    t_done: Optional[float] = None     # retired
    t_deadline: Optional[float] = None  # absolute monotonic deadline
    preemptions: int = 0            # times preempted back to the queue
    requeues: int = 0               # times evacuated off a dead rank
    attempts: int = 0               # frontend retry count (serve/frontend)
    # engine-internal resume state (set by preempt_slot)
    _resume_pos: Optional[int] = field(default=None, repr=False)
    _kv: Optional[object] = field(default=None, repr=False)

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-retire seconds (None until both stamps exist)."""
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit

    def cost_estimate(self) -> int:
        """Admission-policy key: total tokens this request still needs
        (prompt prefill + remaining decode budget)."""
        return len(self.prompt) + self.max_new_tokens - len(self.out_tokens)

    def mark_resumable(self):
        """Arm the re-prefill resume path off the emitted-token snapshot
        (``out_tokens`` IS the resumable state — every token the request
        has streamed so far): the next admission re-prefills
        ``prompt + out_tokens[:-1]`` and decode continues the stream
        exactly where it stopped, with no token resampled. Any KV
        snapshot is dropped (it may live on a dead rank's devices).
        No-op for requests with nothing emitted yet — a fresh prefill is
        already exact. Used when a request is moved across engines,
        ranks, or hosts (scheduler requeue-on-failure, frontend retry)."""
        self._kv = None
        self._resume_pos = (len(self.prompt) + len(self.out_tokens) - 1
                            if self.out_tokens else None)


# Engine counter keys, declared (declare-if-absent) into the telemetry
# registry scope for this engine's rank. The analyzer's
# TELEMETRY-DECLARED pass checks every stats[...] write in serve/
# against repro.serve.telemetry.DECLARED_STATS.
_STAT_KEYS = ("decode_steps", "admitted",
              "prefill_tokens", "prefill_tokens_skipped",
              "reprefill_tokens", "generated_tokens",
              "continuous_refills", "preemptions",
              "resumes", "failed", "requeued",
              "cancelled", "deaths",
              "spec_rounds", "spec_draft_tokens",
              "spec_accepted_tokens", "spec_fallbacks")


def _exec_path_label(params, cfg: ModelConfig) -> str:
    """The execution-path label this engine's decode tokens are
    credited to (telemetry per-path tok/s gauges, ROADMAP item 4):
    dense / masked / bsr / kernel / packed / int8. Resolved once at
    construction — a pure host-side walk of the param tree for the
    packed-container markers (``deploy_packed`` sets path="kernel" and
    replaces the BSR overlay with ``sasp_packed``/``sasp_fused``)."""
    s = cfg.sasp
    if not getattr(s, "enabled", False):
        return "dense"
    if getattr(s, "quantize", False):
        return "int8"

    def has_packed(p) -> bool:
        if isinstance(p, dict):
            return ("sasp_packed" in p or "sasp_fused" in p
                    or any(has_packed(v) for v in p.values()))
        if isinstance(p, (list, tuple)):
            return any(has_packed(v) for v in p)
        return False

    if s.path == "kernel" and has_packed(params):
        return "packed"
    return s.path


def _sample_tokens(logits: jnp.ndarray, key, temps: jnp.ndarray
                   ) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32. Greedy where temp <= 0, else
    categorical at logits/temp. Runs on device."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temps, 1e-6)[:, None]
    keys = jax.random.split(key, lg.shape[0])
    samp = jax.vmap(jax.random.categorical)(keys, lg / t).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 cache_len: int = 512, rng_seed: int = 0, mesh=None,
                 profile: str = "tp", admission: str = "continuous",
                 rank: int = 0,
                 buckets: Optional[Sequence[int]] = None,
                 kv_pages: Optional[int] = None,
                 kv_page_len: Optional[int] = None,
                 kv_watermark: float = 1.0,
                 kv_host_pages: int = 0,
                 kv_share: bool = False,
                 kv_share_min_pages: int = 1,
                 draft_sparsity: Optional[float] = None,
                 draft_k: int = 4,
                 draft_int8: bool = False,
                 draft_interactive: bool = False,
                 kv_dedup_every: int = 0,
                 telemetry: Optional[Telemetry] = None):
        assert admission in ADMISSION_MODES, admission
        self.admission = admission
        self.rank = rank
        self.dead = False               # set by the scheduler on a raise
        # telemetry (DESIGN.md §18): counters live in the registry's
        # per-rank scope behind the same mapping surface the ad-hoc
        # stats dict had; declare-if-absent keeps values across a
        # revive_rank rebuild against a shared Telemetry. A private
        # default (tracing off) keeps solo engines zero-config.
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry()
        self._trace = self.telemetry.tracer
        self.stats = self.telemetry.engine_stats(rank) \
            .declare(_STAT_KEYS)
        self.mesh = mesh
        self.profile = profile
        if mesh is not None:
            from repro.distribution import sharding as shd
            psh = shd.param_shardings(cfg, jax.eval_shape(lambda: params),
                                      mesh, profile)
            params = jax.device_put(params, psh)
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.cache_len = cache_len
        # prefill length buckets (sorted, ≤ cache_len); None = exact
        # shapes (the pre-bucketing behavior, bit-identical programs)
        self.buckets: Optional[Tuple[int, ...]] = None
        if buckets:
            bs = tuple(sorted({int(b) for b in buckets}))
            if bs[0] < 1 or bs[-1] > cache_len:
                raise ValueError(
                    f"prefill buckets must lie in [1, cache_len="
                    f"{cache_len}], got {bs} — a bucket beyond the "
                    f"cache can never admit")
            self.buckets = bs
        self._attn_only = all(m == MIXER_ATTN
                              for m in cfg.layer_mixer_kinds())
        # paged KV (DESIGN.md §13): shared page pool + block tables
        # instead of per-slot contiguous rings
        self.pool = None
        if kv_share and not kv_pages:
            raise ValueError(
                "kv_share requires the paged KV pool (kv_pages) — "
                "contiguous rings have no pages to share")
        self.kv_share_min_pages = max(1, int(kv_share_min_pages))
        # rid -> prefix tokens matched at admission (prefill skips them)
        self._shared_tokens: dict = {}
        if kv_pages:
            from repro.serve.memory import PagedKVPool
            self.pool = PagedKVPool(
                params, cfg, cache_len=cache_len,
                device_pages=kv_pages, page_len=kv_page_len,
                watermark=kv_watermark, host_pages=kv_host_pages,
                mesh=mesh, profile=profile, share=kv_share,
                telemetry=self.telemetry)
            self.caches = None
        else:
            self.caches = lm.init_caches(params, cfg, batch_slots,
                                         cache_len)
            if mesh is not None:
                from repro.distribution import sharding as shd
                csh = shd.cache_shardings(
                    cfg, mesh, batch_slots,
                    jax.eval_shape(lambda: self.caches))
                self.caches = jax.device_put(self.caches, csh)
        self.pos = np.zeros((batch_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._finished_at_admission: List[Request] = []
        self.on_token: Optional[Callable[[Request, int], None]] = None
        self._key = jax.random.PRNGKey(rng_seed)
        if self.pool is not None:
            self._decode = jax.jit(partial(
                self._paged_decode_step, cfg, self.pool.NB,
                self.pool.page_len))
            self._prefill = jax.jit(partial(
                self._paged_prefill_write, cfg, cache_len))
            self._prefill_past = jax.jit(partial(
                self._paged_prefill_past_write, cfg))
        else:
            self._decode = jax.jit(partial(self._decode_step, cfg))
            self._prefill = jax.jit(partial(self._prefill_and_write, cfg,
                                            cache_len))
        self._sample = jax.jit(_sample_tokens)
        # preemption resume: one-gather snapshot / one-scatter restore of
        # a slot's cache rows (slot index is traced — no per-slot
        # recompilation). Paged engines unmap pages instead (no copy).
        self._snap = jax.jit(lambda caches, slot: jax.tree.map(
            lambda leaf: leaf[:, slot], caches))
        self._restore = jax.jit(lambda caches, saved, slot: jax.tree.map(
            lambda leaf, s: leaf.at[:, slot].set(s), caches, saved))
        # self-speculative decoding (DESIGN.md §17): the SAME weights
        # re-pruned at a higher sparsity (optionally int8) draft k
        # tokens per round into scratch pages; one full-fidelity verify
        # pass accepts a prefix of them. Greedy exactness never rests
        # on the drafter — every emitted token is a target argmax.
        self.draft_sparsity = draft_sparsity
        self.draft_k = int(draft_k)
        self.draft_interactive = bool(draft_interactive)
        self._draft = None
        if draft_sparsity is not None:
            if self.pool is None:
                raise ValueError(
                    "speculative decoding (draft_sparsity) requires "
                    "the paged KV pool (kv_pages) — draft tokens live "
                    "on scratch pages")
            if getattr(cfg, "kv_quant", False):
                raise ValueError(
                    "speculative decoding is incompatible with "
                    "kv_quant: the verify pass attends fresh fp "
                    "suffix K/V while sequential decode attends "
                    "dequantized int8 entries, breaking the "
                    "bit-identity contract")
            if self.draft_k < 1:
                raise ValueError(f"draft_k={draft_k} must be >= 1")
            if self.draft_k + 1 > cache_len:
                raise ValueError(
                    f"draft_k={draft_k} needs k+1 <= cache_len="
                    f"{cache_len}: a round's write range must fit the "
                    f"ring without self-overlap")
            from repro.core.deploy import draft_pack
            dparams, dcfg = draft_pack(
                self.params, cfg, sparsity=float(draft_sparsity),
                quantize=bool(draft_int8))
            if mesh is not None:
                from repro.distribution import sharding as shd
                dsh = shd.param_shardings(
                    dcfg, jax.eval_shape(lambda: dparams), mesh,
                    profile)
                dparams = jax.device_put(dparams, dsh)
            self._draft = (dparams, dcfg)
            self._draft_decode = jax.jit(partial(
                self._paged_decode_step, dcfg, self.pool.NB,
                self.pool.page_len))
            self._verify = jax.jit(partial(
                self._paged_spec_verify, cfg))
        # opportunistic cross-request dedup (ROADMAP item 1 leftover):
        # re-link identical already-resident pages every N steps
        self.kv_dedup_every = max(0, int(kv_dedup_every))
        if self.kv_dedup_every and (self.pool is None
                                    or not self.pool.share):
            raise ValueError(
                "kv_dedup_every requires the sharing page pool "
                "(kv_pages + kv_share) — without the radix index "
                "there is no content evidence to merge on")
        # per-path tok/s attribution (ROADMAP item 4's autotuner input)
        self.path_label = _exec_path_label(self.params, cfg)
        if self.pool is not None:
            # export-time memory gauges — keyed so a revive_rank
            # rebuild replaces its predecessor's collector instead of
            # exporting a dead pool forever
            self.telemetry.registry.register_collector(
                self._memory_metrics, key=("kv_pool", rank))

    def _memory_metrics(self):
        """Prometheus lines for the page pool's MemoryStats (pure host
        counters — no device sync)."""
        out = {}
        for k, v in self.pool.stats().as_dict().items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f'serve_kv_{k}{{rank="{self.rank}"}}'] = v
        return out

    @staticmethod
    def _prefill_and_write(cfg, cache_len, params, toks, poss, caches,
                           slots, valid):
        """Jitted admission: prompt prefill + scatter of the new cache
        rows into the batch caches at ``slots``, one device program.
        (Admission used to run the forward eagerly — per-op dispatch
        made a single refill cost ~100 decode steps, wiping out the
        continuous-batching win under load.) Only the last-token logits
        (G, V) come back to the host.

        ``valid``: optional (G,) bool mask for the bucketed fixed-shape
        admission — rows where it is False are group padding whose
        scatter must leave the target slot untouched, so their "new"
        rows are replaced by the slot's EXISTING rows before the write
        (``slots`` covers each batch slot exactly once in that mode, so
        the scatter indices stay unique and deterministic)."""
        logits, caches1 = lm.prefill(params, cfg, tokens=toks,
                                     cache_len=cache_len,
                                     positions=poss)

        def put(batch_leaf, new_leaf):
            new_leaf = new_leaf.astype(batch_leaf.dtype)
            if valid is not None:
                keep = batch_leaf[:, slots]
                vm = valid.reshape((1, -1) + (1,) * (new_leaf.ndim - 2))
                new_leaf = jnp.where(vm, new_leaf, keep)
            return batch_leaf.at[:, slots].set(new_leaf)

        return logits[:, 0], jax.tree.map(put, caches, caches1)

    @staticmethod
    def _decode_step(cfg, params, toks, pos, caches, key, temps, active,
                     eos, remaining):
        """One fused decode + sample + retire-check step; only (B,) token
        ids and (B,) done flags leave the device."""
        logits, caches = lm.decode_step(params, cfg, toks, pos, caches)
        key, sub = jax.random.split(key)
        nxt = _sample_tokens(logits[:, 0], sub, temps)
        nxt = jnp.where(active, nxt, 0)
        done = active & ((nxt == eos) | (remaining <= 1))
        return nxt, done, caches, key

    # -- paged-KV twins (DESIGN.md §13) --------------------------------
    @staticmethod
    def _paged_prefill_write(cfg, cache_len, params, toks, poss, data,
                             dests):
        """Jitted paged admission: prompt prefill + scatter of the new
        cache PAGES into the pool at ``dests`` (G, NB) — the trash page
        absorbs unallocated logical pages and admission-group padding
        rows, so no validity mask is needed."""
        from repro.serve import memory as kvmem
        logits, caches1 = lm.prefill(params, cfg, tokens=toks,
                                     cache_len=cache_len,
                                     positions=poss, uniform_cache=True)
        return logits[:, 0], kvmem.scatter_prefill_pages(data, caches1,
                                                         dests)

    @staticmethod
    def _paged_prefill_past_write(cfg, params, toks, poss, data, past_bt,
                                  dests):
        """Jitted suffix-only admission (prefix sharing, DESIGN.md §16):
        gather each request's MATCHED prefix pages into a ring (the
        suffix region reads the zero page — masked emptiness), prefill
        just the suffix against it, scatter the fresh suffix pages.
        ``dests`` maps the shared prefix pages to the trash page, so
        the scatter can never touch a page with refcount > 1."""
        from repro.serve import memory as kvmem
        past = kvmem.gather_block_tables(data, past_bt)
        logits, caches1 = lm.prefill_with_past(params, cfg, toks, poss,
                                               past)
        return logits[:, 0], kvmem.scatter_prefill_pages(data, caches1,
                                                         dests)

    @staticmethod
    def _paged_decode_step(cfg, NB, L, params, toks, pos, data, bt, key,
                           temps, active, eos, remaining):
        """One decode step over the page pool: gather each slot's pages
        into the exact contiguous ring layout, run the unchanged decode
        math, scatter back the one page per slot that was written."""
        from repro.serve import memory as kvmem
        caches = kvmem.gather_block_tables(data, bt)
        logits, caches = lm.decode_step(params, cfg, toks, pos, caches)
        key, sub = jax.random.split(key)
        nxt = _sample_tokens(logits[:, 0], sub, temps)
        nxt = jnp.where(active, nxt, 0)
        done = active & ((nxt == eos) | (remaining <= 1))
        data = kvmem.scatter_written_pages(data, caches, bt, pos, NB, L)
        return nxt, done, data, key

    @staticmethod
    def _paged_spec_verify(cfg, params, toks, poss, data, past_bt,
                           dests):
        """Jitted speculative verify (DESIGN.md §17): ONE full-fidelity
        suffix pass over [x0, d1..dk] (absolute positions P..P+k, pad
        rows all -1) against each slot's REAL pages, returning the
        target's greedy token after EVERY position — t_pred[j] is what
        sequential decode would emit after consuming position P+j. The
        fresh target K/V merges into the round's SCRATCH pages
        (``dests``), pos-masked so pre-range and old-lap entries seeded
        from the real pages survive: whatever is later promoted is
        exact target KV (the drafter's writes are fully overwritten —
        its entries never outlive the round)."""
        from repro.serve import memory as kvmem
        past = kvmem.gather_block_tables(data, past_bt)
        logits, caches1 = lm.prefill_with_past(params, cfg, toks, poss,
                                               past, all_logits=True)
        # same greedy read as _sample_tokens' temp<=0 branch: argmax
        # over f32 logits — bit-identical token selection
        pred = jnp.argmax(logits.astype(jnp.float32),
                          axis=-1).astype(jnp.int32)
        data = kvmem.masked_scatter_pages(data, caches1, dests)
        return pred, data

    # ------------------------------------------------------------------
    def _mesh_ctx(self):
        """Active-mesh scope for every traced/executed model call: the
        shard_map packed drivers and TP/SP paths key off
        ``distribution.context.active_mesh()`` at trace time."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distribution import context as dctx
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(dctx.use_mesh(self.mesh, self.profile))
        return stack

    def submit(self, req: Request):
        """Enqueue a request (FCFS append; a scheduler imposes its own
        queue order by re-sorting before each step)."""
        if req.t_submit is None:
            req.t_submit = time.monotonic()
        req.rank = self.rank
        req.status = "queued"
        self.queue.append(req)
        self._trace.instant("submit", tid=self.rank, rid=req.rid,
                            queue=len(self.queue))

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- scheduler-facing views of the slot state machine --------------
    def slot_states(self) -> List[str]:
        """Per-slot state: 'free' or 'decode' (PREFILL is transient
        inside the same ``step`` that admits — see module docstring)."""
        return ["free" if r is None else "decode" for r in self.slot_req]

    def n_free(self) -> int:
        return len(self._free_slots())

    def admission_capacity(self) -> int:
        """Requests this engine could plausibly admit RIGHT NOW: free
        slots, capped by page-pool headroom when KV is paged — the
        scheduler's admission control consults this instead of raw slot
        count (a free slot with no pages behind it absorbs nothing)."""
        free = self.n_free()
        if self.pool is None:
            return free
        return min(free, self.pool.admissible_requests())

    def memory_stats(self):
        """Paged-KV pool accounting (None when KV is contiguous)."""
        return None if self.pool is None else self.pool.stats()

    def route_headroom_tokens(self) -> Optional[int]:
        """Page-pool residency headroom in TOKENS — how much new cache
        this engine can allocate before the high-watermark policy starts
        spilling cold pages to host RAM. The scheduler's spill-aware
        routing steers traffic away from ranks whose headroom cannot
        cover a request's prefill (they are mid-spill or about to be).
        None for contiguous engines: no paging, no spill pressure.

        *Effective* headroom under prefix sharing: physical residency
        already counts a shared page ONCE however many block tables
        reference it, and rc-0 cached pages are reclaimable without any
        spill (eviction just forgets regenerable prefix KV), so they
        count as headroom too."""
        if self.pool is None:
            return None
        st = self.pool.stats()
        free = max(0, st.watermark - st.device_used) + st.cached_pages
        return free * self.pool.page_len

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None
                                       for r in self.slot_req)

    def outstanding_tokens(self, slo: Optional[str] = None) -> int:
        """Load metric for scheduler routing: queued work (prompt still
        to prefill + decode budget) plus the REMAINING decode budget of
        every occupied slot (their prompts are already prefilled).
        ``slo`` restricts the sum to one SLO class (latency-aware
        routing keys interactive traffic on interactive contention)."""
        return (sum(r.cost_estimate() for r in self.queue
                    if slo is None or r.slo == slo)
                + sum(r.max_new_tokens - len(r.out_tokens)
                      for r in self.slot_req
                      if r is not None and (slo is None or r.slo == slo)))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _emit(self, req: Request, tok: int):
        """Append + stream a freshly sampled token."""
        req.out_tokens.append(tok)
        self._trace.instant("token", tid=self.rank, rid=req.rid)
        if self.on_token is not None:
            self.on_token(req, tok)

    def _sample_host(self, logits, reqs: List[Request]) -> List[int]:
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        toks = self._sample(logits, self._next_key(), temps)
        return [int(t) for t in np.asarray(toks)]

    # -- preemption (DESIGN.md §12) ------------------------------------
    def preempt_slot(self, slot: int, *, keep_kv: bool = True) -> Request:
        """Move the request decoding in ``slot`` back to QUEUED at step
        granularity and free the slot. ``keep_kv=True`` snapshots the
        slot's cache rows for a one-scatter exact resume;
        ``keep_kv=False`` drops them — resume re-prefills
        ``prompt + out_tokens[:-1]`` (the last emitted token becomes the
        next decode input, exactly as if decode had never stopped). The
        caller re-queues the returned request.

        Paged KV (DESIGN.md §13): no data moves — ``keep_kv=True``
        merely UNMAPS the slot (its pages stay allocated, turn cold, and
        may spill to host RAM under memory pressure; resume faults them
        back); ``keep_kv=False`` frees the pages outright."""
        req = self.slot_req[slot]
        assert req is not None, f"preempting free slot {slot}"
        if self.pool is not None:
            if keep_kv:
                self.pool.preempt(req.rid)
            else:
                self.pool.free(req.rid)
        elif keep_kv:
            with self._mesh_ctx():
                req._kv = self._snap(self.caches, slot)
        req._resume_pos = int(self.pos[slot])
        req.preemptions += 1
        req.status = "queued"
        self.slot_req[slot] = None
        self.stats["preemptions"] += 1
        self._trace.instant("preempt", tid=self.rank, rid=req.rid,
                            kept_kv=bool(keep_kv))
        return req

    def _finish_resume(self, slot: int, req: Request):
        req._resume_pos = None
        req._kv = None
        req.status = "running"
        self.slot_req[slot] = req
        self.stats["resumes"] += 1
        self._trace.instant("resume", tid=self.rank, rid=req.rid)

    def _restore_slot(self, slot: int, req: Request):
        """KV-snapshot resume: scatter the saved cache rows back — no
        forward pass, bit-exact by construction."""
        assert self.slot_req[slot] is None, \
            f"resume into occupied slot {slot}"
        self.caches = self._restore(self.caches, req._kv, slot)
        self.pos[slot] = req._resume_pos
        self._finish_resume(slot, req)

    def _attach_paged_resume(self, slot: int, req: Request):
        """Paged resume: the request's pages were just pinned resident
        (host-spilled ones faulted back) — only the block table changes;
        no cache copy at all."""
        assert self.slot_req[slot] is None, \
            f"resume into occupied slot {slot}"
        self.pos[slot] = req._resume_pos
        self._finish_resume(slot, req)

    def _page_keys(self, seq: np.ndarray) -> Tuple[bytes, ...]:
        """Exact-content radix keys: one per FULL page of ``seq`` (the
        partial trailing page is always private — the ISSUE's
        'partial-page boundary re-prefilled into a fresh page').
        Empty when sharing is off or the sequence overflows the ring
        (wrapped pages hold mixed-position content — not indexable)."""
        if self.pool is None or not self.pool.share:
            return ()
        if len(seq) > self.cache_len:
            return ()
        L = self.pool.page_len
        a = np.ascontiguousarray(np.asarray(seq, np.int32))
        return tuple(a[j * L:(j + 1) * L].tobytes()
                     for j in range(len(seq) // L))

    def _paged_reserve(self, req: Request) -> Tuple[bool, str]:
        """Acquire the pages an admission needs. Returns (ok, mode):
        mode 'resume' re-attached a preempted request's live pages
        (skip prefill entirely), 'prefill' allocated pages for a fresh
        prompt or a re-prefill resume (dropped/never-kept pages). Not
        ok = pool exhausted; the caller defers the request.

        Sharing: the prompt's full-page keys walk the radix index and
        matched pages are mapped instead of allocated — capped so at
        least ONE token is always prefilled (the first sampled token
        comes from the suffix forward's last-position logits)."""
        if req._resume_pos is not None and self.pool.has_pages(req.rid):
            return self.pool.resume(req.rid), "resume"
        seq = self._prefill_tokens(req)
        n = self.pool.pages_for(len(seq))
        keys = self._page_keys(seq)
        if keys:
            keys = keys[:(len(seq) - 1) // self.pool.page_len]
        ok, m = self.pool.admit_prefix(
            req.rid, n, keys, min_pages=self.kv_share_min_pages)
        if ok and m:
            self._shared_tokens[req.rid] = m * self.pool.page_len
        return ok, "prefill"

    def _prefill_tokens(self, req: Request) -> np.ndarray:
        """The token sequence admission must prefill: the prompt, or for
        a re-prefill resume the prompt + all generated tokens but the
        last (which is the next decode input)."""
        if req._resume_pos is None:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.out_tokens[:-1], np.int32)])

    # -- prefill bucketing (DESIGN.md §12) -----------------------------
    def _bucket_len(self, S: int) -> int:
        """Smallest bucket ≥ S; exact S when S exceeds every bucket
        (rare tail — one extra program, never a wrong answer)."""
        for b in self.buckets:
            if b >= S:
                return b
        return S

    def _run_prefill(self, toks, poss, all_slots, reqs, valid):
        """Dispatch one jitted admission pass: contiguous engines
        scatter cache ROWS into the batch caches at ``all_slots``
        (``valid`` masks bucketed padding rows); paged engines scatter
        cache PAGES into the pool at each request's allocated pages
        (padding rows write to the trash page — no mask needed).
        Returns the last-token logits (G, V)."""
        t0 = self._trace.t0()
        if self.pool is not None:
            dests = self.pool.dest_table([r.rid for r in reqs],
                                         toks.shape[0])
            logits_last, self.pool.data = self._prefill(
                self.params, toks, poss, self.pool.data,
                jnp.asarray(dests))
        else:
            logits_last, self.caches = self._prefill(
                self.params, toks, poss, self.caches,
                jnp.asarray(np.asarray(all_slots, np.int32)), valid)
        self._trace.complete("prefill", t0, tid=self.rank,
                             rids=[r.rid for r in reqs],
                             rows=int(toks.shape[0]),
                             S=int(toks.shape[1]))
        return logits_last

    def _prefill_into_slot(self, slot: int, req: Request,
                           seq: np.ndarray):
        """Single-sequence prefill; its cache rows are written into the
        batch caches at ``slot``. Fallback path: hybrid/SSM stacks and
        prompts longer than the cache."""
        toks = jnp.asarray(seq[None, :], jnp.int32)
        logits_last = self._run_prefill(toks, None, [slot], [req], None)
        self._register_prompt([req], [seq])
        assert self.slot_req[slot] is None, \
            f"prefill into occupied slot {slot}"
        self.pos[slot] = len(seq)
        if req._resume_pos is not None:
            self._finish_resume(slot, req)
            return
        (nxt,) = self._sample_host(logits_last, [req])
        self._emit(req, nxt)
        req.t_first = time.monotonic()
        self._observe_ttft(req)
        if self._retired_at_admission(req):
            return
        req.status = "running"
        self.slot_req[slot] = req

    def _prefill_group(self, slots: List[int], reqs: List[Request],
                       seqs: List[np.ndarray]):
        """Batched multi-slot prefill: one LEFT-padded forward pass for
        all admitted prompts. Row i of the positions array is
        [-(S-L_i) … -1, 0 … L_i-1]; negative positions are masked out of
        attention and land in the cache with pos = -1, so shorter
        prompts are bit-exact vs solo prefill. With ``buckets`` the
        group is padded to a FIXED shape — all B rows, S rounded up to a
        bucket — and a validity mask keeps the pad rows from touching
        any slot (O(len(buckets)) compiled programs total)."""
        G = len(reqs)
        lens = [len(s) for s in seqs]
        S = max(lens)
        valid = None
        all_slots = list(slots)
        if self.buckets:
            S = self._bucket_len(S)
            all_slots += [i for i in range(self.B) if i not in slots]
            valid = jnp.asarray(np.arange(len(all_slots)) < G)
        Gp = len(all_slots)
        toks = np.zeros((Gp, S), np.int32)
        poss = np.tile(np.arange(S, dtype=np.int32) - S, (Gp, 1))
        for g, seq in enumerate(seqs):
            pad = S - lens[g]
            toks[g, pad:] = seq
            poss[g] = np.arange(S) - pad
        logits_last = self._run_prefill(jnp.asarray(toks),
                                        jnp.asarray(poss), all_slots,
                                        reqs, valid)
        self._register_prompt(reqs, seqs)
        temps = np.zeros((Gp,), np.float32)
        for g, r in enumerate(reqs):
            temps[g] = r.temperature
        sampled = self._sample(logits_last, self._next_key(),
                               jnp.asarray(temps))
        nxts = [int(t) for t in np.asarray(sampled)[:G]]
        now = time.monotonic()
        for slot, req, nxt, L in zip(slots, reqs, nxts, lens):
            assert self.slot_req[slot] is None, \
                f"prefill into occupied slot {slot}"
            self.pos[slot] = L
            if req._resume_pos is not None:
                # re-prefill resume: the sampled token is discarded (the
                # request's last token was emitted before preemption)
                self._finish_resume(slot, req)
                continue
            self._emit(req, nxt)
            req.t_first = now
            self._observe_ttft(req)
            if self._retired_at_admission(req):
                continue
            req.status = "running"
            self.slot_req[slot] = req

    def _observe_ttft(self, req: Request):
        """Aggregate time-to-first-token into the per-SLO-class
        histogram the moment ``t_first`` is stamped (the stamp used to
        be write-only — nothing ever read it back)."""
        if req.t_submit is not None and req.t_first is not None:
            self.telemetry.observe_ttft(req.slo,
                                        req.t_first - req.t_submit)

    def _register_prompt(self, reqs: List[Request],
                         seqs: List[np.ndarray]):
        """Publish freshly prefilled full prompt pages into the radix
        index. Runs right after the prefill pass and BEFORE any
        retire-at-admission free, so even a prompt that EOSes
        immediately seeds the cache (its pages turn cached, not free).
        No-op with sharing off."""
        if self.pool is None or not self.pool.share:
            return
        for r, s in zip(reqs, seqs):
            self.pool.register_prefix(r.rid, self._page_keys(s))

    def _prefill_group_shared(self, slots: List[int],
                              reqs: List[Request],
                              seqs: List[np.ndarray]):
        """Suffix-only batched prefill for admissions whose prompt
        matched shared prefix pages: row g holds ``seq[skip_g:]``
        left-padded, with ABSOLUTE positions (pads carry -1 — masked
        as keys, routed to the sacrificial slot by
        ``build_cache_from_suffix``). The jitted pass gathers each
        row's matched pages as its past ring and scatters only the
        fresh suffix pages back (shared pages are never written)."""
        L = self.pool.page_len
        skips = [self._shared_tokens[r.rid] for r in reqs]
        sufs = [np.asarray(s[m:], np.int32)
                for s, m in zip(seqs, skips)]
        lens = [len(s) for s in sufs]
        G = len(reqs)
        S = max(lens)
        nrows = G
        if self.buckets:
            S = self._bucket_len(S)
            nrows = self.B
        toks = np.zeros((nrows, S), np.int32)
        poss = np.full((nrows, S), -1, np.int32)
        for g, suf in enumerate(sufs):
            pad = S - lens[g]
            toks[g, pad:] = suf
            poss[g, pad:] = np.arange(skips[g], skips[g] + lens[g])
        rids = [r.rid for r in reqs]
        skip_pages = [m // L for m in skips]
        past_bt = self.pool.prefix_table(rids, skip_pages, nrows)
        dests = self.pool.dest_table(rids, nrows,
                                     skip_pages=skip_pages)
        t0 = self._trace.t0()
        logits_last, self.pool.data = self._prefill_past(
            self.params, jnp.asarray(toks), jnp.asarray(poss),
            self.pool.data, jnp.asarray(past_bt), jnp.asarray(dests))
        self._trace.complete("prefill", t0, tid=self.rank, rids=rids,
                             rows=int(nrows), S=int(S), shared=True)
        self._register_prompt(reqs, seqs)
        temps = np.zeros((nrows,), np.float32)
        for g, r in enumerate(reqs):
            temps[g] = r.temperature
        sampled = self._sample(logits_last, self._next_key(),
                               jnp.asarray(temps))
        nxts = [int(t) for t in np.asarray(sampled)[:G]]
        now = time.monotonic()
        for slot, req, nxt, seq in zip(slots, reqs, nxts, seqs):
            assert self.slot_req[slot] is None, \
                f"prefill into occupied slot {slot}"
            self.pos[slot] = len(seq)       # FULL prompt length
            if req._resume_pos is not None:
                self._finish_resume(slot, req)
                continue
            self._emit(req, nxt)
            req.t_first = now
            self._observe_ttft(req)
            if self._retired_at_admission(req):
                continue
            req.status = "running"
            self.slot_req[slot] = req

    def _retired_at_admission(self, req: Request) -> bool:
        """EOS / budget check on the prefill-sampled token: a request can
        finish without ever occupying a decode slot."""
        if ((req.eos_id is not None
             and req.out_tokens[-1] == req.eos_id)
                or len(req.out_tokens) >= req.max_new_tokens):
            req.done = True
            req.status = "done"
            req.t_done = time.monotonic()
            if self.pool is not None:
                self.pool.free(req.rid)
            self._finished_at_admission.append(req)
            return True
        return False

    def _admit(self):
        free = self._free_slots()
        if self.admission == "drain" and len(free) < self.B:
            return                  # drain-batch baseline: wait for all
        take = min(len(free), len(self.queue))
        if not take:
            return
        popped = [self.queue.pop(0) for _ in range(take)]
        slots = free[:take]
        try:
            # KV-snapshot / page resumes restore directly (no forward
            # pass); paged admissions acquire their pages first and
            # DEFER (back to the queue, in order) once the pool is
            # exhausted — slots are oversubscribable, pages are not
            pending = []
            for k, (slot, req) in enumerate(zip(slots, popped)):
                if self.pool is not None:
                    ok, mode = self._paged_reserve(req)
                    if not ok:
                        self.queue[:0] = popped[k:]
                        popped = popped[:k]
                        break
                    if mode == "resume":
                        self._attach_paged_resume(slot, req)
                        continue
                if req._resume_pos is not None and req._kv is not None:
                    self._restore_slot(slot, req)
                else:
                    pending.append((slot, req))
            if len(free) < self.B:  # refill while other slots decode
                self.stats["continuous_refills"] += len(popped)
            self.stats["admitted"] += len(popped)
            if self._trace.enabled:
                for req in popped:
                    self._trace.instant("admit", tid=self.rank,
                                        rid=req.rid)
            if not pending:
                return
            # split sharing admissions (suffix-only prefill through the
            # past-attending jit) from normal ones (unchanged path —
            # trivially bit-identical to sharing off)
            shared, normal = [], []
            for slot, req in pending:
                seq = self._prefill_tokens(req)
                skip = self._shared_tokens.get(req.rid, 0)
                if req._resume_pos is None:
                    self.stats["prefill_tokens"] += len(seq) - skip
                    self.stats["prefill_tokens_skipped"] += skip
                else:
                    # re-prefill resume: the prompt was already counted
                    # (and its shared pages already credited) at first
                    # admission — charging it again double-counts both
                    # stats vs the solo run. The recovery work is its
                    # own counter.
                    self.stats["reprefill_tokens"] += len(seq) - skip
                (shared if skip else normal).append((slot, req, seq))
            if shared:
                self._prefill_group_shared(
                    [s for s, _, _ in shared], [r for _, r, _ in shared],
                    [q for _, _, q in shared])
            if normal:
                slots = [s for s, _, _ in normal]
                reqs = [r for _, r, _ in normal]
                seqs = [q for _, _, q in normal]
                if (self._attn_only
                        and max(len(s) for s in seqs) <= self.cache_len
                        and (len(reqs) > 1 or self.buckets)):
                    self._prefill_group(slots, reqs, seqs)
                else:
                    for slot, req, seq in zip(slots, reqs, seqs):
                        self._prefill_into_slot(slot, req, seq)
            for _, req in pending:
                self._shared_tokens.pop(req.rid, None)
        except BaseException:
            # a raising prefill/restore must not lose the popped
            # requests: everything not yet slotted (or retired at
            # admission) goes back to the queue front, so the
            # scheduler's failure handler can re-route it
            placed = {id(r) for r in self.slot_req if r is not None}
            placed |= {id(r) for r in self._finished_at_admission}
            back = [r for r in popped if id(r) not in placed]
            for r in popped:
                self._shared_tokens.pop(r.rid, None)
            if self.pool is not None:
                # unwind page state: un-prefilled admissions release
                # their pages; unplaced page-holding resumes turn cold
                # again (spillable) instead of leaking resident pages
                for r in back:
                    if not self.pool.has_pages(r.rid):
                        continue
                    if r._resume_pos is not None:
                        self.pool.mark_preempted(r.rid)
                    else:
                        self.pool.free(r.rid)
            self.queue[:0] = back
            raise

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit queued requests, run one decode step, retire finished.
        Returns completed requests."""
        with self._mesh_ctx():
            return self._step_inner()

    def _step_inner(self) -> List[Request]:
        self._admit()

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        # speculative rounds (DESIGN.md §17) claim eligible slots
        # FIRST: their decode writes land on scratch pages, so they
        # skip the write-rule guard below entirely
        specs = self._collect_specs(active) if active else []
        if self.pool is not None and active:
            # decode growth + write rule: the page holding this step's
            # write position must be resident AND writable (rc == 1,
            # unregistered) BEFORE the step — a shared page is
            # copy-on-written here, never scattered to (DESIGN.md §16).
            # A slot that cannot grow/copy (pool exhausted, nothing
            # cold to spill) is preempted with its pages kept — they
            # turn cold, so some other slot's growth (or this one's
            # later resume) can evict them. watermark >= one ring
            # guarantees a lone slot always fits.
            C, L = self.cache_len, self.pool.page_len
            for i in list(active):
                req = self.slot_req[i]
                if not self.pool.ensure_writable(
                        req.rid, (int(self.pos[i]) % C) // L):
                    self.queue.insert(0, self.preempt_slot(i))
                    active.remove(i)
        if not active and not specs:
            finished = self._finished_at_admission
            self._finished_at_admission = []
            if self.pool is not None:
                self.stats["memory"] = self.pool.stats().as_dict()
            return finished
        # requests retired AT admission stay buffered until the decode
        # below succeeds — if it raises, the scheduler's failure handler
        # can still recover them as completed (they are done, not lost)
        finished: List[Request] = []

        if active:
            last = np.zeros((self.B, 1), np.int32)
            temps = np.zeros((self.B,), np.float32)
            act = np.zeros((self.B,), bool)
            eos = np.full((self.B,), -1, np.int64)
            remaining = np.zeros((self.B,), np.int32)
            for i in active:
                req = self.slot_req[i]
                last[i, 0] = req.out_tokens[-1]
                temps[i] = req.temperature
                act[i] = True
                eos[i] = -1 if req.eos_id is None else req.eos_id
                remaining[i] = req.max_new_tokens - len(req.out_tokens)

            if self.pool is not None:
                # speculating slots are masked AND their rows read/write
                # the trash tables — the normal decode never touches
                # their pages this step
                bt = jnp.asarray(self.pool.block_table(
                    [r.rid if (r is not None and i in active) else None
                     for i, r in enumerate(self.slot_req)]))
                nxt, done, self.pool.data, self._key = self._decode(
                    self.params, jnp.asarray(last),
                    jnp.asarray(self.pos, jnp.int32), self.pool.data, bt,
                    self._key, jnp.asarray(temps), jnp.asarray(act),
                    jnp.asarray(eos.astype(np.int32)),
                    jnp.asarray(remaining))
            else:
                nxt, done, self.caches, self._key = self._decode(
                    self.params, jnp.asarray(last),
                    jnp.asarray(self.pos, jnp.int32), self.caches,
                    self._key,
                    jnp.asarray(temps), jnp.asarray(act),
                    jnp.asarray(eos.astype(np.int32)),
                    jnp.asarray(remaining))
            nxt = np.asarray(nxt)               # (B,) int32 — the ONLY
            done = np.asarray(done)             # per-token host traffic
        else:
            # every live slot is speculating: still split the step key
            # once so the RNG key-state stays in lockstep with the
            # non-speculative engine (temperature>0 requests admitted
            # later draw identical randomness either way)
            self._next_key()

        self.stats["decode_steps"] += 1
        self.stats["generated_tokens"] += len(active)
        self.telemetry.note_tokens(self.path_label, len(active))
        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            self._emit(req, int(nxt[i]))
            if bool(done[i]):
                req.done = True
                req.status = "done"
                req.t_done = time.monotonic()
                if self.pool is not None:       # EOS frees the pages
                    self.pool.free(req.rid)
                finished.append(req)
                self.slot_req[i] = None
        if specs:
            finished += self._run_spec_round(specs)
        if (self.kv_dedup_every
                and self.stats["decode_steps"] % self.kv_dedup_every
                == 0):
            self.pool.dedup_sweep()
        finished = self._finished_at_admission + finished
        self._finished_at_admission = []
        if self.pool is not None:
            self.stats["memory"] = self.pool.stats().as_dict()
        return finished

    # -- speculative decoding (DESIGN.md §17) --------------------------
    def _collect_specs(self, active: List[int]
                       ) -> List[Tuple[int, Request, dict]]:
        """Claim the slots that speculate this step (removed from
        ``active``): greedy (temperature 0) requests — batch-class by
        default, interactive only when opted in — with at least two
        tokens of budget left, whose draft round can get its scratch
        pages. Under pool pressure a slot silently decodes the normal
        way this step (never preempted just to speculate)."""
        if self._draft is None:
            return []
        C, L = self.cache_len, self.pool.page_len
        k = self.draft_k
        specs = []
        for i in list(active):
            req = self.slot_req[i]
            if req.temperature > 0:
                continue
            if req.slo == "interactive" and not self.draft_interactive:
                continue
            if req.max_new_tokens - len(req.out_tokens) < 2:
                continue                  # one token left: just decode
            P = int(self.pos[i])
            js = sorted({((P + t) % C) // L for t in range(k + 1)})
            got = self.pool.begin_scratch(req.rid, js)
            if got is None:
                self.stats["spec_fallbacks"] += 1
                continue
            specs.append((i, req, got))
            active.remove(i)
        return specs

    def _run_spec_round(self, specs: List[Tuple[int, Request, dict]]
                        ) -> List[Request]:
        """Draft-k/verify-1 over the claimed slots, batched.

        Draft: k drafter decode steps through a block table whose
        write-range logical pages are swapped to the round's scratch
        pages — the drafter reads the real prefix, its KV lands only on
        scratch. Verify: ONE target pass over [x0, d1..dk] against the
        REAL pages, whose fresh KV overwrites the drafter's entries in
        the same scratch pages (promoted KV is always target KV). With
        a = the longest prefix of drafts matching the target's greedy
        predictions, positions P..P+a were verified exactly as
        sequential decode would have computed them: emit t_pred[0..a]
        (all target argmaxes — a+1 tokens), promote the scratch pages
        fully inside the accepted range, masked-merge the boundary
        page, discard the rest. EOS/budget truncates the emitted run
        and frees everything. On a promotion failure (pool exhausted
        mid-merge) the slot falls back to an exact re-prefill resume —
        rollback is always an unmap, never a copy."""
        from repro.serve.memory import ZERO_PAGE, TRASH_PAGE
        k = self.draft_k
        C, L, NB = self.cache_len, self.pool.page_len, self.pool.NB
        B = self.B
        dparams, _ = self._draft
        finished: List[Request] = []
        t_round = self._trace.t0()
        emitted = 0
        try:
            slot_rids: List[Optional[int]] = [None] * B
            for i, req, _ in specs:
                slot_rids[i] = req.rid
            dbt = self.pool.block_table(slot_rids)
            for i, req, got in specs:
                for j, s in got.items():
                    dbt[i, j] = s
            dbt_j = jnp.asarray(dbt)
            cur = np.zeros((B, 1), np.int32)
            act = np.zeros((B,), bool)
            for i, req, _ in specs:
                cur[i, 0] = req.out_tokens[-1]
                act[i] = True
            pos_d = self.pos.astype(np.int32).copy()
            temps0 = jnp.zeros((B,), jnp.float32)
            eos_none = jnp.full((B,), -1, jnp.int32)
            rem_big = jnp.full((B,), 1 << 30, jnp.int32)
            act_j = jnp.asarray(act)
            dkey = jax.random.PRNGKey(0)  # temp 0: argmax ignores it
            drafts = np.zeros((k, B), np.int32)
            for t in range(k):
                nxt, _, self.pool.data, _ = self._draft_decode(
                    dparams, jnp.asarray(cur),
                    jnp.asarray(pos_d), self.pool.data, dbt_j,
                    dkey, temps0, act_j, eos_none, rem_big)
                drafts[t] = np.asarray(nxt)
                cur = drafts[t].reshape(B, 1)
                pos_d += 1
            toks = np.zeros((B, k + 1), np.int32)
            poss = np.full((B, k + 1), -1, np.int32)
            verify_bt = np.full((B, NB), ZERO_PAGE, np.int32)
            dests = np.full((B, NB), TRASH_PAGE, np.int32)
            for i, req, got in specs:
                P = int(self.pos[i])
                toks[i, 0] = req.out_tokens[-1]
                toks[i, 1:] = drafts[:, i]
                poss[i] = np.arange(P, P + k + 1)
                for j, p in enumerate(
                        self.pool.alloc.dev_pages(req.rid)):
                    if p is not None:
                        verify_bt[i, j] = p
                for j, s in got.items():
                    dests[i, j] = s
            pred, self.pool.data = self._verify(
                self.params, jnp.asarray(toks), jnp.asarray(poss),
                self.pool.data, jnp.asarray(verify_bt),
                jnp.asarray(dests))
            pred = np.asarray(pred)             # (B, k+1) target argmax
            for i, req, got in specs:
                P = int(self.pos[i])
                a = 0
                while a < k and drafts[a, i] == pred[i, a]:
                    a += 1
                self.stats["spec_rounds"] += 1
                self.stats["spec_draft_tokens"] += k
                self.stats["spec_accepted_tokens"] += a
                self.telemetry.note_spec_round(a, k)
                done = False
                for t in range(a + 1):
                    tok = int(pred[i, t])
                    self._emit(req, tok)
                    self.stats["generated_tokens"] += 1
                    emitted += 1
                    if ((req.eos_id is not None and tok == req.eos_id)
                            or len(req.out_tokens)
                            >= req.max_new_tokens):
                        done = True
                        break
                if done:
                    self.pool.discard_scratch(req.rid)
                    req.done = True
                    req.status = "done"
                    req.t_done = time.monotonic()
                    self.pool.free(req.rid)
                    finished.append(req)
                    self.slot_req[i] = None
                    continue
                # not done => all a+1 tokens emitted; keep KV for
                # positions P..P+a. Promotion invariant: a real page
                # never holds entries beyond the slot's last written
                # position — fully-accepted pages swap in (pure
                # bookkeeping), the boundary page masked-merges only
                # the accepted range, rejected pages just unmap.
                hi = P + a
                ok = True
                for j in sorted(got):
                    wj = [p for p in range(P, P + k + 1)
                          if (p % C) // L == j]
                    kj = [p for p in wj if p <= hi]
                    if not kj:
                        continue      # fully rejected: discard below
                    if len(kj) == len(wj):
                        self.pool.promote_scratch(req.rid, j)
                    else:
                        if not self.pool.ensure_writable(req.rid, j):
                            ok = False
                            break
                        dst = self.pool.alloc.dev_pages(req.rid)[j]
                        self.pool.merge_scratch_slots(got[j], dst,
                                                      P, hi)
                self.pool.discard_scratch(req.rid)
                if not ok:
                    # pool exhausted mid-promotion: the emitted tokens
                    # stand; resume re-prefills prompt + out[:-1]
                    # (always exact), releasing every page
                    self.stats["spec_fallbacks"] += 1
                    self.queue.insert(
                        0, self.preempt_slot(i, keep_kv=False))
                    continue
                self.pos[i] = P + a + 1
        finally:
            # containment: a raise mid-round must not leak scratch
            for _, req, _ in specs:
                self.pool.discard_scratch(req.rid)
        if emitted:
            self.telemetry.note_tokens("draft", emitted)
        self._trace.complete("spec_round", t_round, tid=self.rank,
                             slots=len(specs), emitted=emitted)
        return finished

    # -- failure containment (DESIGN.md §12/§14) -----------------------
    def _release_slot(self, slot: int) -> Request:
        """Detach the request occupying ``slot`` (pages/snapshot freed,
        slot back to FREE) WITHOUT deciding its fate — the caller marks
        it failed, requeues it, or cancels it."""
        req = self.slot_req[slot]
        assert req is not None, f"releasing free slot {slot}"
        if self.pool is not None and self.pool.has_pages(req.rid):
            self.pool.free(req.rid)
        self.slot_req[slot] = None
        return req

    def evacuate_inflight(self) -> List[Request]:
        """Pull every in-flight (slot-occupying) request off this engine
        with its emitted-token snapshot armed for an exact re-prefill
        resume elsewhere (:meth:`Request.mark_resumable`). Called by the
        scheduler's requeue-on-failure path when this shard's step
        raised: the evacuated requests re-route to live ranks and their
        greedy streams continue bit-identically."""
        evacuated = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self._release_slot(i)
            req.mark_resumable()
            evacuated.append(req)
        return evacuated

    def fail_inflight(self, err) -> List[Request]:
        """Mark every in-flight (slot-occupying) request failed and free
        its slot. Called by the scheduler when this shard's step raised
        and requeueing is off (or nowhere to requeue to): only the
        requests that were mid-flight on the broken rank fail; queued
        requests are re-routable by the caller."""
        failed = []
        now = time.monotonic()
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self._release_slot(i)
            req.status = "failed"
            req.error = f"{type(err).__name__}: {err}"
            req.t_done = now
            self.stats["failed"] += 1
            failed.append(req)
        return failed

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a request from this engine wherever it sits — waiting
        in the queue or mid-decode in a slot — releasing its pages and
        any KV snapshot. Returns the request (status untouched; the
        caller decides what the cancellation means — watchdog timeout,
        drain expiry, user abort), or None if ``rid`` is not here."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(i)
                if self.pool is not None and self.pool.has_pages(rid):
                    self.pool.free(rid)
                req._kv = None
                self.stats["cancelled"] += 1
                return req
        for i, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                self._release_slot(i)
                req._kv = None
                self.stats["cancelled"] += 1
                return req
        return None

    def run(self, requests: List[Request],
            on_token: Optional[Callable[[Request, int], None]] = None
            ) -> List[Request]:
        prev = self.on_token
        if on_token is not None:
            self.on_token = on_token
        try:
            for r in requests:
                self.submit(r)
            done: List[Request] = []
            while len(done) < len(requests):
                done.extend(self.step())
            return done
        finally:
            self.on_token = prev

    def stream(self, requests: List[Request]
               ) -> Iterator[Tuple[int, int]]:
        """Per-token iterator: yields ``(rid, token)`` in sampling order
        as decode steps retire — same serving semantics as :meth:`run`,
        incremental visibility."""
        buf: List[Tuple[int, int]] = []
        prev = self.on_token
        self.on_token = lambda req, tok: buf.append((req.rid, tok))
        try:
            for r in requests:
                self.submit(r)
            ndone = 0
            while ndone < len(requests):
                ndone += len(self.step())
                while buf:
                    yield buf.pop(0)
        finally:
            self.on_token = prev
