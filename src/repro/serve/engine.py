"""Batched serving engine: prefill + greedy/temperature decode over a
fixed ring-cache budget, with slot-based continuous batching.

The engine keeps B slots. Each slot holds one sequence (its own cache
rows — caches are batched pytrees, so slot i is index i of every cache
leaf). Finished sequences free their slot; queued requests prefill into
free slots. Decode steps run over the full batch every iteration (idle
slots are masked). SASP-deployed weights (masked / BSR / kernel /
packed paths) serve through the same code — the paper's tile-skip
savings apply to every decode GEMM.

Serving fast path (DESIGN.md §9):

* **Batched multi-slot prefill** — when several slots free up at once,
  their prompts prefill in ONE left-padded forward pass (per-batch
  positions mask the pad columns out of attention and out of the KV
  cache). Attention-only stacks only; hybrid/SSM stacks fall back to
  per-request prefill (a padded prefix would corrupt the recurrent
  state).
* **On-device sampling** — greedy argmax and temperature sampling
  (``jax.random.categorical``) run inside the jitted decode step, so
  only the sampled token ids (B int32) and done flags cross to the
  host. The full (B, vocab) logits never leave the device.
* **Device-side length/EOS masking** — per-slot remaining-token budgets
  and EOS ids live in device arrays; the decode step returns done flags
  and zeros the sampled token of idle slots.
* **Mesh-native serving** (DESIGN.md §10) — ``Engine(..., mesh=...)``
  places params (incl. TP-sharded packed containers) and KV caches with
  NamedShardings and runs every prefill/decode under the active-mesh
  context, so the shard_map packed drivers and SDPA/TP paths engage.
  Greedy streams are bit-identical to the single-device packed path.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MIXER_ATTN, ModelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    eos_id: Optional[int] = None    # stop token (device-side check)
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


def _sample_tokens(logits: jnp.ndarray, key, temps: jnp.ndarray
                   ) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32. Greedy where temp <= 0, else
    categorical at logits/temp. Runs on device."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temps, 1e-6)[:, None]
    keys = jax.random.split(key, lg.shape[0])
    samp = jax.vmap(jax.random.categorical)(keys, lg / t).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 cache_len: int = 512, rng_seed: int = 0, mesh=None,
                 profile: str = "tp"):
        self.mesh = mesh
        self.profile = profile
        if mesh is not None:
            from repro.distribution import sharding as shd
            psh = shd.param_shardings(cfg, jax.eval_shape(lambda: params),
                                      mesh, profile)
            params = jax.device_put(params, psh)
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.cache_len = cache_len
        self.caches = lm.init_caches(params, cfg, batch_slots, cache_len)
        if mesh is not None:
            from repro.distribution import sharding as shd
            csh = shd.cache_shardings(
                cfg, mesh, batch_slots,
                jax.eval_shape(lambda: self.caches))
            self.caches = jax.device_put(self.caches, csh)
        self.pos = np.zeros((batch_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._finished_at_admission: List[Request] = []
        self._key = jax.random.PRNGKey(rng_seed)
        self._attn_only = all(m == MIXER_ATTN
                              for m in cfg.layer_mixer_kinds())
        self._decode = jax.jit(partial(self._decode_step, cfg))
        self._sample = jax.jit(_sample_tokens)

    @staticmethod
    def _decode_step(cfg, params, toks, pos, caches, key, temps, active,
                     eos, remaining):
        """One fused decode + sample + retire-check step; only (B,) token
        ids and (B,) done flags leave the device."""
        logits, caches = lm.decode_step(params, cfg, toks, pos, caches)
        key, sub = jax.random.split(key)
        nxt = _sample_tokens(logits[:, 0], sub, temps)
        nxt = jnp.where(active, nxt, 0)
        done = active & ((nxt == eos) | (remaining <= 1))
        return nxt, done, caches, key

    # ------------------------------------------------------------------
    def _mesh_ctx(self):
        """Active-mesh scope for every traced/executed model call: the
        shard_map packed drivers and TP/SP paths key off
        ``distribution.context.active_mesh()`` at trace time."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distribution import context as dctx
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(dctx.use_mesh(self.mesh, self.profile))
        return stack

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample_host(self, logits, reqs: List[Request]) -> List[int]:
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        toks = self._sample(logits, self._next_key(), temps)
        return [int(t) for t in np.asarray(toks)]

    def _prefill_into_slot(self, slot: int, req: Request):
        """Single-sequence prefill; its cache rows are written into the
        batch caches at ``slot``. Fallback path: hybrid/SSM stacks and
        prompts longer than the cache."""
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, caches1 = lm.prefill(self.params, self.cfg, tokens=toks,
                                     cache_len=self.cache_len)

        def put(batch_leaf, one_leaf):
            return batch_leaf.at[:, slot].set(one_leaf[:, 0])

        self.caches = jax.tree.map(put, self.caches, caches1)
        self.pos[slot] = len(req.prompt)
        (nxt,) = self._sample_host(logits[:, 0], [req])
        req.out_tokens.append(nxt)
        if self._retired_at_admission(req):
            return
        self.slot_req[slot] = req

    def _prefill_group(self, slots: List[int], reqs: List[Request]):
        """Batched multi-slot prefill: one LEFT-padded forward pass for
        all admitted prompts. Row i of the positions array is
        [-(S-L_i) … -1, 0 … L_i-1]; negative positions are masked out of
        attention and land in the cache with pos = -1, so shorter
        prompts are bit-exact vs solo prefill."""
        G = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        S = max(lens)
        toks = np.zeros((G, S), np.int32)
        poss = np.zeros((G, S), np.int32)
        for g, r in enumerate(reqs):
            pad = S - lens[g]
            toks[g, pad:] = r.prompt
            poss[g] = np.arange(S) - pad
        logits, caches1 = lm.prefill(
            self.params, self.cfg, tokens=jnp.asarray(toks),
            cache_len=self.cache_len, positions=jnp.asarray(poss))

        sl = jnp.asarray(np.asarray(slots, np.int32))

        def put(batch_leaf, new_leaf):
            return batch_leaf.at[:, sl].set(
                new_leaf.astype(batch_leaf.dtype))

        self.caches = jax.tree.map(put, self.caches, caches1)
        nxts = self._sample_host(logits[:, 0], reqs)
        for slot, req, nxt, L in zip(slots, reqs, nxts, lens):
            self.pos[slot] = L
            req.out_tokens.append(nxt)
            if self._retired_at_admission(req):
                continue
            self.slot_req[slot] = req

    def _retired_at_admission(self, req: Request) -> bool:
        """EOS / budget check on the prefill-sampled token: a request can
        finish without ever occupying a decode slot."""
        if ((req.eos_id is not None
             and req.out_tokens[-1] == req.eos_id)
                or len(req.out_tokens) >= req.max_new_tokens):
            req.done = True
            self._finished_at_admission.append(req)
            return True
        return False

    def _admit(self):
        free = self._free_slots()
        take = min(len(free), len(self.queue))
        if not take:
            return
        reqs = [self.queue.pop(0) for _ in range(take)]
        slots = free[:take]
        if (take > 1 and self._attn_only
                and max(len(r.prompt) for r in reqs) <= self.cache_len):
            self._prefill_group(slots, reqs)
        else:
            for slot, req in zip(slots, reqs):
                self._prefill_into_slot(slot, req)

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit queued requests, run one decode step, retire finished.
        Returns completed requests."""
        with self._mesh_ctx():
            return self._step_inner()

    def _step_inner(self) -> List[Request]:
        self._admit()

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        finished: List[Request] = self._finished_at_admission
        self._finished_at_admission = []
        if not active:
            return finished

        last = np.zeros((self.B, 1), np.int32)
        temps = np.zeros((self.B,), np.float32)
        act = np.zeros((self.B,), bool)
        eos = np.full((self.B,), -1, np.int64)
        remaining = np.zeros((self.B,), np.int32)
        for i in active:
            req = self.slot_req[i]
            last[i, 0] = req.out_tokens[-1]
            temps[i] = req.temperature
            act[i] = True
            eos[i] = -1 if req.eos_id is None else req.eos_id
            remaining[i] = req.max_new_tokens - len(req.out_tokens)

        nxt, done, self.caches, self._key = self._decode(
            self.params, jnp.asarray(last),
            jnp.asarray(self.pos, jnp.int32), self.caches, self._key,
            jnp.asarray(temps), jnp.asarray(act),
            jnp.asarray(eos.astype(np.int32)), jnp.asarray(remaining))
        nxt = np.asarray(nxt)                   # (B,) int32 — the ONLY
        done = np.asarray(done)                 # per-token host traffic

        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            req.out_tokens.append(int(nxt[i]))
            if bool(done[i]):
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        while len(done) < len(requests):
            done.extend(self.step())
        return done
