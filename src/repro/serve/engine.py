"""Batched serving engine: prefill + greedy/temperature decode over a
fixed ring-cache budget, with slot-based continuous batching.

The engine keeps B slots. Each slot holds one sequence (its own cache
rows — caches are batched pytrees, so slot i is index i of every cache
leaf). Finished sequences free their slot; queued requests prefill into
free slots. Decode steps run over the full batch every iteration (idle
slots are masked). SASP-deployed weights (masked / BSR / kernel /
packed paths) serve through the same code — the paper's tile-skip
savings apply to every decode GEMM.

Serving fast path (DESIGN.md §9):

* **Batched multi-slot prefill** — when several slots free up at once,
  their prompts prefill in ONE left-padded forward pass (per-batch
  positions mask the pad columns out of attention and out of the KV
  cache). Attention-only stacks only; hybrid/SSM stacks fall back to
  per-request prefill (a padded prefix would corrupt the recurrent
  state).
* **On-device sampling** — greedy argmax and temperature sampling
  (``jax.random.categorical``) run inside the jitted decode step, so
  only the sampled token ids (B int32) and done flags cross to the
  host. The full (B, vocab) logits never leave the device.
* **Device-side length/EOS masking** — per-slot remaining-token budgets
  and EOS ids live in device arrays; the decode step returns done flags
  and zeros the sampled token of idle slots.
* **Mesh-native serving** (DESIGN.md §10) — ``Engine(..., mesh=...)``
  places params (incl. TP-sharded packed containers) and KV caches with
  NamedShardings and runs every prefill/decode under the active-mesh
  context, so the shard_map packed drivers and SDPA/TP paths engage.
  Greedy streams are bit-identical to the single-device packed path.

Slot lifecycle (DESIGN.md §11): each slot moves FREE -> PREFILL ->
DECODE -> FREE. PREFILL is transient inside :meth:`Engine._admit` (the
prompt's cache rows are written and the first token sampled in the same
host call); from the next :meth:`Engine.step` on the slot participates
in the batched decode, where the ``active`` mask hides FREE slots —
slots admitted at different times decode side by side. Under
``admission="continuous"`` (default) a slot freed by EOS/budget is
refilled from the queue at the very next step; ``admission="drain"`` is
the classic batch-inference baseline that only admits when EVERY slot
is free (used as the benchmark control for continuous batching).

One Engine is one *engine shard*: in the sharded scheduler
(``serve/scheduler.py``) each DP rank owns an Engine whose caches —
hence slots — live on that rank's submesh, so ranks serve independent
traffic.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MIXER_ATTN, ModelConfig
from repro.models import lm

ADMISSION_MODES = ("continuous", "drain")


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    eos_id: Optional[int] = None    # stop token (device-side check)
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # serving metadata (filled by Engine / ShardedScheduler)
    rank: Optional[int] = None      # engine shard that served the request
    t_submit: Optional[float] = None   # time.monotonic() at submission
    t_first: Optional[float] = None    # first token sampled (prefill)
    t_done: Optional[float] = None     # retired

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-retire seconds (None until both stamps exist)."""
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit

    def cost_estimate(self) -> int:
        """Admission-policy key: total tokens this request still needs
        (prompt prefill + remaining decode budget)."""
        return len(self.prompt) + self.max_new_tokens - len(self.out_tokens)


def _sample_tokens(logits: jnp.ndarray, key, temps: jnp.ndarray
                   ) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32. Greedy where temp <= 0, else
    categorical at logits/temp. Runs on device."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temps, 1e-6)[:, None]
    keys = jax.random.split(key, lg.shape[0])
    samp = jax.vmap(jax.random.categorical)(keys, lg / t).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 cache_len: int = 512, rng_seed: int = 0, mesh=None,
                 profile: str = "tp", admission: str = "continuous",
                 rank: int = 0):
        assert admission in ADMISSION_MODES, admission
        self.admission = admission
        self.rank = rank
        self.stats = {"decode_steps": 0, "admitted": 0,
                      "prefill_tokens": 0, "generated_tokens": 0,
                      "continuous_refills": 0}
        self.mesh = mesh
        self.profile = profile
        if mesh is not None:
            from repro.distribution import sharding as shd
            psh = shd.param_shardings(cfg, jax.eval_shape(lambda: params),
                                      mesh, profile)
            params = jax.device_put(params, psh)
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.cache_len = cache_len
        self.caches = lm.init_caches(params, cfg, batch_slots, cache_len)
        if mesh is not None:
            from repro.distribution import sharding as shd
            csh = shd.cache_shardings(
                cfg, mesh, batch_slots,
                jax.eval_shape(lambda: self.caches))
            self.caches = jax.device_put(self.caches, csh)
        self.pos = np.zeros((batch_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._finished_at_admission: List[Request] = []
        self._key = jax.random.PRNGKey(rng_seed)
        self._attn_only = all(m == MIXER_ATTN
                              for m in cfg.layer_mixer_kinds())
        self._decode = jax.jit(partial(self._decode_step, cfg))
        self._prefill = jax.jit(partial(self._prefill_and_write, cfg,
                                        cache_len))
        self._sample = jax.jit(_sample_tokens)

    @staticmethod
    def _prefill_and_write(cfg, cache_len, params, toks, poss, caches,
                           slots):
        """Jitted admission: prompt prefill + scatter of the new cache
        rows into the batch caches at ``slots``, one device program.
        (Admission used to run the forward eagerly — per-op dispatch
        made a single refill cost ~100 decode steps, wiping out the
        continuous-batching win under load.) Only the last-token logits
        (G, V) come back to the host."""
        logits, caches1 = lm.prefill(params, cfg, tokens=toks,
                                     cache_len=cache_len,
                                     positions=poss)

        def put(batch_leaf, new_leaf):
            return batch_leaf.at[:, slots].set(
                new_leaf.astype(batch_leaf.dtype))

        return logits[:, 0], jax.tree.map(put, caches, caches1)

    @staticmethod
    def _decode_step(cfg, params, toks, pos, caches, key, temps, active,
                     eos, remaining):
        """One fused decode + sample + retire-check step; only (B,) token
        ids and (B,) done flags leave the device."""
        logits, caches = lm.decode_step(params, cfg, toks, pos, caches)
        key, sub = jax.random.split(key)
        nxt = _sample_tokens(logits[:, 0], sub, temps)
        nxt = jnp.where(active, nxt, 0)
        done = active & ((nxt == eos) | (remaining <= 1))
        return nxt, done, caches, key

    # ------------------------------------------------------------------
    def _mesh_ctx(self):
        """Active-mesh scope for every traced/executed model call: the
        shard_map packed drivers and TP/SP paths key off
        ``distribution.context.active_mesh()`` at trace time."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distribution import context as dctx
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(dctx.use_mesh(self.mesh, self.profile))
        return stack

    def submit(self, req: Request, index: Optional[int] = None):
        """Enqueue a request. ``index`` lets a scheduler place it by
        admission policy (e.g. SJF); default is FCFS append."""
        if req.t_submit is None:
            req.t_submit = time.monotonic()
        req.rank = self.rank
        if index is None:
            self.queue.append(req)
        else:
            self.queue.insert(index, req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- scheduler-facing views of the slot state machine --------------
    def slot_states(self) -> List[str]:
        """Per-slot state: 'free' or 'decode' (PREFILL is transient
        inside the same ``step`` that admits — see module docstring)."""
        return ["free" if r is None else "decode" for r in self.slot_req]

    def n_free(self) -> int:
        return len(self._free_slots())

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None
                                       for r in self.slot_req)

    def outstanding_tokens(self) -> int:
        """Load metric for scheduler routing: queued work (prompt still
        to prefill + decode budget) plus the REMAINING decode budget of
        every occupied slot (their prompts are already prefilled)."""
        return (sum(r.cost_estimate() for r in self.queue)
                + sum(r.max_new_tokens - len(r.out_tokens)
                      for r in self.slot_req if r is not None))

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample_host(self, logits, reqs: List[Request]) -> List[int]:
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        toks = self._sample(logits, self._next_key(), temps)
        return [int(t) for t in np.asarray(toks)]

    def _prefill_into_slot(self, slot: int, req: Request):
        """Single-sequence prefill; its cache rows are written into the
        batch caches at ``slot``. Fallback path: hybrid/SSM stacks and
        prompts longer than the cache."""
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits_last, self.caches = self._prefill(
            self.params, toks, None, self.caches,
            jnp.asarray([slot], jnp.int32))
        self.pos[slot] = len(req.prompt)
        (nxt,) = self._sample_host(logits_last, [req])
        req.out_tokens.append(nxt)
        req.t_first = time.monotonic()
        if self._retired_at_admission(req):
            return
        self.slot_req[slot] = req

    def _prefill_group(self, slots: List[int], reqs: List[Request]):
        """Batched multi-slot prefill: one LEFT-padded forward pass for
        all admitted prompts. Row i of the positions array is
        [-(S-L_i) … -1, 0 … L_i-1]; negative positions are masked out of
        attention and land in the cache with pos = -1, so shorter
        prompts are bit-exact vs solo prefill."""
        G = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        S = max(lens)
        toks = np.zeros((G, S), np.int32)
        poss = np.zeros((G, S), np.int32)
        for g, r in enumerate(reqs):
            pad = S - lens[g]
            toks[g, pad:] = r.prompt
            poss[g] = np.arange(S) - pad
        logits_last, self.caches = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(poss),
            self.caches, jnp.asarray(np.asarray(slots, np.int32)))
        nxts = self._sample_host(logits_last, reqs)
        now = time.monotonic()
        for slot, req, nxt, L in zip(slots, reqs, nxts, lens):
            self.pos[slot] = L
            req.out_tokens.append(nxt)
            req.t_first = now
            if self._retired_at_admission(req):
                continue
            self.slot_req[slot] = req

    def _retired_at_admission(self, req: Request) -> bool:
        """EOS / budget check on the prefill-sampled token: a request can
        finish without ever occupying a decode slot."""
        if ((req.eos_id is not None
             and req.out_tokens[-1] == req.eos_id)
                or len(req.out_tokens) >= req.max_new_tokens):
            req.done = True
            req.t_done = time.monotonic()
            self._finished_at_admission.append(req)
            return True
        return False

    def _admit(self):
        free = self._free_slots()
        if self.admission == "drain" and len(free) < self.B:
            return                  # drain-batch baseline: wait for all
        take = min(len(free), len(self.queue))
        if not take:
            return
        if len(free) < self.B:      # refill while other slots decode
            self.stats["continuous_refills"] += take
        reqs = [self.queue.pop(0) for _ in range(take)]
        slots = free[:take]
        self.stats["admitted"] += take
        self.stats["prefill_tokens"] += sum(len(r.prompt) for r in reqs)
        if (take > 1 and self._attn_only
                and max(len(r.prompt) for r in reqs) <= self.cache_len):
            self._prefill_group(slots, reqs)
        else:
            for slot, req in zip(slots, reqs):
                self._prefill_into_slot(slot, req)

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit queued requests, run one decode step, retire finished.
        Returns completed requests."""
        with self._mesh_ctx():
            return self._step_inner()

    def _step_inner(self) -> List[Request]:
        self._admit()

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        finished: List[Request] = self._finished_at_admission
        self._finished_at_admission = []
        if not active:
            return finished

        last = np.zeros((self.B, 1), np.int32)
        temps = np.zeros((self.B,), np.float32)
        act = np.zeros((self.B,), bool)
        eos = np.full((self.B,), -1, np.int64)
        remaining = np.zeros((self.B,), np.int32)
        for i in active:
            req = self.slot_req[i]
            last[i, 0] = req.out_tokens[-1]
            temps[i] = req.temperature
            act[i] = True
            eos[i] = -1 if req.eos_id is None else req.eos_id
            remaining[i] = req.max_new_tokens - len(req.out_tokens)

        nxt, done, self.caches, self._key = self._decode(
            self.params, jnp.asarray(last),
            jnp.asarray(self.pos, jnp.int32), self.caches, self._key,
            jnp.asarray(temps), jnp.asarray(act),
            jnp.asarray(eos.astype(np.int32)), jnp.asarray(remaining))
        nxt = np.asarray(nxt)                   # (B,) int32 — the ONLY
        done = np.asarray(done)                 # per-token host traffic

        self.stats["decode_steps"] += 1
        self.stats["generated_tokens"] += len(active)
        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            req.out_tokens.append(int(nxt[i]))
            if bool(done[i]):
                req.done = True
                req.t_done = time.monotonic()
                finished.append(req)
                self.slot_req[i] = None
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        while len(done) < len(requests):
            done.extend(self.step())
        return done
