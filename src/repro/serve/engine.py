"""Batched serving engine: prefill + greedy/temperature decode over a
fixed ring-cache budget, with slot-based continuous batching.

The engine keeps B slots. Each slot holds one sequence (its own cache
rows — caches are batched pytrees, so slot i is index i of every cache
leaf). Finished sequences free their slot; queued requests prefill into
free slots. Decode steps run over the full batch every iteration (idle
slots are masked). SASP-deployed weights (masked / BSR / kernel paths)
serve through the same code — the paper's tile-skip savings apply to
every decode GEMM.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 cache_len: int = 512, rng_seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.cache_len = cache_len
        self.caches = lm.init_caches(params, cfg, batch_slots, cache_len)
        self.pos = np.zeros((batch_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.rng = np.random.default_rng(rng_seed)
        self._decode = jax.jit(
            lambda p, t, pos, c: lm.decode_step(p, cfg, t, pos, c))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _prefill_into_slot(self, slot: int, req: Request):
        """Single-sequence prefill; its cache rows are written into the
        batch caches at ``slot``."""
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, caches1 = lm.prefill(self.params, self.cfg, tokens=toks,
                                     cache_len=self.cache_len)

        def put(batch_leaf, one_leaf):
            return batch_leaf.at[:, slot].set(one_leaf[:, 0])

        self.caches = jax.tree.map(put, self.caches, caches1)
        self.pos[slot] = len(req.prompt)
        nxt = self._sample(np.asarray(logits)[0, 0], req)
        req.out_tokens.append(int(nxt))
        self.slot_req[slot] = req

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / req.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Admit queued requests, run one decode step, retire finished.
        Returns completed requests."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._prefill_into_slot(slot, self.queue.pop(0))

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        finished: List[Request] = []
        if not active:
            return finished

        last = np.zeros((self.B, 1), np.int32)
        for i in active:
            last[i, 0] = self.slot_req[i].out_tokens[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(last),
            jnp.asarray(self.pos, jnp.int32), self.caches)
        logits = np.asarray(logits)

        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            nxt = self._sample(logits[i, 0], req)
            req.out_tokens.append(nxt)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        while len(done) < len(requests):
            done.extend(self.step())
        return done
