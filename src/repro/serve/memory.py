"""Paged KV-cache memory subsystem (DESIGN.md §13).

The contiguous serving engine pins a full ``cache_len`` ring of KV
memory per slot for the slot's whole lifetime — queued, preempted,
half-empty, it all costs the same. This module converts KV memory into
a *scheduled* resource: a shared device **page pool** plus per-slot
**block tables**, with the page size tile-aligned to the SASP pruning
block (the same granularity the systolic-array tile-skip kernels use —
the paper's co-design move applied to memory instead of FLOPs).

Layout. One *page* holds ``page_len`` consecutive ring positions of
every attention layer at once (all scan repeats, all segment slots) —
pool leaves are ``(R, P, page_len, …)``, built by
``models.lm.init_caches(..., uniform_cap=True)``. A slot's logical ring
of ``cache_len = NB · page_len`` tokens is assembled by a jitted
block-table gather (``models.attention.gather_kv_pages``), which is
bit-identical to the contiguous ring, so prefill/decode math runs
unchanged and greedy streams match the unpaged engine exactly.

Two physical pages are reserved:

* ``ZERO_PAGE`` — all zeros, ``pos = -1`` everywhere; unallocated
  logical pages point here for READS (masked out of attention, same
  content as an unwritten ring region). Never a write target.
* ``TRASH_PAGE`` — the write target for idle batch rows and
  admission-group padding; never read by a live slot.

Policy. Pages are allocated on admission growth (``pages_for`` the
prompt, then one page each time decode crosses a page boundary) and
freed on EOS/failure. A high-watermark cap bounds resident device
pages; when an allocation would cross it, *cold* pages spill to a
host-RAM pool — preempted requests first, longest-idle first — via a
``jax.device_put``/``device_get`` round-trip, and fault back on resume.
When the host pool is also full, the coldest preempted request's pages
are **dropped** and it falls back to re-prefill resume (still exact —
the same fallback PR 4 uses for cross-rank resume). ``MemoryStats``
(device/host pages, spills, faults, drops, residency) is surfaced
through ``Engine.stats["memory"]`` and the scheduler's per-rank stats.

Prefix sharing (DESIGN.md §16). With ``share=True`` every physical page
carries a refcount and FULL prompt pages are registered in a radix
index — a trie keyed by the page's exact token bytes, so a node's depth
pins the absolute position range and two pages share a trie path iff
their whole token prefix matches (content addressing with no hash
collisions). ``admit_prefix`` walks a new prompt's page keys down the
trie and maps every hit onto the already-resident page (refcount++)
instead of allocating, returning how many prefix pages the engine's
prefill can skip; the partial trailing page is always private. Page
lifecycle becomes free / **owned** (rc ≥ 1 — exactly the number of
block-table references) / **cached** (rc == 0 but still registered:
a freed prompt's pages stay matchable until evicted, LRU). The write
rule: a page may be scattered to only while rc == 1 AND unregistered —
decode copy-on-writes a shared page before the step and unregisters a
private-but-registered one; room-making evicts cached pages first
(free to regenerate), then spills preempted requests' *private* pages
(shared pages never spill — a co-owner may be mid-decode), then drops.

Bookkeeping and data movement are split: :class:`PageAllocator` is a
pure host-side state machine (property-tested with hypothesis in
``tests/test_memory.py``) that returns *moves*; :class:`PagedKVPool`
owns the arrays and executes the moves.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import MIXER_ATTN, ModelConfig
from repro.models import attention as attn_mod
from repro.models import lm
from repro.serve.telemetry import SpanTracer, Telemetry

ZERO_PAGE = 0
TRASH_PAGE = 1
RESERVED_PAGES = 2


def systolic_tile(cfg: ModelConfig) -> int:
    """The tile the page size must align to: the SASP pruning block
    (paper: the systolic-array dimension) when SASP is deployed, else 1
    (no tiling constraint to compose with)."""
    if cfg.sasp.enabled:
        return max(int(cfg.sasp.block_k), int(cfg.sasp.block_n))
    return 1


def tile_aligned_page_len(cfg: ModelConfig, cache_len: int,
                          page_len: Optional[int] = None) -> int:
    """Resolve the page length in tokens: a multiple of the systolic
    tile that divides ``cache_len`` (so NB = cache_len / page_len is
    whole and paging granularity composes with the packed-kernel
    tiling). Default: one tile when SASP is deployed (clamped to the
    cache), else cache_len / 8-ish."""
    tile = systolic_tile(cfg)
    if page_len is None:
        page_len = min(tile, cache_len) if cfg.sasp.enabled \
            else max(1, cache_len // 8)
        # grow to the nearest divisor of cache_len (tile already divides
        # cache_len or we fail below with the explicit-arg message)
        while cache_len % page_len:
            page_len += 1
    page_len = int(page_len)
    if page_len < 1 or page_len > cache_len:
        raise ValueError(
            f"kv page_len={page_len} must lie in [1, cache_len="
            f"{cache_len}]")
    if page_len % tile:
        raise ValueError(
            f"kv page_len={page_len} must be a multiple of the SASP "
            f"tile {tile} (block_k/block_n) so paging granularity "
            f"composes with the packed-kernel tiling")
    if cache_len % page_len:
        raise ValueError(
            f"cache_len={cache_len} must be a multiple of kv "
            f"page_len={page_len} (whole pages per ring)")
    return page_len


@dataclass
class MemoryStats:
    """Per-pool accounting, surfaced through ``Engine.stats['memory']``
    and ``ShardedScheduler.stats()['per_rank']``."""
    device_pages: int        # allocatable device pages (excl. reserved)
    host_pages: int          # host-RAM spill pool capacity
    watermark: int           # resident-page cap (high-watermark policy)
    device_used: int
    host_used: int
    preempted_resident: int  # device pages pinned by preempted requests
    spills: int              # pages spilled device -> host (cumulative)
    faults: int              # pages faulted host -> device (cumulative)
    drops: int               # preempted requests dropped to re-prefill
    # prefix sharing (DESIGN.md §16) — all zero when share is off
    shared_pages: int = 0    # physical pages with refcount > 1
    cached_pages: int = 0    # rc == 0 pages retained in the radix index
    prefix_hits: int = 0     # admissions that matched >= 1 prefix page
    prefix_pages_reused: int = 0  # pages mapped instead of allocated
    cow_copies: int = 0      # shared pages copied before a write
    cache_evictions: int = 0  # cached pages reclaimed by room-making
    # speculative decoding (DESIGN.md §17) / cross-request dedup
    scratch_pages: int = 0   # pages held by in-flight draft rounds
    dedup_merges: int = 0    # resident duplicate pages re-linked

    @property
    def device_free(self) -> int:
        return self.device_pages - self.device_used

    @property
    def residency(self) -> float:
        """Fraction of the device pool resident."""
        return self.device_used / max(1, self.device_pages)

    def as_dict(self) -> Dict:
        import dataclasses
        return dict(dataclasses.asdict(self),
                    device_free=self.device_free,
                    residency=round(self.residency, 4))


# page-table entries: ("dev", page_id) | ("host", host_slot) | None
_Move = Tuple  # ("spill", rid, j, dev, host) | ("fault", rid, j, host, dev)


class _RadixNode:
    """One full page of prompt tokens in the prefix index. Children are
    keyed by the NEXT page's exact token bytes; depth pins the absolute
    position range, so equal keys at equal depth == equal whole prefix.
    ``page`` is the resident device page holding this node's KV (None =
    evicted hole; a prefix walk stops there — descendants are
    unreachable until re-registered, which keeps matches contiguous)."""

    __slots__ = ("children", "page")

    def __init__(self):
        self.children: Dict[bytes, "_RadixNode"] = {}
        self.page: Optional[int] = None


class PageAllocator:
    """Host-side page bookkeeping — no arrays, no jax.

    Tracks per-request page tables, the device/host free lists, the
    resident/preempted split, per-page refcounts + the radix prefix
    index (``share=True``), and the high-watermark cap. Mutating ops
    return the ordered data-movement *moves* the pool must execute (or
    None when the operation cannot be satisfied). Invariants (checked
    by :meth:`check`, property-tested in tests/test_memory.py):

    * every device page is free, cached (rc 0 + registered), or owned;
    * refcount of an owned page == its block-table reference count;
    * every host slot is free or owned by exactly one request;
    * non-free device pages never exceed the watermark cap;
    * a request is resident XOR preempted; resident requests hold no
      host (spilled) pages;
    * spilled (host) pages are never shared and never registered.
    """

    def __init__(self, device_ids: Sequence[int], host_slots: int,
                 watermark_cap: int, slot_pages: int,
                 share: bool = False):
        self._all_dev = sorted(int(p) for p in device_ids)
        self.free_dev: List[int] = list(self._all_dev)
        self.n_device = len(self.free_dev)
        self.cap = int(watermark_cap)
        self.NB = int(slot_pages)          # logical pages per slot
        if self.cap < self.NB:
            raise ValueError(
                f"watermark cap {self.cap} pages < one slot's ring "
                f"({self.NB} pages): a single slot could never be "
                f"fully resident — raise kv_pages / kv_watermark")
        self.free_host: List[int] = list(range(int(host_slots)))
        self.n_host = int(host_slots)
        self.tables: Dict[int, List[Optional[Tuple]]] = {}
        self.resident: set = set()
        self.preempted: List[int] = []     # oldest (coldest) first
        self.spills = 0
        self.faults = 0
        self.drops = 0
        # prefix sharing (DESIGN.md §16). rc is maintained even with
        # share off (every owned page at rc 1) so the invariants and
        # the property-test machine are uniform across modes.
        self.share = bool(share)
        self.rc: Dict[int, int] = {}       # owned page -> #table refs
        self.cached: List[int] = []        # rc-0 registered pages, LRU
        self._radix = _RadixNode()         # root (empty prefix)
        self._node_of: Dict[int, _RadixNode] = {}  # page -> its node
        self.prefix_hits = 0
        self.prefix_pages_reused = 0
        self.cow = 0
        self.evictions = 0
        # speculative-decode scratch (DESIGN.md §17): rid -> {logical
        # page j -> physical page} for an IN-FLIGHT verify round. A
        # scratch page sits outside the free list and every block
        # table: no refcount, never registered, invisible to
        # room-making — promote_scratch/discard_scratch resolve it.
        self.scratch: Dict[int, Dict[int, int]] = {}
        # per-request page content keys (the prompt's full-page token
        # bytes), kept while the page is still byte-identical to what
        # was prefilled — the cross-request dedup sweep's evidence. A
        # write (COW/unregister path) invalidates the page's key.
        self._keys: Dict[int, List[Optional[bytes]]] = {}
        self.dedup_merges = 0

    # -- views ---------------------------------------------------------
    @property
    def used_dev(self) -> int:
        return self.n_device - len(self.free_dev)

    @property
    def used_host(self) -> int:
        return self.n_host - len(self.free_host)

    def has(self, rid: int) -> bool:
        return rid in self.tables

    def dev_pages(self, rid: int) -> List[Optional[int]]:
        """Per-logical-page device ids (None = unallocated). Only valid
        for resident requests (no host entries)."""
        out = []
        for e in self.tables[rid]:
            assert e is None or e[0] == "dev", (rid, e)
            out.append(None if e is None else e[1])
        return out

    def preempted_dev_pages(self) -> int:
        """Distinct physical device pages held by preempted requests
        (a page shared across requests counts once)."""
        return len({e[1] for rid in self.preempted
                    for e in self.tables[rid] if e and e[0] == "dev"})

    def _room(self) -> int:
        """Device pages allocatable right now without spilling."""
        return min(len(self.free_dev), self.cap - self.used_dev)

    def reclaimable_pages(self) -> int:
        """Device pages room-making could release: the cached prefix
        pages (rc 0, regenerable) plus cold (preempted) pages not
        co-owned by a resident request — each physical page counted
        once (the *effective* headroom view: shared residency is paid
        for once, so it is only reclaimable once)."""
        resident_held = {e[1] for rid in self.resident
                         for e in self.tables[rid] if e and e[0] == "dev"}
        cold = {e[1] for rid in self.preempted
                for e in self.tables[rid] if e and e[0] == "dev"}
        return len(self.cached) + len(cold - resident_held)

    def headroom(self) -> int:
        """Device pages allocatable after evicting the prefix cache and
        spilling/dropping every cold (preempted) page — the
        admission-control view of the pool."""
        return self._room() + self.reclaimable_pages()

    def admissible_requests(self, pages_per_req: int = 2) -> int:
        """Rough admission headroom in requests (prompt page + growth
        page); the scheduler consults this instead of raw slot count."""
        return self.headroom() // max(1, pages_per_req)

    # -- refcount / radix internals ------------------------------------
    def _ref(self, p: int):
        """Add a table reference to page ``p`` (promoting a cached page
        back to owned)."""
        if p in self.cached:
            self.cached.remove(p)
            self.rc[p] = 1
        else:
            self.rc[p] = self.rc.get(p, 0) + 1

    def _unref(self, p: int):
        """Drop one table reference: the last one demotes the page to
        cached (still matchable) when registered, else frees it."""
        self.rc[p] -= 1
        if self.rc[p] == 0:
            del self.rc[p]
            if p in self._node_of:
                self.cached.append(p)      # newest -> LRU tail
            else:
                self.free_dev.append(p)

    def _unregister(self, p: int):
        """Detach an OWNED page from the prefix index (write path /
        spill path). The trie node stays as a hole so deeper matches
        stop there."""
        node = self._node_of.pop(p, None)
        if node is not None:
            node.page = None

    def _evict_cached_lru(self):
        p = self.cached.pop(0)
        node = self._node_of.pop(p)
        node.page = None
        self.free_dev.append(p)
        self.evictions += 1

    def match_prefix(self, keys: Sequence[bytes]) -> List[int]:
        """Longest resident prefix of ``keys`` in the radix index —
        the device pages a new prompt can map instead of prefilling.
        Read-only (no refs taken)."""
        out: List[int] = []
        node = self._radix
        for key in keys:
            node = node.children.get(key)
            if node is None or node.page is None:
                break
            out.append(node.page)
        return out

    def register_prefix(self, rid: int, keys: Sequence[bytes]):
        """Publish ``rid``'s first ``len(keys)`` pages (all freshly
        prefilled or matched FULL pages) into the prefix index. First
        registration wins per node; pages spilled, COW'd or unwritable
        at that depth are skipped without disturbing the walk."""
        if not self.share:
            return
        # remember the content keys: pages stay byte-identical to what
        # was prefilled until a write invalidates them (make_writable /
        # promote_scratch), which is the dedup sweep's evidence
        self._keys[rid] = list(keys)
        node = self._radix
        for j, key in enumerate(keys):
            e = self.tables[rid][j]
            if e is None or e[0] != "dev":
                break                       # spilled mid-prefix: stop
            node = node.children.setdefault(key, _RadixNode())
            if node.page is None and e[1] not in self._node_of:
                node.page = e[1]
                self._node_of[e[1]] = node

    def _stale_key(self, rid: int, j: int):
        """A write is about to land on logical page ``j``: its content
        no longer matches the prefilled prompt bytes, so it must stop
        participating in dedup matching."""
        ks = self._keys.get(rid)
        if ks and j < len(ks):
            ks[j] = None

    # -- room making (evict-cached, spill-private, then-drop policy) ---
    def _spill_victim(self, protect) -> Optional[int]:
        """Oldest preempted request with a *private* (rc == 1) device
        page — shared pages never spill (a co-owner may be resident
        and mid-decode on them)."""
        for rid in self.preempted:          # oldest preempt first
            if rid == protect:
                continue
            if any(e and e[0] == "dev" and self.rc[e[1]] == 1
                   for e in self.tables[rid]):
                return rid
        return None

    def _drop(self, rid: int):
        """Release ALL of a preempted request's pages (device + host):
        it will resume by re-prefill instead of page fault. Shared
        device pages survive with their other owners; this request's
        refs are simply dropped."""
        for e in self.tables.pop(rid):
            if e is None:
                continue
            if e[0] == "dev":
                self._unref(e[1])
            else:
                self.free_host.append(e[1])
        self.free_dev.extend(self.scratch.pop(rid, {}).values())
        self._keys.pop(rid, None)
        self.preempted.remove(rid)
        self.drops += 1

    def _make_room(self, n: int, moves: List[_Move],
                   protect=None) -> bool:
        """Free device pages until ``n`` are allocatable, cheapest
        reclamation first: (1) evict cached prefix pages (rc 0 — their
        KV regenerates from a prefill, nothing to move); (2) spill cold
        *private* pages (preempted requests, oldest first) to host;
        (3) drop whole preempted requests to re-prefill once the host
        pool is full — or when all their device pages are shared
        (unspillable), since dropping releases the refs and any page
        that reaches rc 0 turns cached and is evicted by (1). False =
        nothing cold left to reclaim."""
        while self._room() < n:
            if self.cached:
                self._evict_cached_lru()
                continue
            victim = self._spill_victim(protect)
            if victim is not None:
                refs = self.tables[victim]
                if self.free_host:
                    j = max(j for j, e in enumerate(refs)
                            if e and e[0] == "dev"
                            and self.rc[e[1]] == 1)
                    dev = refs[j][1]
                    self._unregister(dev)   # host copies never match
                    host = self.free_host.pop()
                    moves.append(("spill", victim, j, dev, host))
                    refs[j] = ("host", host)
                    del self.rc[dev]
                    self.free_dev.append(dev)
                    self.spills += 1
                else:
                    self._drop(victim)
                continue
            # no privately-spillable page anywhere: drop the oldest
            # cold request whose device pages are all SHARED (its refs
            # may cascade pages into the cache, which the next
            # iteration evicts). Host-only holders are left alone —
            # dropping them gains no device room.
            drop = next(
                (r for r in self.preempted if r != protect
                 and any(e and e[0] == "dev" for e in self.tables[r])),
                None)
            if drop is None:
                return False
            self._drop(drop)
        return True

    # -- lifecycle ops -------------------------------------------------
    #
    # Every op returns (ok, moves). The moves list MUST be executed by
    # the caller even when ok is False: _make_room commits spills to
    # the bookkeeping as it goes, so a failed allocation may still have
    # moved cold pages to "host" state — dropping those moves would
    # leave the host pool without the data and a later resume would
    # fault back zeros (silent KV corruption). Spilling cold pages is
    # never wrong, so partial room-making simply stands.

    def admit(self, rid: int, n: int) -> Tuple[bool, List[_Move]]:
        """Allocate the first ``n`` logical pages for a new (or
        re-prefilling) request. not ok = pool exhausted (caller
        defers; any partial spill moves still execute)."""
        ok, moves, _ = self.admit_prefix(rid, n, ())
        return ok, moves

    def admit_prefix(self, rid: int, n: int,
                     keys: Sequence[bytes] = (), min_pages: int = 1
                     ) -> Tuple[bool, List[_Move], int]:
        """Admission with prefix matching: walk ``keys`` (one exact
        token-bytes key per FULL prompt page) down the radix index and
        map every hit (refcount++, cached pages promoted) instead of
        allocating; pages [len(hit)..n) are allocated fresh. Returns
        (ok, moves, matched_pages) — the engine skips ``matched ·
        page_len`` prefill tokens. Matches shorter than ``min_pages``
        are ignored (not worth splitting the prefill batch for). A
        failed admission unwinds the matched refs exactly (no leaks;
        partial spill moves still execute)."""
        assert rid not in self.tables, f"rid {rid} already has pages"
        assert 1 <= n <= self.NB, (rid, n)
        matched: List[int] = []
        if self.share and keys:
            matched = self.match_prefix(keys[:n])
            if len(matched) < max(1, int(min_pages)):
                matched = []
        # take the refs BEFORE room-making: a matched cached page
        # leaves the eviction pool the moment this prompt claims it
        for p in matched:
            self._ref(p)
        m = len(matched)
        moves: List[_Move] = []
        if not self._make_room(n - m, moves):
            for p in matched:               # unwind: no leaked refs
                self._unref(p)
            return False, moves, 0
        refs: List[Optional[Tuple]] = [None] * self.NB
        for j, p in enumerate(matched):
            refs[j] = ("dev", p)
        for j in range(m, n):
            p = self.free_dev.pop()
            refs[j] = ("dev", p)
            self.rc[p] = 1
        self.tables[rid] = refs
        self.resident.add(rid)
        if m:
            self.prefix_hits += 1
            self.prefix_pages_reused += m
        return True, moves, m

    def ensure(self, rid: int, j: int) -> Tuple[bool, List[_Move]]:
        """Decode growth: allocate logical page ``j`` if absent. not
        ok = no room (caller preempts the slot)."""
        refs = self.tables[rid]
        assert rid in self.resident, f"growing non-resident rid {rid}"
        if refs[j] is not None:
            assert refs[j][0] == "dev", (rid, j, refs[j])
            return True, []
        moves: List[_Move] = []
        if not self._make_room(1, moves, protect=rid):
            return False, moves
        p = self.free_dev.pop()
        refs[j] = ("dev", p)
        self.rc[p] = 1
        return True, moves

    def make_writable(self, rid: int, j: int
                      ) -> Tuple[bool, List[_Move],
                                 Optional[Tuple[int, int]]]:
        """Enforce the write rule on logical page ``j`` before a decode
        scatter: a page may only be written while rc == 1 AND
        unregistered. Shared (rc > 1) pages copy-on-write to a fresh
        page — returns ``(src, dst)`` for the pool's device copy;
        private registered pages just unregister (the write would
        invalidate the indexed content). not ok = COW needed but no
        room (caller preempts the slot; moves still execute)."""
        refs = self.tables[rid]
        e = refs[j]
        assert e is not None and e[0] == "dev", (rid, j, e)
        p = e[1]
        self._stale_key(rid, j)
        if self.rc[p] == 1:
            self._unregister(p)
            return True, [], None
        moves: List[_Move] = []
        if not self._make_room(1, moves, protect=rid):
            return False, moves, None
        q = self.free_dev.pop()
        self.rc[q] = 1
        refs[j] = ("dev", q)
        self._unref(p)
        self.cow += 1
        return True, moves, (p, q)

    def free(self, rid: int):
        """EOS / failure: drop every table reference. Private device
        pages return to the free list — unless registered in the
        prefix index, in which case they turn *cached* (rc 0, still
        matchable, evicted LRU under pressure); shared pages live on
        with their co-owners."""
        assert rid in self.tables, f"double free of rid {rid}"
        self.resident.discard(rid)
        if rid in self.preempted:
            self.preempted.remove(rid)
        for e in self.tables.pop(rid):
            if e is None:
                continue
            if e[0] == "dev":
                self._unref(e[1])
            else:
                self.free_host.append(e[1])
        # a request can die mid-draft-round (engine containment):
        # defensively reclaim any scratch it still holds
        self.free_dev.extend(self.scratch.pop(rid, {}).values())
        self._keys.pop(rid, None)

    def preempt(self, rid: int):
        """Unmap from its slot: pages stay allocated but become cold
        (spillable). No data moves — this is the paged replacement for
        the KV-snapshot copy."""
        assert rid not in self.scratch, \
            f"rid {rid} preempted mid-draft-round (scratch leak)"
        self.resident.remove(rid)
        self.preempted.append(rid)

    def mark_preempted(self, rid: int):
        """Idempotent preempt (admission-failure unwind path)."""
        if rid in self.resident:
            self.preempt(rid)

    def resume(self, rid: int) -> Tuple[bool, List[_Move]]:
        """Fault a preempted request's spilled pages back and pin it
        resident. not ok = no room yet (caller retries later) — the
        request keeps its preempted position, partial spill moves of
        OTHER requests still execute. Callers must check :meth:`has`
        first (dropped requests re-prefill)."""
        refs = self.tables[rid]
        need = sum(1 for e in refs if e and e[0] == "host")
        moves: List[_Move] = []
        if not self._make_room(need, moves, protect=rid):
            return False, moves
        for j, e in enumerate(refs):
            if e and e[0] == "host":
                dev = self.free_dev.pop()
                moves.append(("fault", rid, j, e[1], dev))
                self.free_host.append(e[1])
                refs[j] = ("dev", dev)
                self.rc[dev] = 1
                self.faults += 1
        self.preempted.remove(rid)
        self.resident.add(rid)
        return True, moves

    # -- speculative-decode scratch (DESIGN.md §17) --------------------
    def alloc_scratch(self, rid: int, js: Sequence[int]
                      ) -> Tuple[bool, List[_Move], Dict[int, int]]:
        """Reserve one scratch page per logical page in ``js`` for a
        draft/verify round. Scratch pages leave the free list (they
        count toward the watermark) but take NO table reference: they
        are invisible to sharing, spill and room-making until the
        round resolves them via promote/discard. not ok = pool
        pressure — the caller decodes this slot non-speculatively this
        step (partial spill moves still execute)."""
        assert rid in self.resident, f"scratch for non-resident {rid}"
        assert rid not in self.scratch, f"rid {rid} already drafting"
        moves: List[_Move] = []
        if not self._make_room(len(js), moves, protect=rid):
            return False, moves, {}
        got = {int(j): self.free_dev.pop() for j in js}
        self.scratch[rid] = got
        return True, moves, dict(got)

    def promote_scratch(self, rid: int, j: int) -> int:
        """Accept a FULLY-verified scratch page: swap it into the block
        table at logical page ``j`` (rc 1, unregistered) and drop the
        ref on the old page — co-owners keep it, a registered private
        page turns cached. Pure bookkeeping: rollback-by-unmap, never
        a copy. Returns the promoted physical page."""
        s = self.scratch[rid].pop(j)
        refs = self.tables[rid]
        old = refs[j]
        refs[j] = ("dev", s)
        self.rc[s] = 1
        self._stale_key(rid, j)   # speculated content != prompt bytes
        if old is not None:
            assert old[0] == "dev", (rid, j, old)
            self._unref(old[1])
        if not self.scratch[rid]:
            del self.scratch[rid]
        return s

    def discard_scratch(self, rid: int):
        """Reject (or finish) a draft round: every scratch page still
        held returns to the free list. Idempotent."""
        self.free_dev.extend(self.scratch.pop(rid, {}).values())

    # -- cross-request dedup sweep (ROADMAP item 1 leftover) -----------
    def dedup_sweep(self) -> int:
        """Re-link identical ALREADY-RESIDENT pages: requests admitted
        before the radix index knew their content (e.g. simultaneous
        same-prompt admissions in one bucket group, or pages whose
        canonical twin was registered later) hold private duplicates.
        Walk each resident request's stored content keys down the trie;
        where the canonical page differs from ours, move our table ref
        onto the canonical page and drop ours (freed, or kept by
        co-owners). Holes met on the way are repaired by publishing our
        page. Exactness: both pages hold KV from a deterministic
        prefill of the same tokens at the same absolute positions —
        the same argument admission-time prefix sharing rests on
        (DESIGN.md §16). Returns pages merged; no data moves."""
        if not self.share:
            return 0
        merged = 0
        for rid in sorted(self.resident):
            keys = self._keys.get(rid)
            if not keys or rid in self.scratch:
                continue
            refs = self.tables[rid]
            node = self._radix
            for j, key in enumerate(keys):
                if key is None:
                    break      # written since prefill: content unknown
                node = node.children.get(key)
                if node is None:
                    break
                e = refs[j]
                if e is None or e[0] != "dev":
                    break
                p = e[1]
                if node.page is None:
                    if p not in self._node_of:
                        node.page = p       # repair the eviction hole
                        self._node_of[p] = node
                    continue
                q = node.page
                if q == p or p in self._node_of:
                    continue
                self._ref(q)
                refs[j] = ("dev", q)
                self._unref(p)
                merged += 1
        self.dedup_merges += merged
        return merged

    # -- invariants ----------------------------------------------------
    def check(self):
        ref_count: Dict[int, int] = {}
        owned_host = []
        for rid, refs in self.tables.items():
            for e in refs:
                if e is None:
                    continue
                if e[0] == "dev":
                    ref_count[e[1]] = ref_count.get(e[1], 0) + 1
                else:
                    owned_host.append(e[1])
        assert ref_count == self.rc, \
            (f"refcount != block-table references: rc={self.rc} "
             f"vs tables={ref_count}")
        owned_dev = sorted(ref_count)
        scratch_pages = [p for d in self.scratch.values()
                         for p in d.values()]
        assert sorted(owned_dev + self.free_dev + self.cached
                      + scratch_pages) \
            == self._all_dev, "device pages leaked or double-owned"
        assert sorted(owned_host + self.free_host) == \
            list(range(self.n_host)), "host slots leaked or double-owned"
        assert len(set(owned_host)) == len(owned_host)
        assert self.used_dev <= self.cap, \
            f"watermark breached: {self.used_dev} > {self.cap}"
        assert set(self.preempted).isdisjoint(self.resident)
        assert set(self.tables) == self.resident | set(self.preempted)
        for rid in self.resident:
            assert all(e is None or e[0] == "dev"
                       for e in self.tables[rid]), \
                f"resident rid {rid} holds spilled pages"
        # prefix-index consistency: every cached page is registered;
        # every registered page is resident on device (owned or
        # cached) and its node points back at it; holes carry no page
        assert len(set(self.cached)) == len(self.cached)
        for p in self.cached:
            assert p in self._node_of, f"cached page {p} unregistered"
        for p, node in self._node_of.items():
            assert node.page == p, (p, node.page)
            assert p in self.rc or p in self.cached, \
                f"registered page {p} neither owned nor cached"
        # speculative scratch: only resident requests draft, scratch
        # pages carry no refcount and are never registered
        for rid, d in self.scratch.items():
            assert rid in self.resident, \
                f"scratch held by non-resident rid {rid}"
            for p in d.values():
                assert p not in self.rc and p not in self._node_of, \
                    f"scratch page {p} owned or registered"
        assert set(self._keys) <= set(self.tables), \
            "content keys for departed requests"
        if not self.share:
            assert not self._node_of and not self.cached
            assert all(c == 1 for c in self.rc.values())


# ---------------------------------------------------------------------------
# The pool: arrays + jitted movement on top of the allocator
# ---------------------------------------------------------------------------


def gather_block_tables(data, bt: jnp.ndarray):
    """Pool pytree + (B, NB) block table -> logical ring caches
    (R, B, C, …) per leaf; jit-traceable."""
    return jax.tree.map(lambda a: attn_mod.gather_kv_pages(a, bt), data)


def scatter_written_pages(data, caches, bt: jnp.ndarray,
                          pos: jnp.ndarray, NB: int, L: int):
    """Write back the one page per slot a decode step touched (the page
    holding ring position ``pos % C``)."""
    pj = ((pos % (NB * L)) // L).astype(jnp.int32)
    return jax.tree.map(
        lambda a, c: attn_mod.scatter_kv_written_page(a, c, bt, pj),
        data, caches)


def scatter_prefill_pages(data, caches, dests: jnp.ndarray):
    """Scatter per-request prefill caches into the pool at ``dests``
    (G, NB) — trash where unallocated/invalid."""
    return jax.tree.map(
        lambda a, c: attn_mod.scatter_prefill_pages(a, c, dests),
        data, caches)


def masked_scatter_pages(data, caches, dests: jnp.ndarray):
    """Merge suffix caches (logical rings (R, G, C, …) with ``pos = -1``
    at untouched ring slots) into the pool at ``dests`` (G, NB),
    writing ONLY the slots the suffix actually holds and keeping the
    pool's existing content everywhere else. This is the speculative
    verify scatter (DESIGN.md §17): scratch pages seeded from the real
    pages keep their pre-range and old-lap entries while the speculated
    range is overwritten with the verify pass's exact target K/V.
    Unwanted rows route to TRASH_PAGE (rewritten with its own content —
    harmless). jit-traceable."""
    G, NB = dests.shape
    idx = dests.reshape(-1)

    def per_cache(pool_c, new_c):
        L = pool_c.pos.shape[2]
        m = (new_c.pos >= 0).reshape(new_c.pos.shape[0], G * NB, L)

        def mix(a, v):
            r = v.reshape((v.shape[0], G * NB, L) + v.shape[3:])
            mm = m.reshape(m.shape + (1,) * (a.ndim - 3))
            return a.at[:, idx].set(
                jnp.where(mm, r.astype(a.dtype), a[:, idx]))
        return jax.tree.map(mix, pool_c, new_c)

    return jax.tree.map(per_cache, data, caches,
                        is_leaf=lambda x: isinstance(x, attn_mod.KVCache))


def merge_page_slots(data, src, dst, lo, hi):
    """Copy the ring slots whose entry position lies in [lo, hi] from
    physical page ``src`` into page ``dst``, all layers at once — the
    boundary-page promotion of a partially-accepted draft (DESIGN.md
    §17): only the ACCEPTED speculated entries move; the destination's
    other slots (pre-range content, old-lap entries the rejected tail
    must not clobber) stay put. jit-traceable."""
    def per_cache(c):
        m = (c.pos[:, src] >= lo) & (c.pos[:, src] <= hi)   # (R, L)

        def mix(a):
            mm = m.reshape(m.shape + (1,) * (a.ndim - 3))
            return a.at[:, dst].set(
                jnp.where(mm, a[:, src], a[:, dst]))
        return jax.tree.map(mix, c)

    return jax.tree.map(per_cache, data,
                        is_leaf=lambda x: isinstance(x, attn_mod.KVCache))


class PagedKVPool:
    """Shared device page pool + host-RAM spill pool for one Engine.

    ``data`` is the pool pytree (leaves (R, P, L, …), P = device_pages
    + 2 reserved); the engine's jitted prefill/decode read and write it
    through block tables. All policy lives in the embedded
    :class:`PageAllocator`; this class executes the data moves.
    """

    def __init__(self, params, cfg: ModelConfig, *, cache_len: int,
                 device_pages: int, page_len: Optional[int] = None,
                 watermark: float = 1.0, host_pages: int = 0,
                 mesh=None, profile: str = "tp", share: bool = False,
                 telemetry: Optional[Telemetry] = None):
        if any(m != MIXER_ATTN for m in cfg.layer_mixer_kinds()):
            raise ValueError(
                "paged KV requires an attention-only stack (SSM/hybrid "
                "recurrent state has no ring to page)")
        if device_pages < 1:
            raise ValueError(f"device_pages={device_pages} must be >= 1")
        if not 0.0 < watermark <= 1.0:
            raise ValueError(
                f"kv watermark={watermark} must lie in (0, 1]")
        if share and cfg.kv_quant:
            raise ValueError(
                "kv_share is incompatible with kv_quant: suffix prefill "
                "attends DEQUANTIZED int8 prefix KV, which breaks the "
                "bit-identity contract vs the solo/contiguous engine")
        self.telemetry = telemetry
        self._trace = (telemetry.tracer if telemetry is not None
                       else SpanTracer(enabled=False))
        self.cfg = cfg
        self.cache_len = int(cache_len)
        self.page_len = tile_aligned_page_len(cfg, cache_len, page_len)
        self.NB = self.cache_len // self.page_len
        self.n_device = int(device_pages)
        cap = max(1, int(math.floor(self.n_device * watermark)))
        self.share = bool(share)
        self.alloc = PageAllocator(
            range(RESERVED_PAGES, RESERVED_PAGES + self.n_device),
            host_pages, cap, self.NB, share=self.share)
        P = self.n_device + RESERVED_PAGES
        self.data = lm.init_caches(params, cfg, P, self.page_len,
                                   uniform_cap=True)
        self.mesh = mesh
        if mesh is not None:
            from repro.distribution import sharding as shd
            psh = shd.pool_shardings(
                cfg, mesh, jax.eval_shape(lambda: self.data))
            self.data = jax.device_put(self.data, psh)
        # host-RAM spill pool: same structure, numpy, (R, H, L, …)
        self._host = None
        if host_pages > 0:
            self._host = jax.tree.map(
                lambda s: np.zeros(
                    (s.shape[0], host_pages) + s.shape[2:], s.dtype),
                jax.eval_shape(lambda: self.data))
        self._read = jax.jit(
            lambda data, ids: jax.tree.map(lambda a: a[:, ids], data))
        self._write = jax.jit(
            lambda data, ids, vals: jax.tree.map(
                lambda a, v: a.at[:, ids].set(v.astype(a.dtype)),
                data, vals))
        # page scrub: recycled pages carry the previous owner's stale
        # contents — in particular pos values >= 0 that the ring mask
        # would attend to. Prefill and fault writes cover whole pages,
        # but decode-growth pages get only ONE token written, so they
        # are reset to the pristine zero page (zeros, pos = -1) first.
        self._scrub = jax.jit(
            lambda data, ids: jax.tree.map(
                lambda a: a.at[:, ids].set(a[:, ZERO_PAGE][:, None]),
                data))
        # copy-on-write: duplicate one physical page (all layers) so a
        # divergent writer stops aliasing its shared prefix
        self._copy = jax.jit(
            lambda data, src, dst: jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), data))
        # boundary-page promotion of a partially-accepted draft
        self._merge = jax.jit(merge_page_slots)

    # -- sizing --------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Logical pages a prefill of ``n_tokens`` writes (the ring
        keeps at most cache_len of them)."""
        n = min(int(n_tokens), self.cache_len)
        return max(1, -(-n // self.page_len))

    # -- lifecycle (delegates to the allocator, executes moves) --------
    # the allocator's moves execute even when the op fails: partial
    # spills committed by its room-making must reach the host pool, or
    # a later resume would fault back never-written zeros

    def admit(self, rid: int, n_pages: int) -> bool:
        ok, moves = self.alloc.admit(rid, n_pages)
        self._execute(moves)
        return ok

    def admit_prefix(self, rid: int, n_pages: int,
                     keys: Sequence[bytes] = (), min_pages: int = 1
                     ) -> Tuple[bool, int]:
        """Sharing-aware admission: returns (ok, matched_pages) — the
        engine prefills only the suffix beyond ``matched_pages``."""
        ok, moves, m = self.alloc.admit_prefix(rid, n_pages, keys,
                                               min_pages=min_pages)
        self._execute(moves)
        return ok, m

    def register_prefix(self, rid: int, keys: Sequence[bytes]):
        """Publish ``rid``'s freshly prefilled full prompt pages into
        the prefix index (no-op with sharing off)."""
        if self.share and keys:
            self.alloc.register_prefix(rid, keys)

    def ensure_page(self, rid: int, j: int) -> bool:
        fresh = self.alloc.tables[rid][j] is None
        ok, moves = self.alloc.ensure(rid, j)
        self._execute(moves)
        if ok and fresh:
            self.data = self._scrub(
                self.data,
                jnp.asarray([self.alloc.tables[rid][j][1]], jnp.int32))
        return ok

    def ensure_writable(self, rid: int, j: int) -> bool:
        """Decode pre-step guard: page ``j`` must exist AND satisfy the
        write rule (rc == 1, unregistered) before the step's scatter.
        Absent pages allocate+scrub (growth); shared pages copy-on-write
        (one device page copy); private registered pages unregister.
        With sharing off this reduces exactly to :meth:`ensure_page`."""
        if self.alloc.tables[rid][j] is None:
            return self.ensure_page(rid, j)
        ok, moves, copy = self.alloc.make_writable(rid, j)
        self._execute(moves)
        if ok and copy is not None:
            src, dst = copy
            self.data = self._copy(self.data,
                                   jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32))
        return ok

    def resume(self, rid: int) -> bool:
        ok, moves = self.alloc.resume(rid)
        self._execute(moves)
        return ok

    # -- speculative-decode scratch (DESIGN.md §17) --------------------
    def begin_scratch(self, rid: int, js: Sequence[int]
                      ) -> Optional[Dict[int, int]]:
        """Open a draft round for ``rid``: allocate one scratch page
        per logical page in ``js`` and seed each with the CURRENT real
        page's content (scrubbed-empty where unallocated) so pre-range
        in-page entries and post-wrap old-lap entries survive the
        round. Returns {logical page -> scratch page}, or None under
        pool pressure (the slot decodes non-speculatively this step)."""
        ok, moves, got = self.alloc.alloc_scratch(rid, list(js))
        self._execute(moves)
        if not ok:
            return None
        pages = self.alloc.dev_pages(rid)
        fresh = [s for j, s in got.items() if pages[j] is None]
        if fresh:
            self.data = self._scrub(self.data,
                                    jnp.asarray(fresh, jnp.int32))
        seeded = [(pages[j], s) for j, s in got.items()
                  if pages[j] is not None]
        if seeded:
            src = jnp.asarray([a for a, _ in seeded], jnp.int32)
            dst = jnp.asarray([b for _, b in seeded], jnp.int32)
            self.data = self._write(self.data, dst,
                                    self._read(self.data, src))
        return got

    def promote_scratch(self, rid: int, j: int) -> int:
        """Fully-accepted page: pure bookkeeping swap (never a copy)."""
        return self.alloc.promote_scratch(rid, j)

    def discard_scratch(self, rid: int):
        self.alloc.discard_scratch(rid)

    def merge_scratch_slots(self, src: int, dst: int,
                            lo: int, hi: int):
        """Boundary page of a partial acceptance: copy the entries with
        positions in [lo, hi] from scratch page ``src`` onto real page
        ``dst`` (which must already satisfy the write rule)."""
        self.data = self._merge(self.data,
                                jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32),
                                jnp.asarray(lo, jnp.int32),
                                jnp.asarray(hi, jnp.int32))

    def dedup_sweep(self) -> int:
        """Cross-request dedup of already-resident identical pages —
        bookkeeping only (the pages are byte-identical twins)."""
        return self.alloc.dedup_sweep()

    def free(self, rid: int):
        self.alloc.free(rid)

    def preempt(self, rid: int):
        self.alloc.preempt(rid)

    def mark_preempted(self, rid: int):
        self.alloc.mark_preempted(rid)

    def has_pages(self, rid: int) -> bool:
        return self.alloc.has(rid)

    def admissible_requests(self) -> int:
        return self.alloc.admissible_requests()

    # -- tables for the jitted paths -----------------------------------
    def block_table(self, slot_rids: Sequence[Optional[int]]
                    ) -> np.ndarray:
        """(B, NB) physical page ids for the decode gather: occupied
        slots map their allocated pages (zero page where unallocated —
        read as masked emptiness), free slots map the trash page (their
        writes are discarded)."""
        B = len(slot_rids)
        bt = np.full((B, self.NB), TRASH_PAGE, np.int32)
        for i, rid in enumerate(slot_rids):
            if rid is None:
                continue
            for j, p in enumerate(self.alloc.dev_pages(rid)):
                bt[i, j] = ZERO_PAGE if p is None else p
        return bt

    def dest_table(self, rids: Sequence[int], n_rows: int,
                   skip_pages: Optional[Sequence[int]] = None
                   ) -> np.ndarray:
        """(n_rows, NB) prefill WRITE destinations: allocated pages for
        each admitted request, trash everywhere else (unallocated
        logical pages, admission-group padding rows). ``skip_pages[i]``
        routes request i's first k logical pages to trash as well —
        the suffix prefill must never scatter over its SHARED prefix
        pages (they are resident and possibly rc > 1)."""
        dests = np.full((n_rows, self.NB), TRASH_PAGE, np.int32)
        for i, rid in enumerate(rids):
            skip = 0 if skip_pages is None else int(skip_pages[i])
            for j, p in enumerate(self.alloc.dev_pages(rid)):
                if p is not None and j >= skip:
                    dests[i, j] = p
        return dests

    def prefix_table(self, rids: Sequence[int],
                     shared_pages: Sequence[int],
                     n_rows: int) -> np.ndarray:
        """(n_rows, NB) READ table for the suffix prefill: ONLY the
        matched prefix pages are mapped — everything else (the suffix
        region, pad rows) points at the zero page (pos = -1, masked),
        so the gathered ring is exactly 'prefix resident, rest empty'
        and suffix keys enter attention solely through the fresh K/V."""
        bt = np.full((n_rows, self.NB), ZERO_PAGE, np.int32)
        for i, (rid, m) in enumerate(zip(rids, shared_pages)):
            pages = self.alloc.dev_pages(rid)
            for j in range(int(m)):
                assert pages[j] is not None, (rid, j, m)
                bt[i, j] = pages[j]
        return bt

    # -- data movement -------------------------------------------------
    def _execute(self, moves: List[_Move]):
        """Run the allocator's spill/fault moves: one batched gather to
        host per call, one batched scatter from host per call."""
        spills = [(m[3], m[4]) for m in moves if m[0] == "spill"]
        faults = [(m[3], m[4]) for m in moves if m[0] == "fault"]
        t0 = self._trace.t0()
        if spills:
            dev_ids = jnp.asarray([d for d, _ in spills], jnp.int32)
            out = self._read(self.data, dev_ids)
            for leaf in jax.tree.leaves(out):   # overlap D2H copies
                if hasattr(leaf, "copy_to_host_async"):
                    leaf.copy_to_host_async()
            vals = jax.device_get(out)
            hs = [h for _, h in spills]

            def put_host(hleaf, v):
                hleaf[:, hs] = v
                return hleaf
            jax.tree.map(put_host, self._host, vals)
            self._trace.complete("spill", t0, cat="kv",
                                 pages=len(spills))
        if faults:
            host_ids = [h for h, _ in faults]
            dev_ids = jnp.asarray([d for _, d in faults], jnp.int32)
            vals = jax.tree.map(lambda h: jnp.asarray(h[:, host_ids]),
                                self._host)
            self.data = self._write(self.data, dev_ids, vals)
            self._trace.complete("fault", t0, cat="kv",
                                 pages=len(faults))

    # -- accounting ----------------------------------------------------
    def stats(self) -> MemoryStats:
        a = self.alloc
        return MemoryStats(
            device_pages=a.n_device, host_pages=a.n_host,
            watermark=a.cap, device_used=a.used_dev,
            host_used=a.used_host,
            preempted_resident=a.preempted_dev_pages(),
            spills=a.spills, faults=a.faults, drops=a.drops,
            shared_pages=sum(1 for c in a.rc.values() if c > 1),
            cached_pages=len(a.cached),
            prefix_hits=a.prefix_hits,
            prefix_pages_reused=a.prefix_pages_reused,
            cow_copies=a.cow, cache_evictions=a.evictions,
            scratch_pages=sum(len(d) for d in a.scratch.values()),
            dedup_merges=a.dedup_merges)
