"""Fault-tolerant cluster frontend over per-host schedulers
(DESIGN.md §14).

The layer above ``serve.scheduler`` that turns N independent hosts —
each a :class:`~repro.serve.scheduler.ShardedScheduler` — into one
serving surface that keeps answering while hosts die, stall, and come
back. PR 4's rank containment and PR 5's ``revive_rank`` are the
single-process halves; this module adds the cluster half the ROADMAP's
multi-host tier calls for:

* **Heartbeat health checks** — every frontend tick pings each host.
  ``suspect_after`` consecutive misses stop NEW routing to the host
  (it may still finish what it holds); ``dead_after`` misses — or a
  positively-dead host (process exited, every rank dead) — mark it
  dead and trigger evacuation. A suspect host that answers again is
  healthy again (misses reset), so a transient stall costs routing
  preference, not its in-flight work.
* **Idempotent retry with backoff** — a dead host's queued AND
  in-flight requests re-submit to live hosts, each re-submission
  delayed by ``backoff_base * 2**attempt`` (capped, ± seeded jitter so
  a mass failure doesn't re-converge in lockstep). Retries are bounded
  by ``retries``; exhaustion fails the request with the history
  attached. Because :meth:`~repro.serve.engine.Request.mark_resumable`
  arms the exact re-prefill resume off the emitted-token snapshot, a
  retried request CONTINUES its stream — no token is recomputed, and
  greedy streams are bit-identical to an undisturbed run.
* **Exactly-once token delivery** — the frontend dedups by request id
  and per-request delivered-token index: a token is handed to the
  caller's sink only when it is the next undelivered index, so replays
  (a subprocess host re-streaming after a resume, a retry racing a
  late event) never double-stream. One request, one resolution:
  ``done``, ``rejected``, or ``failed`` — never two.
* **Watchdog** — a per-request wall-clock budget
  (``request_timeout``): an overdue request is cancelled out of
  whichever host holds it (releasing its slot/pages) and failed,
  without stalling the loop or the other hosts.
* **Graceful drain** — :meth:`ClusterFrontend.drain` stops admission
  and serves what is in flight to completion (retries and hand-offs
  stay live — a host dying mid-drain hands its work off as usual),
  bounded by ``drain_timeout``; stragglers are cancelled and failed at
  the deadline, so shutdown is itself bounded.
* **Revive + replay** — :meth:`revive_host` rebuilds a dead host's
  dead ranks (``revive_rank``, stats continuous across the outage),
  resets its health, and replays every retryable failure (retries
  exhausted, no-live-hosts) back into the pool with a fresh attempt
  budget — an operator bringing capacity back also brings back the
  requests the outage failed.

Two host flavors behind one interface: :class:`LocalHost` wraps an
in-process scheduler (with optional :mod:`~repro.serve.chaos` fault
hooks — deterministic kill/raise/drop-hb/slow at seeded steps), and
:class:`SubprocessHost` speaks a line-JSON protocol to a
``tests/dist_worker.py frontend_host`` child process, so tests can
``kill -9`` a real OS process mid-load and assert the same recovery
guarantees. Like every layer below (engine slots, scheduler ranks),
the frontend preserves the serving contract: every completed request's
greedy stream is bit-identical to running it alone on a single
undisturbed host, no matter how many hosts died under it on the way.
"""
from __future__ import annotations

import json
import os
import queue
import random
import signal
import subprocess
import threading
import time
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.serve.chaos import ChaosMonkey
from repro.serve.engine import Request
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler
from repro.serve.telemetry import Telemetry, merged_ttft_stats

HOST_STATES = ("healthy", "suspect", "dead")
OUTCOMES = ("done", "rejected", "failed")


@dataclass
class FrontendConfig:
    # --- retry ladder -------------------------------------------------
    retries: int = 2                # re-submissions after host failures
    backoff_base: float = 0.02     # seconds; attempt k waits base*2^k
    backoff_cap: float = 2.0       # ceiling on any single delay
    backoff_jitter: float = 0.25   # ± uniform fraction of the delay
    # --- health ladder ------------------------------------------------
    suspect_after: int = 1         # missed beats -> stop new routing
    dead_after: int = 3            # missed beats -> dead + evacuate
    # --- timeouts -----------------------------------------------------
    request_timeout: Optional[float] = None   # per-request wall clock
    drain_timeout: float = 30.0
    rng_seed: int = 0              # backoff jitter (deterministic)


class _Tracker:
    """Frontend-side lifecycle record for one request: which host holds
    it, how many delivery attempts it has burned, how many tokens the
    caller has been handed (the dedup cursor), and its one-and-only
    resolution."""
    __slots__ = ("req", "host_id", "attempts", "retry_at", "delivered",
                 "outcome", "replayable", "t0")

    def __init__(self, req: Request, now: float):
        self.req = req
        self.host_id: Optional[int] = None
        self.attempts = 0              # host submissions so far
        self.retry_at: Optional[float] = None   # due time when unrouted
        self.delivered = 0             # tokens handed to the sink
        self.outcome: Optional[str] = None      # None until resolved
        self.replayable = False        # revive_host may resurrect it
        self.t0 = now                  # watchdog epoch


# ----------------------------------------------------------------------
# host handles
# ----------------------------------------------------------------------
class LocalHost:
    """In-process host: one :class:`ShardedScheduler` plus optional
    chaos hooks. ``step()`` returns ``(finished_rids,
    failed_[(rid, err)], token_events)`` — token events are empty here
    (local tokens flow through the streaming sink directly); the tuple
    shape matches :class:`SubprocessHost`."""

    def __init__(self, host_id: int, scheduler: ShardedScheduler, *,
                 chaos: Optional[ChaosMonkey] = None):
        self.host_id = host_id
        self.sched = scheduler
        self.telemetry = scheduler.telemetry
        self.chaos = chaos
        self.steps = 0                  # local step counter (chaos keys)
        self.killed = False             # chaos hard-kill latch

    @property
    def alive(self) -> bool:
        return not self.killed and bool(self.sched._live())

    def set_sink(self, fn: Optional[Callable[[Request, int], None]]):
        self.sched.set_on_token(fn)

    def heartbeat(self) -> bool:
        if self.killed:
            return False
        if self.chaos is not None and self.chaos.heartbeat_dropped(
                self.host_id, self.steps):
            self.telemetry.tracer.instant("hb_drop", cat="chaos",
                                          step=self.steps)
            return False
        return bool(self.sched._live())

    def headroom_tokens(self) -> Optional[int]:
        """Best single live rank's spill headroom — a request lands on
        ONE rank, so the max (not the sum) decides admissibility.
        With prefix sharing this is *effective* headroom: each engine
        counts shared physical pages once and adds evictable cached
        pages back in (DESIGN.md §16), so routing sees the capacity a
        new request could actually claim."""
        hs = [e.route_headroom_tokens() for e in self.sched._live()]
        hs = [h for h in hs if h is not None]
        return max(hs) if hs else None

    def submit(self, req: Request) -> str:
        """'ok' | 'rejected' (admission control) | 'dead' (no live
        ranks — the frontend retries elsewhere). The scheduler's own
        terminal bookkeeping for non-admitted requests is undone here:
        the FRONTEND owns their fate."""
        if self.killed or not self.sched._live():
            return "dead"
        if self.sched.submit(req):
            return "ok"
        self.sched.retract_request(req)
        return "rejected" if req.status == "rejected" else "dead"

    def step(self) -> Tuple[List[int], List[Tuple[int, str]],
                            List[Tuple[int, int, int]]]:
        if self.killed:
            return [], [], []
        self.steps += 1
        if self.chaos is not None:
            if self.chaos.kill_due(self.host_id, self.steps):
                self.telemetry.tracer.instant("host_kill", cat="chaos",
                                              step=self.steps)
                self.killed = True      # hard death: strands its work
                return [], [], []
            d = self.chaos.delay_s(self.host_id)
            if d > 0:
                time.sleep(d)
            if self.chaos.decode_raise_due(self.host_id, self.steps):
                live = self.sched._live()
                if live:                # next step on this rank raises;
                    def _boom(*a, **k):  # revive_rank rebuilds _decode
                        raise RuntimeError("chaos: injected decode fault")
                    live[0]._decode = _boom
        finished = self.sched.step()
        # terminal scheduler failures (requeues exhausted, no live
        # shards) escalate to the frontend, which owns their fate —
        # drain them off the host's list under the scheduler's lock
        failed = [(r.rid, r.error or "rank failure")
                  for r in self.sched.drain_failed()]
        return [r.rid for r in finished], failed, []

    def cancel(self, rid: int) -> Optional[Request]:
        return self.sched.cancel(rid)

    def evacuate(self, rids: Sequence[int]):
        """Purge the given requests from this (dead) host so its
        scheduler holds no references to objects the frontend is about
        to hand elsewhere — a later revive must not resume stale
        copies."""
        for rid in rids:
            self.sched.cancel(rid)

    def revive(self):
        for r, eng in enumerate(self.sched.shards):
            if eng.dead:
                self.sched.revive_rank(r)
        self.killed = False

    def close(self):
        pass

    def stats(self) -> Dict:
        d = self.sched.stats()
        d["host"] = self.host_id
        d["steps"] = self.steps
        return d


class SubprocessHost:
    """A host in its own OS process (``tests/dist_worker.py``
    ``frontend_host`` mode): newline-JSON commands on stdin, ``EV
    {json}`` events on stdout, read by a daemon thread so a hung or
    killed worker can never block the frontend loop past the rpc
    timeout. The parent applies streamed token events to its own
    canonical :class:`Request` objects (the shadow state IS the resume
    snapshot — after ``kill -9``, a replacement submission carries
    ``out_tokens`` and resumes exactly). Any protocol breakdown — EOF,
    broken pipe, rpc timeout, nonzero exit — latches ``killed``; the
    frontend's health ladder does the rest."""

    def __init__(self, host_id: int, cmd: Sequence[str], *,
                 env: Optional[Dict[str, str]] = None,
                 ready_timeout: float = 600.0,
                 step_timeout: float = 300.0,
                 hb_timeout: float = 60.0):
        self.host_id = host_id
        self.cmd = list(cmd)
        self.env = dict(env) if env is not None else None
        self.ready_timeout = ready_timeout
        self.step_timeout = step_timeout
        self.hb_timeout = hb_timeout
        self.killed = False
        self.steps = 0
        self._pending: List[Dict] = []  # events read while awaiting acks
        self._spawn()

    # -- process + reader ------------------------------------------------
    def _spawn(self):
        self.proc = subprocess.Popen(
            self.cmd, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            text=True, bufsize=1, env=self.env)
        self._q: "queue.Queue[Optional[str]]" = queue.Queue()
        t = threading.Thread(target=self._read_loop,
                             args=(self.proc.stdout, self._q), daemon=True)
        t.start()
        self._pending = []
        if self._wait_for({"ready"}, self.ready_timeout) is None:
            raise RuntimeError(
                f"frontend host {self.host_id} worker failed to start: "
                f"{self.cmd}")

    @staticmethod
    def _read_loop(stream, q):
        try:
            for line in stream:
                q.put(line)
        except ValueError:              # stream closed under the reader
            pass
        q.put(None)                     # EOF sentinel

    @property
    def alive(self) -> bool:
        return not self.killed and self.proc.poll() is None

    def _send(self, **obj) -> bool:
        if not self.alive:
            return False
        try:
            self.proc.stdin.write(json.dumps(obj) + "\n")
            self.proc.stdin.flush()
            return True
        except (BrokenPipeError, OSError, ValueError):
            self.killed = True
            return False

    def _next_event(self, timeout: float) -> Optional[Dict]:
        deadline = time.monotonic() + timeout
        while True:
            try:
                line = self._q.get(
                    timeout=max(0.0, deadline - time.monotonic()))
            except queue.Empty:
                return None             # rpc timeout: treat as hung
            if line is None:
                self.killed = True      # EOF: the process is gone
                return None
            line = line.strip()
            if not line.startswith("EV "):
                continue                # stray runtime chatter
            try:
                return json.loads(line[3:])
            except json.JSONDecodeError:
                continue

    def _wait_for(self, kinds, timeout: float) -> Optional[Dict]:
        """Read events until one of ``kinds``; everything else (tok/
        done/failed arriving ahead of an ack) buffers for the next
        ``step()`` harvest. None = timeout or EOF → host is dead."""
        deadline = time.monotonic() + timeout
        while True:
            ev = self._next_event(max(0.0, deadline - time.monotonic()))
            if ev is None:
                self.killed = True
                return None
            if ev.get("ev") in kinds:
                return ev
            self._pending.append(ev)

    # -- host interface --------------------------------------------------
    def heartbeat(self) -> bool:
        if not self._send(cmd="ping"):
            return False
        return self._wait_for({"pong"}, self.hb_timeout) is not None

    def headroom_tokens(self) -> Optional[int]:
        return None                     # not worth the protocol chatter

    def submit(self, req: Request) -> str:
        ok = self._send(
            cmd="submit", rid=req.rid,
            prompt=[int(t) for t in req.prompt],
            resume=[int(t) for t in req.out_tokens],
            max_new=req.max_new_tokens, temperature=req.temperature,
            eos=req.eos_id, slo=req.slo)
        if not ok:
            return "dead"
        ev = self._wait_for({"submitted"}, self.hb_timeout)
        if ev is None:
            return "dead"
        if ev.get("ok", True):
            return "ok"
        # non-admission: admission-control shed vs worker ranks dead
        return "rejected" if ev.get("status") == "rejected" else "dead"

    def step(self) -> Tuple[List[int], List[Tuple[int, str]],
                            List[Tuple[int, int, int]]]:
        if not self._send(cmd="step"):
            return [], [], []
        self.steps += 1
        events, self._pending = self._pending, []
        while True:
            ev = self._next_event(self.step_timeout)
            if ev is None:
                self.killed = True      # hung/killed mid-step
                return [], [], []
            if ev.get("ev") == "stepped":
                break
            events.append(ev)
        fin, failed, toks = [], [], []
        for ev in events:
            kind = ev.get("ev")
            if kind == "tok":
                toks.append((ev["rid"], ev["i"], ev["tok"]))
            elif kind == "done":
                fin.append(ev["rid"])
            elif kind == "failed":
                failed.append((ev["rid"], ev.get("error", "worker failure")))
        return fin, failed, toks

    def cancel(self, rid: int):
        if self._send(cmd="cancel", rid=rid):
            self._wait_for({"cancelled"}, self.hb_timeout)
        return None

    def evacuate(self, rids: Sequence[int]):
        pass                            # the process is gone with them

    def set_sink(self, fn):
        pass                            # tokens arrive as step events

    def kill(self):
        """SIGKILL the worker — the test-facing chaos primitive."""
        self.killed = True
        try:
            os.kill(self.proc.pid, signal.SIGKILL)
        except (ProcessLookupError, OSError):
            pass

    def revive(self):
        self.kill()                     # ensure the old process is gone
        self.killed = False
        self._spawn()

    def close(self):
        if self.proc.poll() is None:
            self._send(cmd="exit")
            try:
                self.proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                self.proc.kill()
        self.killed = True

    def stats(self) -> Dict:
        return {"host": self.host_id, "steps": self.steps,
                "alive": self.alive}


def make_local_hosts(params, cfg, *, hosts: int = 2,
                     sched: Optional[SchedulerConfig] = None,
                     ranks: int = 1, chaos: Optional[ChaosMonkey] = None,
                     profile: str = "tp",
                     trace: bool = False) -> List[LocalHost]:
    """Build N in-process hosts, each its own ShardedScheduler over
    ``ranks`` engine shards (rng seeds offset per host so hosts are
    distinct engines, which greedy decoding never observes). Each host
    gets its OWN Telemetry (same-rank engines on different hosts must
    not share counter scopes); ``trace`` arms every host's span
    tracer — the frontend merges the ring buffers at export."""
    sched = sched or SchedulerConfig()
    out = []
    for h in range(hosts):
        s = replace(sched, rng_seed=sched.rng_seed + h * max(1, ranks))
        out.append(LocalHost(
            h, ShardedScheduler(params, cfg, sched=s, ranks=ranks,
                                profile=profile,
                                telemetry=Telemetry(trace=trace)),
            chaos=chaos))
    return out


# ----------------------------------------------------------------------
# the frontend
# ----------------------------------------------------------------------
class ClusterFrontend:
    """Routes requests across hosts; owns every request's lifecycle
    (exactly-once resolution, exactly-once token delivery) no matter
    which hosts fail underneath. See module docstring for semantics."""

    def __init__(self, hosts: Sequence, cfg: Optional[FrontendConfig]
                 = None, *, on_token: Optional[
                     Callable[[Request, int], None]] = None,
                 telemetry: Optional[Telemetry] = None):
        assert hosts, "a frontend needs at least one host"
        ids = [h.host_id for h in hosts]
        assert len(set(ids)) == len(ids), f"duplicate host ids: {ids}"
        self.hosts: Dict[int, object] = {h.host_id: h for h in hosts}
        self.cfg = cfg or FrontendConfig()
        self.on_token = on_token
        self.rng = random.Random(self.cfg.rng_seed)
        # the frontend's OWN registry/tracer — retry/health/watchdog
        # events land here, host events stay in the hosts' rings and
        # merge at export. Default: trace iff any host traces.
        if telemetry is None:
            telemetry = Telemetry(trace=any(
                getattr(h, "telemetry", None) is not None
                and h.telemetry.tracer.enabled for h in hosts))
        self.telemetry = telemetry
        self._trace = telemetry.tracer
        self.telemetry.registry.register_collector(
            self._cluster_metrics, key="cluster")
        # guards trackers/outcome lists/health against concurrent
        # callers (submit from a caller thread while run()/step() ticks;
        # stats from a monitor). Reentrant: a LocalHost step fires
        # _local_sink inline while step() already holds the lock.
        self._lock = threading.RLock()
        self.trackers: Dict[int, _Tracker] = {}
        self.done: List[Request] = []
        self.failed: List[Request] = []
        self.rejected: List[Request] = []
        self.draining = False
        self.n_retries = 0              # re-submissions actually made
        self.n_deduped = 0              # duplicate token events dropped
        self._health = {h.host_id: {"state": "healthy", "misses": 0}
                        for h in hosts}
        for h in hosts:
            h.set_sink(self._local_sink)

    # -- views -----------------------------------------------------------
    def unresolved(self) -> List[_Tracker]:
        with self._lock:
            return [t for t in self.trackers.values()
                    if t.outcome is None]

    def _state(self, hid: int) -> str:
        return self._health[hid]["state"]

    def _routable(self) -> List:
        return [h for h in self.hosts.values()
                if self._state(h.host_id) == "healthy" and h.alive]

    def _exhausted(self) -> bool:
        return not any(h.alive and self._state(h.host_id) != "dead"
                       for h in self.hosts.values())

    def _outstanding(self, hid: int, slo: Optional[str] = None) -> int:
        return sum(t.req.cost_estimate() for t in self.trackers.values()
                   if t.outcome is None and t.host_id == hid
                   and (slo is None or t.req.slo == slo))

    # -- routing (mirrors ShardedScheduler._route at host granularity) ---
    def _route(self, req: Request):
        cands = self._routable()
        if not cands:
            return None
        need = len(req.prompt) + max(0, len(req.out_tokens) - 1)

        def pressed(h) -> int:
            hr = h.headroom_tokens()
            return 0 if hr is None or hr >= need else 1

        if req.slo == "interactive":
            return min(cands, key=lambda h: (
                pressed(h), self._outstanding(h.host_id, "interactive"),
                self._outstanding(h.host_id), h.host_id))
        return min(cands, key=lambda h: (
            pressed(h), self._outstanding(h.host_id), h.host_id))

    # -- resolution (exactly once) ---------------------------------------
    def _resolve(self, tr: _Tracker, outcome: str):
        assert tr.outcome is None, \
            f"request {tr.req.rid} resolved twice ({tr.outcome} -> {outcome})"
        tr.outcome = outcome
        {"done": self.done, "failed": self.failed,
         "rejected": self.rejected}[outcome].append(tr.req)

    def _fail(self, tr: _Tracker, error: str, *, replayable: bool):
        req = tr.req
        req.status = "failed"
        req.error = error
        req.t_done = time.monotonic()
        req._kv = None
        tr.replayable = replayable
        self._resolve(tr, "failed")

    def _reject(self, tr: _Tracker, reason: str):
        tr.req.status = "rejected"
        tr.req.error = reason
        self._resolve(tr, "rejected")

    # -- token delivery (exactly once) -----------------------------------
    def _local_sink(self, req: Request, tok: int):
        with self._lock:
            tr = self.trackers.get(req.rid)
            if tr is None or tr.outcome is not None:
                return
            if len(req.out_tokens) == tr.delivered + 1:
                tr.delivered += 1
                if self.on_token is not None:
                    self.on_token(req, tok)
            else:
                self.n_deduped += 1

    def _remote_token(self, tr: _Tracker, i: int, tok: int):
        """Apply one worker token event to the parent's shadow request.
        ``i`` is the GLOBAL output index, so replays after a resume
        (i < delivered) dedup away and the sink sees each index once."""
        if tr.outcome is not None:
            return
        if i == len(tr.req.out_tokens):
            tr.req.out_tokens.append(tok)
        if i == tr.delivered:
            tr.delivered += 1
            if self.on_token is not None:
                self.on_token(tr.req, tok)
        elif i < tr.delivered:
            self.n_deduped += 1

    # -- submission / retry ladder ---------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit a request to the cluster. False = resolved on the spot
        as rejected (draining, or a host's admission control shed it);
        True = the frontend owns it until it resolves. With no routable
        host RIGHT NOW the request waits at the frontend and routes
        when one recovers (or fails when every host is gone)."""
        with self._lock:
            now = time.monotonic()
            tr = _Tracker(req, now)
            assert req.rid not in self.trackers, \
                f"duplicate rid {req.rid}"
            self.trackers[req.rid] = tr
            if self.draining:
                self._reject(tr, "frontend is draining")
                return False
            if req.t_submit is None:
                req.t_submit = now
            return self._dispatch(tr)

    def _dispatch(self, tr: _Tracker) -> bool:
        """Try to place a request on a host now; park it on the retry
        timer otherwise."""
        host = self._route(tr.req)
        if host is None:
            tr.host_id = None
            if tr.retry_at is None:
                tr.retry_at = time.monotonic()  # due as soon as possible
            return True
        tr.attempts += 1
        if tr.attempts > 1:
            self.n_retries += 1
        verdict = host.submit(tr.req)
        if verdict == "ok":
            tr.host_id = host.host_id
            tr.retry_at = None
            return True
        if verdict == "rejected":
            self._reject(tr, f"host {host.host_id} admission control")
            return False
        # 'dead': the host failed under us between health check and
        # submit — count the miss and put the request on the ladder
        self._health[host.host_id]["misses"] += 1
        self._schedule_retry(tr, f"host {host.host_id} died at submit")
        return tr.outcome is None

    def _backoff(self, attempt: int) -> float:
        d = min(self.cfg.backoff_cap,
                self.cfg.backoff_base * (2.0 ** max(0, attempt - 1)))
        return d * (1.0 + self.cfg.backoff_jitter
                    * (2.0 * self.rng.random() - 1.0))

    def _schedule_retry(self, tr: _Tracker, reason: str):
        """A host failed while holding this request: arm an exact
        resume and either park it for a backed-off re-submission or,
        with the attempt budget spent, fail it (replayable — a revive
        can resurrect it)."""
        tr.host_id = None
        if tr.attempts > self.cfg.retries:
            self._fail(tr, f"{reason}; {self.cfg.retries} retr"
                       f"{'y' if self.cfg.retries == 1 else 'ies'} "
                       "exhausted", replayable=True)
            return
        req = tr.req
        req.mark_resumable()
        req.status = "queued"
        tr.retry_at = time.monotonic() + self._backoff(tr.attempts)
        self._trace.instant("retry", pid=-1, rid=req.rid,
                            attempt=tr.attempts, reason=reason)

    def _flush_retries(self, now: float):
        for tr in self.unresolved():
            if tr.host_id is None and tr.retry_at is not None \
                    and tr.retry_at <= now:
                self._dispatch(tr)

    # -- health ladder ----------------------------------------------------
    def _beat(self):
        for hid, host in self.hosts.items():
            st = self._health[hid]
            if st["state"] == "dead":
                continue
            if not host.alive:
                self._mark_dead(hid, "host process/ranks gone")
                continue
            if host.heartbeat():
                st["misses"] = 0
                st["state"] = "healthy"
                continue
            st["misses"] += 1
            if st["misses"] >= self.cfg.dead_after or not host.alive:
                self._mark_dead(hid, f"{st['misses']} missed heartbeats")
            elif st["misses"] >= self.cfg.suspect_after:
                st["state"] = "suspect"

    def _mark_dead(self, hid: int, why: str):
        self._health[hid]["state"] = "dead"
        self._trace.instant("host_dead", pid=-1, host=hid, why=why)
        host = self.hosts[hid]
        stranded = [t for t in self.unresolved() if t.host_id == hid]
        host.evacuate([t.req.rid for t in stranded])
        for tr in stranded:
            self._schedule_retry(tr, f"host {hid} dead ({why})")

    # -- watchdog ----------------------------------------------------------
    def _watchdog(self, now: float):
        if self.cfg.request_timeout is None:
            return
        for tr in self.unresolved():
            if now - tr.t0 <= self.cfg.request_timeout:
                continue
            if tr.host_id is not None:
                self.hosts[tr.host_id].cancel(tr.req.rid)
            self._trace.instant("watchdog_cancel", pid=-1,
                                rid=tr.req.rid)
            self._fail(tr, f"watchdog: exceeded {self.cfg.request_timeout}"
                       "s wall clock", replayable=False)

    # -- the tick ----------------------------------------------------------
    def step(self) -> List[Request]:
        """One frontend tick: health checks, watchdog, due retries, one
        scheduler step on every live host. Returns requests completed
        this tick."""
        with self._lock:
            now = time.monotonic()
            self._beat()
            self._watchdog(now)
            self._flush_retries(now)
            out: List[Request] = []
            for hid, host in self.hosts.items():
                if self._state(hid) == "dead" or not host.alive:
                    continue
                fin, failed, toks = host.step()
                for rid, i, tok in toks:
                    tr = self.trackers.get(rid)
                    if tr is not None:
                        self._remote_token(tr, i, tok)
                for rid in fin:
                    tr = self.trackers.get(rid)
                    if tr is None or tr.outcome is not None:
                        continue
                    req = tr.req
                    if not req.done:    # subprocess host: stamp shadow
                        req.done = True
                        req.status = "done"
                        req.t_done = time.monotonic()
                    self._resolve(tr, "done")
                    out.append(req)
                for rid, err in failed:
                    tr = self.trackers.get(rid)
                    if tr is not None and tr.outcome is None:
                        tr.req.status = "queued"  # frontend owns it
                        self._schedule_retry(tr, f"host {hid}: {err}")
            return out

    def _host_busy(self) -> bool:
        return any(t.host_id is not None for t in self.unresolved())

    def _next_due(self) -> Optional[float]:
        due = [t.retry_at for t in self.unresolved()
               if t.host_id is None and t.retry_at is not None]
        return min(due) if due else None

    # -- serving loops -----------------------------------------------------
    def run(self, requests: Sequence[Request],
            arrivals: Optional[Sequence[float]] = None,
            on_token: Optional[Callable[[Request, int], None]] = None,
            *, on_tick: Optional[Callable[[int], None]] = None
            ) -> List[Request]:
        """Serve ``requests`` to completion (``arrivals``: offsets in
        seconds, e.g. Poisson; omitted = all up front). Returns the
        COMPLETED requests; rejected/failed ones land on
        ``self.rejected``/``self.failed``. Every submitted request
        resolves exactly once even if every host dies. ``on_tick``
        (tick index) lets tests drive chaos from the loop."""
        if on_token is not None:
            self.on_token = on_token
        timed = arrivals is not None
        order = sorted(range(len(requests)),
                       key=lambda i: arrivals[i] if timed else 0.0)
        t0 = time.monotonic()
        i = 0
        tick = 0
        completed: List[Request] = []
        while True:
            # the tick's work runs under the lock; the idle sleep below
            # runs OUTSIDE it, so concurrent submit()/stats() callers
            # are never blocked behind a sleeping loop
            sleep_for: Optional[float] = None
            with self._lock:
                if i >= len(order) and not self.unresolved():
                    break
                if self._exhausted():
                    self._beat()            # record deaths in health
                    while i < len(order):   # arrivals must resolve
                        self.submit(requests[order[i]])
                        i += 1
                    for tr in self.unresolved():
                        self._fail(tr, "no live hosts", replayable=True)
                    break
                now = time.monotonic() - t0
                while i < len(order) and (
                        not timed or arrivals[order[i]] <= now):
                    self.submit(requests[order[i]])
                    i += 1
                if on_tick is not None:
                    on_tick(tick)
                completed.extend(self.step())
                tick += 1
                if not self._host_busy():
                    # idle: nothing decoding anywhere — sleep toward
                    # the next arrival or retry timer, not spinning
                    waits = []
                    if i < len(order) and timed:
                        waits.append(t0 + arrivals[order[i]]
                                     - time.monotonic())
                    due = self._next_due()
                    if due is not None:
                        waits.append(due - time.monotonic())
                    if waits:
                        sleep_for = min(0.05, max(0.0, min(waits)))
            if sleep_for is not None:
                time.sleep(sleep_for)
        return completed

    def drain(self, timeout: Optional[float] = None
              ) -> Tuple[List[Request], bool]:
        """Graceful shutdown: stop admission (new submits reject),
        serve everything in flight to completion — retries and host
        hand-offs stay live — bounded by ``timeout`` (default
        ``drain_timeout``). At the deadline stragglers are cancelled
        out of their hosts and failed, so drain itself always
        terminates. Returns ``(completed_during_drain, clean)`` where
        ``clean`` means nothing was cut off."""
        with self._lock:
            self.draining = True
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.cfg.drain_timeout)
        completed: List[Request] = []
        while time.monotonic() < deadline:
            # per-iteration lock scope: a long drain must not starve
            # concurrent stats()/submit() (which now reject) callers
            with self._lock:
                if not self.unresolved() or self._exhausted():
                    break
                completed.extend(self.step())
        with self._lock:
            leftovers = self.unresolved()
            for tr in leftovers:
                if tr.host_id is not None:
                    self.hosts[tr.host_id].cancel(tr.req.rid)
                self._fail(tr, "drain timeout expired", replayable=True)
        return completed, not leftovers

    def close(self):
        for h in self.hosts.values():
            h.close()

    # -- revive + replay ---------------------------------------------------
    def revive_host(self, host_id: int, *, replay: bool = True):
        """Bring a dead host back (rebuild dead ranks in-process,
        respawn the worker for subprocess hosts), reset its health, and
        — the other half of PR 5's ``revive_rank`` — replay every
        RETRYABLE failure (retries exhausted / no-live-hosts; never
        watchdog kills) back into the pool with a fresh attempt budget:
        restored capacity also restores the requests the outage cost."""
        with self._lock:
            host = self.hosts[host_id]
            host.revive()
            host.set_sink(self._local_sink)
            self._health[host_id] = {"state": "healthy", "misses": 0}
            self._trace.instant("host_revive", pid=-1, host=host_id)
            if not replay:
                return
            for tr in list(self.trackers.values()):
                if tr.outcome != "failed" or not tr.replayable:
                    continue
                self.failed.remove(tr.req)
                tr.outcome = None
                tr.replayable = False
                tr.attempts = 0
                tr.t0 = time.monotonic()  # a replay restarts its clock
                req = tr.req
                req.error = None
                req.t_done = None
                req.mark_resumable()
                req.status = "queued"
                self._dispatch(tr)

    # -- telemetry export --------------------------------------------------
    def _host_telemetries(self) -> List[Telemetry]:
        return [h.telemetry for h in self.hosts.values()
                if getattr(h, "telemetry", None) is not None]

    def _cluster_metrics(self) -> Dict[str, float]:
        """Collector on the frontend registry: per-host counter sums
        (the ``host`` label keeps same-rank series from colliding) plus
        the frontend's own lifecycle counters."""
        out: Dict[str, float] = {}
        with self._lock:
            out["serve_frontend_retries_total"] = self.n_retries
            out["serve_frontend_deduped_tokens_total"] = self.n_deduped
            for st in HOST_STATES:
                n = sum(1 for h in self.hosts
                        if self._state(h) == st)
                out[f'serve_frontend_hosts{{state="{st}"}}'] = n
            tels = list(self.hosts.items())
        for hid, h in tels:
            tel = getattr(h, "telemetry", None)
            if tel is None:
                continue
            for k, v in tel.registry.summary()["counters"].items():
                out[f'serve_{k}_total{{host="{hid}"}}'] = v
        return out

    def trace_events(self) -> List[Dict]:
        """Chrome trace events merged across the frontend's own ring
        (pid = -1) and every host's ring (pid rewritten to the host
        id), time-sorted — one Perfetto track group per host, one row
        per rank."""
        evs = self.telemetry.tracer.events()
        for hid, h in self.hosts.items():
            tel = getattr(h, "telemetry", None)
            if tel is None or tel.tracer is self.telemetry.tracer:
                continue
            for e in tel.tracer.events():
                e["pid"] = hid
                evs.append(e)
        evs.sort(key=lambda e: e["ts"])
        return evs

    def write_trace(self, path: str) -> int:
        trace = {"traceEvents": self.trace_events(),
                 "displayTimeUnit": "ms"}
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(trace, fh)
        return len(trace["traceEvents"])

    def prometheus(self) -> str:
        """Cluster-level text exposition: the frontend registry (whose
        cluster collector folds in per-host counter sums)."""
        return self.telemetry.prometheus()

    def stats(self) -> Dict:
        with self._lock:
            states = [self._state(h) for h in self.hosts]
            return {
                "hosts": len(self.hosts),
                "healthy": states.count("healthy"),
                "suspect": states.count("suspect"),
                "dead": states.count("dead"),
                "submitted": len(self.trackers),
                "done": len(self.done),
                "failed": len(self.failed),
                "rejected": len(self.rejected),
                "unresolved": len(self.unresolved()),
                "retries": self.n_retries,
                "deduped_tokens": self.n_deduped,
                "delivered_tokens": sum(t.delivered
                                        for t in self.trackers.values()),
                # cluster-wide TTFT per SLO class (associative
                # snapshot merge across host registries)
                "ttft": merged_ttft_stats(self._host_telemetries()),
                "per_host": [h.stats() for h in self.hosts.values()],
            }
