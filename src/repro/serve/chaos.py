"""Deterministic fault injection for the serving tier (DESIGN.md §14).

The cluster frontend (``serve/frontend.py``) must keep every request's
exactly-once/bit-identical guarantees while hosts die, stall, and drop
heartbeats — properties that only show up under faults. This module
provides the faults: a seeded, schedule-driven :class:`ChaosMonkey`
whose hooks the in-process :class:`~repro.serve.frontend.LocalHost`
consults at well-defined points of its step loop. Every hook is a pure
function of ``(host_id, step)`` plus a seeded RNG, so a chaos run is
exactly reproducible — the same schedule produces the same kill at the
same step with the same backoff jitter draw every time, which is what
lets tests assert bit-identical recovery instead of "it usually works".

Four fault families (mirroring what real multi-host serving sees):

* ``kill`` — the whole host hard-dies at local step N (the in-process
  analogue of ``kill -9``: it stops stepping, stops answering
  heartbeats, and strands whatever it held). Real SIGKILL coverage
  comes from subprocess hosts (``tests/dist_worker.py``); this hook
  gives the same observable behavior without fork/exec cost.
* ``raise`` — one live rank's decode raises at step N, exercising the
  scheduler's rank containment + requeue path *inside* a host that
  stays up (a partial failure, not a host death).
* ``drop-hb`` — the host answers ``n`` consecutive heartbeats with
  silence starting at step N while continuing to serve, exercising the
  suspect→recover and suspect→dead ladders independently of real
  failure.
* ``slow`` — every step is delayed by a fixed number of seconds (a
  straggler host), exercising the per-request watchdog.

Schedules come from :class:`ChaosConfig` directly or from the compact
CLI spec grammar used by ``launch/serve.py --chaos``::

    kill:HOST@STEP          raise:HOST@STEP
    drop-hb:HOST@STEP[xN]   slow:HOST@SECONDS       seed:K

comma-separated, e.g. ``"kill:0@12,slow:1@0.02,seed:7"``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass
class ChaosConfig:
    """A deterministic fault schedule. Host ids index the frontend's
    host list; steps are the HOST's local step counter (starting at 1
    on its first ``step()``), so a schedule is independent of how many
    ticks the frontend spends on other hosts."""
    seed: int = 0
    # host -> local step at which the host hard-dies
    kill_at_step: Dict[int, int] = field(default_factory=dict)
    # host -> local step at which one live rank's decode raises
    raise_in_decode: Dict[int, int] = field(default_factory=dict)
    # host -> (from_step, n_beats): miss n consecutive heartbeats
    # starting at from_step (n < 0 = forever)
    drop_heartbeat: Dict[int, Tuple[int, int]] = field(
        default_factory=dict)
    # host -> seconds of added latency per step (straggler)
    slow_host: Dict[int, float] = field(default_factory=dict)


class ChaosMonkey:
    """Runtime for a :class:`ChaosConfig` schedule. One-shot hooks
    (``kill_due``, ``decode_raise_due``) fire exactly once per host;
    the seeded RNG is exposed for callers that want reproducible
    randomness tied to the same schedule (property tests draw their
    kill/revive schedules from it)."""

    def __init__(self, cfg: Optional[ChaosConfig] = None):
        self.cfg = cfg or ChaosConfig()
        self.rng = random.Random(self.cfg.seed)
        self._killed: set = set()
        self._raised: set = set()

    def kill_due(self, host_id: int, step: int) -> bool:
        """True exactly once: at (or after — a host may skip steps while
        suspect) the scheduled kill step for this host."""
        at = self.cfg.kill_at_step.get(host_id)
        if at is None or host_id in self._killed or step < at:
            return False
        self._killed.add(host_id)
        return True

    def decode_raise_due(self, host_id: int, step: int) -> bool:
        """True exactly once at the scheduled raise step."""
        at = self.cfg.raise_in_decode.get(host_id)
        if at is None or host_id in self._raised or step < at:
            return False
        self._raised.add(host_id)
        return True

    def heartbeat_dropped(self, host_id: int, step: int) -> bool:
        """True while the host's scheduled heartbeat blackout covers
        ``step`` (the host's current local step at ping time)."""
        win = self.cfg.drop_heartbeat.get(host_id)
        if win is None:
            return False
        start, n = win
        if step < start:
            return False
        return n < 0 or step < start + n

    def delay_s(self, host_id: int) -> float:
        return self.cfg.slow_host.get(host_id, 0.0)


def parse_chaos_spec(spec: str) -> ChaosConfig:
    """Parse the ``--chaos`` CLI grammar (module docstring) into a
    :class:`ChaosConfig`. Empty/None spec = no faults."""
    cfg = ChaosConfig()
    if not spec:
        return cfg
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, rest = part.partition(":")
        kind = kind.strip().lower()
        try:
            if kind == "seed":
                cfg.seed = int(rest)
                continue
            host_s, _, arg = rest.partition("@")
            host = int(host_s)
            if kind == "kill":
                cfg.kill_at_step[host] = int(arg)
            elif kind == "raise":
                cfg.raise_in_decode[host] = int(arg)
            elif kind == "drop-hb":
                step_s, _, n_s = arg.partition("x")
                cfg.drop_heartbeat[host] = (int(step_s),
                                            int(n_s) if n_s else -1)
            elif kind == "slow":
                cfg.slow_host[host] = float(arg)
            else:
                raise ValueError(f"unknown chaos fault {kind!r}")
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"bad chaos spec entry {part!r}: {e} — grammar is "
                "kill:H@N, raise:H@N, drop-hb:H@N[xM], slow:H@SECS, "
                "seed:K") from e
    return cfg
