"""SASP structured pruning (paper §3.1).

Weights are viewed as grids of (block_k × block_n) tiles — the tile matched
to the accelerator (paper: systolic array size; here: the Pallas kernel /
MXU block). Tiles are scored by L1 norm and the lowest-scoring fraction is
zeroed **globally across the model**, which prunes layers heterogeneously
according to sensitivity (reproducing paper Fig 8: early FF layers lose far
more tiles than late ones).

The mask representation is per-weight: bool (KB, NB) with True = keep.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SASPConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Tiling helpers
# ---------------------------------------------------------------------------


def block_grid(shape: Tuple[int, int], bk: int, bn: int) -> Tuple[int, int]:
    K, N = shape
    if K % bk or N % bn:
        raise ValueError(f"weight {shape} not divisible by block ({bk},{bn})")
    return K // bk, N // bn


def effective_blocks(shape: Tuple[int, int], bk: int, bn: int
                     ) -> Tuple[int, int]:
    """Clamp the tile to the matrix dims (small MoE experts: a 512-wide
    expert with block 512 degenerates to whole-matrix granularity)."""
    K, N = shape
    return min(bk, K), min(bn, N)


def tile_l1(w: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    """L1 norm per (bk × bn) tile. w: (..., K, N) -> (..., KB, NB)."""
    *lead, K, N = w.shape
    KB, NB = K // bk, N // bn
    wb = jnp.abs(w.reshape(*lead, KB, bk, NB, bn).astype(jnp.float32))
    return wb.sum(axis=(-3, -1))


def upsample_mask(mask: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    """(..., KB, NB) bool -> (..., KB*bk, NB*bn)."""
    *lead, KB, NB = mask.shape
    m = jnp.broadcast_to(mask[..., :, None, :, None],
                         (*lead, KB, bk, NB, bn))
    return m.reshape(*lead, KB * bk, NB * bn)


def apply_block_mask(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """w: (..., K, N); mask: (..., KB, NB) bool. Zero pruned tiles without
    materializing an upsampled mask the size of w twice."""
    *lead, K, N = w.shape
    KB, NB = mask.shape[-2], mask.shape[-1]
    bk, bn = K // KB, N // NB
    wb = w.reshape(*lead, KB, bk, NB, bn)
    wb = wb * mask[..., :, None, :, None].astype(w.dtype)
    return wb.reshape(*lead, K, N)


# ---------------------------------------------------------------------------
# Global L1 tile selection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrunableLeaf:
    """One prunable weight matrix inside the model pytree."""

    path: Tuple                      # jax.tree_util key path
    shape: Tuple[int, ...]           # (..., K, N); leading dims = stacking
    bk: int                          # effective block (clamped to dims)
    bn: int


def find_prunable(params: Params, sasp: SASPConfig,
                  is_prunable: Callable[[Tuple], bool]) -> List[PrunableLeaf]:
    leaves = []
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        if not is_prunable(path):
            continue
        K, N = leaf.shape[-2], leaf.shape[-1]
        bk, bn = effective_blocks((K, N), sasp.block_k, sasp.block_n)
        if K % bk or N % bn:
            continue
        leaves.append(PrunableLeaf(path, leaf.shape, bk, bn))
    return leaves


def default_ffn_predicate(path: Tuple) -> bool:
    """Paper scope: feed-forward GEMMs only (attention is brittle)."""
    keys = "/".join(str(getattr(k, "key", k)) for k in path)
    return ("ffn" in keys or "moe" in keys) and keys.endswith("/w")


def all_gemm_predicate(path: Tuple) -> bool:
    keys = "/".join(str(getattr(k, "key", k)) for k in path)
    if "emb" in keys or "norm" in keys or "router" in keys:
        return False
    return keys.endswith("/w") or any(
        keys.endswith(s) for s in ("wq/w", "wk/w", "wv/w", "wo/w"))


def scope_predicate(sasp: SASPConfig) -> Callable[[Tuple], bool]:
    return default_ffn_predicate if sasp.scope == "ffn" else \
        all_gemm_predicate


def compute_sasp_masks(params: Params, sasp: SASPConfig,
                       is_prunable: Optional[Callable] = None
                       ) -> Dict[Tuple, jnp.ndarray]:
    """Global-L1 tile selection. Returns {tree-path: bool mask (..., KB, NB)}
    with exactly ``floor(sparsity × total_tiles)`` tiles zeroed model-wide
    (ties broken by flat order, deterministic)."""
    pred = is_prunable or scope_predicate(sasp)
    leaves = find_prunable(params, sasp, pred)
    if not leaves:
        return {}
    flat = dict(jax.tree_util.tree_flatten_with_path(params)[0])

    scores, sizes = [], []
    for leaf in leaves:
        w = flat[leaf.path]
        s = tile_l1(w, leaf.bk, leaf.bn)
        scores.append(s.reshape(-1))
        sizes.append(s.size)
    all_scores = jnp.concatenate(scores)
    total = all_scores.size
    n_prune = int(np.floor(sasp.sparsity * total))

    if n_prune == 0:
        keep_flat = jnp.ones((total,), dtype=bool)
    else:
        # threshold = n_prune-th smallest score; prune strictly-below plus
        # enough ties to hit the budget exactly (deterministic by index).
        order = jnp.argsort(all_scores, stable=True)
        keep_flat = jnp.ones((total,), dtype=bool).at[order[:n_prune]].set(
            False)

    masks: Dict[Tuple, jnp.ndarray] = {}
    off = 0
    for leaf, s, size in zip(leaves, scores, sizes):
        m = keep_flat[off:off + size]
        off += size
        w = flat[leaf.path]
        KB = w.shape[-2] // leaf.bk
        NB = w.shape[-1] // leaf.bn
        masks[leaf.path] = m.reshape(*w.shape[:-2], KB, NB)
    return masks


def prune_params(params: Params, sasp: SASPConfig,
                 is_prunable: Optional[Callable] = None
                 ) -> Tuple[Params, Dict[Tuple, jnp.ndarray]]:
    """Zero pruned tiles in-place (masked-dense path) and return the masks.
    Masks are also what the BSR/kernel paths compile from."""
    masks = compute_sasp_masks(params, sasp, is_prunable)
    if not masks:
        return params, masks

    def maybe_prune(path, leaf):
        if path in masks:
            return apply_block_mask(leaf, masks[path].astype(leaf.dtype)
                                    .astype(bool))
        return leaf

    pruned = jax.tree_util.tree_map_with_path(maybe_prune, params)
    return pruned, masks


def mask_sparsity(masks: Dict[Tuple, jnp.ndarray]) -> float:
    total = sum(int(np.prod(m.shape)) for m in masks.values())
    kept = sum(int(jnp.sum(m)) for m in masks.values())
    return 1.0 - kept / max(total, 1)


def per_matrix_sparsity(masks: Dict[Tuple, jnp.ndarray]
                        ) -> Dict[str, float]:
    """Heterogeneous per-weight pruning rates (paper Fig 8 evidence)."""
    out = {}
    for path, m in masks.items():
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        out[name] = 1.0 - float(jnp.mean(m.astype(jnp.float32)))
    return out


# ---------------------------------------------------------------------------
# Pruning schedule (gradual magnitude pruning for train-time SASP)
# ---------------------------------------------------------------------------


def cubic_sparsity_schedule(step: int, *, start_step: int, end_step: int,
                            final_sparsity: float) -> float:
    """Zhu & Gupta cubic ramp: s(t) = s_f (1 - (1 - t)^3)."""
    if step <= start_step:
        return 0.0
    if step >= end_step:
        return final_sparsity
    t = (step - start_step) / max(1, end_step - start_step)
    return final_sparsity * (1.0 - (1.0 - t) ** 3)
