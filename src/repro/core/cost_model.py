"""Analytic systolic-array cost model — the reproduction-tier stand-in for
the paper's gem5 (§3.2) + RTL synthesis (§4.2) tiers.

Weight-stationary tiling (paper Fig 3): a GEMM (M, K)·(K, N) is tiled into
(K/S)·(N/S) weight tiles; per tile the array pays
    c_w · S²/wpc   weight programming (wpc = weights per 32-bit bus word:
                   1 for FP32, 4 for INT8 — paper §3.2)
  + c_s · M        input/output streaming
  + c_f · S        skew-register fill/drain + instruction overhead
and a SASP-pruned tile is skipped entirely (paper Fig 3). The constants
below are least-squares fitted to the paper's Table 3 no-SASP speedups
(8 cells, FP32+INT8 × 4 sizes); the fit reproduces every cell within ~4 %:

    fp32  4×4  8.23 vs 8.42   | int8  4×4  8.39 vs 8.03
    fp32  8×8 19.12 vs 19.79  | int8  8×8 20.04 vs 20.18
    fp32 16  35.12 vs 35.22   | int8 16  38.33 vs 36.53
    fp32 32  51.90 vs 50.95   | int8 32  59.24 vs 61.33

Area/power are quadratic in S (paper §4.2), calibrated to Table 3 areas
(a₂ = 3.3e-3 mm²/PE ⇒ 8×8 = 0.21 mm², 32×32 = 3.37 mm² vs paper 3.34) and
to the power implied by Table 3 energies under the nominal CPU-baseline
runtime T_BASE (absolute watts depend on that normalization; ratios do not).
INT8 factors: area ×0.64, power ×0.72 (paper: 35.3 % / 19.5 % savings on
the multiplier, diluted over the full PE).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# ---- fitted constants (see module docstring / benchmarks/bench_table3) ----
C_W = 0.9599          # cycles per weight bus-word programmed
C_S = 0.5430          # cycles per activation streamed (in+out, pipelined)
C_F = 62.768          # per-tile fixed cycles (skew fill/drain + instrs)
CPI_MAC = 0.5836      # CPU cycles per MAC (SIMD baseline)
ALPHA_SW = 0.00875    # non-GEMM software fraction (Amdahl term)
FREQ_HZ = 1.0e9       # both CPU and array run at 1 GHz (paper Table 2)
T_BASE_S = 100.0      # nominal CPU-baseline runtime normalization

AREA_PER_PE_MM2 = 3.3e-3
POWER_PER_PE_W = 0.0092
INT8_AREA_FACTOR = 0.64
INT8_POWER_FACTOR = 0.72


@dataclass(frozen=True)
class SystolicConfig:
    size: int                     # S (array is S × S)
    quant: str = "fp32"           # "fp32" | "int8" (weights)

    @property
    def wpc(self) -> int:
        return 4 if self.quant == "int8" else 1

    @property
    def area_mm2(self) -> float:
        a = AREA_PER_PE_MM2 * self.size ** 2
        return a * (INT8_AREA_FACTOR if self.quant == "int8" else 1.0)

    @property
    def power_w(self) -> float:
        p = POWER_PER_PE_W * self.size ** 2
        return p * (INT8_POWER_FACTOR if self.quant == "int8" else 1.0)


@dataclass(frozen=True)
class GEMMWork:
    """One GEMM of the workload. ``sparsity`` is the SASP tile-pruning rate
    ON THIS GEMM (tile size = array size, so pruned tiles are skipped)."""

    M: int
    K: int
    N: int
    sparsity: float = 0.0

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N


def gemm_cycles(sa: SystolicConfig, g: GEMMWork) -> float:
    tiles = -(-g.K // sa.size) * (-(-g.N // sa.size))
    per_tile = (C_W * sa.size * sa.size / sa.wpc + C_S * g.M
                + C_F * sa.size)
    return tiles * (1.0 - g.sparsity) * per_tile


def workload_time_s(sa: SystolicConfig, gemms: Sequence[GEMMWork]) -> float:
    """End-to-end time: accelerated GEMMs + Amdahl software part."""
    t_gemm = sum(gemm_cycles(sa, g) for g in gemms) / FREQ_HZ
    t_sw = ALPHA_SW * cpu_time_s(gemms)
    return t_gemm + t_sw


def cpu_time_s(gemms: Sequence[GEMMWork]) -> float:
    macs = sum(g.macs for g in gemms)
    return macs * CPI_MAC / FREQ_HZ


def speedup_vs_cpu(sa: SystolicConfig, gemms: Sequence[GEMMWork]) -> float:
    t_cpu = cpu_time_s(gemms) * (1.0 + ALPHA_SW)
    return t_cpu / workload_time_s(sa, gemms)


def scale_to_t_base(gemms: Sequence[GEMMWork]) -> float:
    """Normalization so the CPU baseline takes T_BASE_S (Table 3 energies
    were reported for a fixed test set; we normalize the same way)."""
    return T_BASE_S / (cpu_time_s(gemms) * (1.0 + ALPHA_SW))


def energy_j(sa: SystolicConfig, gemms: Sequence[GEMMWork],
             scale: Optional[float] = None) -> float:
    s = scale_to_t_base(gemms) if scale is None else scale
    return sa.power_w * workload_time_s(sa, gemms) * s


# ---------------------------------------------------------------------------
# Transformer-encoder workload builder (the paper's ASR/MT case study)
# ---------------------------------------------------------------------------


def encoder_gemms(*, num_layers: int, d_model: int, d_ff: int, seq: int,
                  ffn_gated: bool = False,
                  ffn_sparsity: float = 0.0,
                  attn_sparsity: float = 0.0) -> List[GEMMWork]:
    """Per-inference GEMM list of a transformer encoder. SASP scope
    follows the paper: FF GEMMs carry ``ffn_sparsity``; attention
    projections carry ``attn_sparsity`` (0 in the paper's experiments)."""
    gs: List[GEMMWork] = []
    n_ff = 3 if ffn_gated else 2
    for _ in range(num_layers):
        for _ in range(4):       # q, k, v, o projections
            gs.append(GEMMWork(seq, d_model, d_model,
                               sparsity=attn_sparsity))
        gs.append(GEMMWork(seq, d_model, d_ff, sparsity=ffn_sparsity))
        if n_ff == 3:
            gs.append(GEMMWork(seq, d_model, d_ff, sparsity=ffn_sparsity))
        gs.append(GEMMWork(seq, d_ff, d_model, sparsity=ffn_sparsity))
    return gs


def model_gemms_from_config(cfg, seq: int, ffn_sparsity: float = 0.0
                            ) -> List[GEMMWork]:
    """GEMM list for one forward pass of an assigned-arch config (decoder
    LM). Attention score/context matmuls are excluded (not weight GEMMs —
    they are not SASP-prunable and, on the edge system, not tiled into the
    weight-stationary array)."""
    from repro.configs.base import FFN_MOE, MIXER_ATTN

    gs: List[GEMMWork] = []
    d = cfg.d_model
    hd = cfg.attn_head_dim
    for mk, fk in zip(cfg.layer_mixer_kinds(), cfg.layer_ffn_kinds()):
        if mk == MIXER_ATTN:
            gs.append(GEMMWork(seq, d, cfg.num_heads * hd))
            gs.append(GEMMWork(seq, d, cfg.num_kv_heads * hd))
            gs.append(GEMMWork(seq, d, cfg.num_kv_heads * hd))
            gs.append(GEMMWork(seq, cfg.num_heads * hd, d))
        else:
            s = cfg.ssm
            di = s.d_inner(d)
            gs.append(GEMMWork(seq, d, 2 * di + 2 * s.ngroups * s.state_dim
                               + s.num_heads(d)))
            gs.append(GEMMWork(seq, di, d))
        n_ff = 3 if cfg.ffn_gated else 2
        if fk == FFN_MOE:
            # active expert GEMMs per token: top_k experts
            eff_rows = seq * cfg.moe.top_k
            for _ in range(n_ff - 1):
                gs.append(GEMMWork(eff_rows, d, cfg.d_ff,
                                   sparsity=ffn_sparsity))
            gs.append(GEMMWork(eff_rows, cfg.d_ff, d,
                               sparsity=ffn_sparsity))
        else:
            for _ in range(n_ff - 1):
                gs.append(GEMMWork(seq, d, cfg.d_ff, sparsity=ffn_sparsity))
            gs.append(GEMMWork(seq, cfg.d_ff, d, sparsity=ffn_sparsity))
    return gs
