"""SASP ↔ model integration.

Three artifact kinds hang off model params (see models/ffn.py paths):

* ``sasp_masks`` overlays — bool (…, KB, NB) per weight, attached next to
  the weights they mask. Masks are NOT trainable: they live in a separate
  overlay pytree and are merged into a *view* of the params inside the loss
  (so ``jax.grad`` never sees bool leaves).
* INT8 ``qw`` entries — post-training weight-only quantization.
* ``sasp_bsr`` containers — block-compressed deployment weights consumed by
  the gathered-matmul and the Pallas tile-skip kernel.
"""
from __future__ import annotations

import copy
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SASPConfig
from repro.core.pruning import (
    compute_sasp_masks,
    mask_sparsity,
    scope_predicate,
)
from repro.core.quantization import quantize_int8
from repro.core.sparse import bsr_from_mask

Params = Dict[str, Any]


def _path_keys(path: Tuple) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def masks_to_overlay(masks: Dict[Tuple, jnp.ndarray]) -> Params:
    """{path-to-'w'-leaf: mask} -> nested overlay dict where each mask sits
    at (..., parent, 'sasp_masks', <matrix-name>). E.g. the mask for
    ``.../ffn/w1/w`` lands at ``.../ffn/sasp_masks/w1``."""
    overlay: Params = {}
    for path, mask in masks.items():
        keys = _path_keys(path)
        assert keys[-1] == "w", keys
        *parent, mat, _ = keys
        node = overlay
        for k in parent:
            node = node.setdefault(k, {})
        node.setdefault("sasp_masks", {})[mat] = mask
    return overlay


def merge_overlay(params: Params, overlay: Params) -> Params:
    """Recursively merge ``overlay`` into a shallow-copied view of params.
    Tuples (segment lists) are merged element-wise by index key."""
    if overlay is None:
        return params
    if isinstance(params, tuple):
        out = list(params)
        for k, v in overlay.items():
            i = int(k)
            out[i] = merge_overlay(out[i], v)
        return tuple(out)
    if isinstance(params, dict):
        out = dict(params)
        for k, v in overlay.items():
            if k in out and isinstance(v, dict) and isinstance(
                    out[k], (dict, tuple)):
                out[k] = merge_overlay(out[k], v)
            else:
                out[k] = v
        return out
    return overlay


def build_sasp_overlay(params: Params, sasp: SASPConfig,
                       is_prunable: Optional[Callable] = None
                       ) -> Tuple[Params, float]:
    """Global-L1 tile selection on the live params -> (overlay, achieved
    sparsity). Attach with ``merge_overlay(params, overlay)`` inside the
    loss (training) or bake permanently with ``prune_params`` (deploy)."""
    masks = compute_sasp_masks(params, sasp, is_prunable)
    return masks_to_overlay(masks), mask_sparsity(masks)


# ---------------------------------------------------------------------------
# Post-training INT8 (weight-only) — deployment params
# ---------------------------------------------------------------------------


def quantize_params(params: Params, sasp: SASPConfig,
                    is_quantizable: Optional[Callable] = None) -> Params:
    """Replace {'w': dense} with {'qw': QuantizedWeight} for every weight in
    scope. Biases/norms/embeddings stay fp."""
    pred = is_quantizable or scope_predicate(sasp)

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    target_parents = set()
    for path, leaf in flat:
        keys = _path_keys(path)
        if keys[-1] == "w" and getattr(leaf, "ndim", 0) >= 2 and pred(path):
            target_parents.add(keys[:-1])

    # ffn._materialize expects p[name] == {"qw": QuantizedWeight}; the
    # matrix dict itself is replaced.
    def rebuild2(node, prefix):
        if isinstance(node, tuple):
            return tuple(rebuild2(v, prefix + (str(i),))
                         for i, v in enumerate(node))
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                child_prefix = prefix + (k,)
                if isinstance(v, dict) and child_prefix in target_parents \
                        and "w" in v:
                    qw = quantize_int8(v["w"], sasp.block_k, sasp.block_n)
                    nv = {kk: vv for kk, vv in v.items() if kk != "w"}
                    nv["qw"] = qw
                    out[k] = nv
                else:
                    out[k] = rebuild2(v, child_prefix)
            return out
        return node

    return rebuild2(params, ())


# ---------------------------------------------------------------------------
# BSR deployment conversion (offline; numpy)
# ---------------------------------------------------------------------------


def bsr_overlay_from_masks(params: Params, masks: Dict[Tuple, jnp.ndarray],
                           sasp: SASPConfig) -> Params:
    """Build {..., 'sasp_bsr': {matrix: BlockSparseWeight}} overlays.

    2-D weights get a single container; 3-D layer stacks (L, K, N) — the
    scan-over-layers layout — get per-layer BSRs padded to a shared k_max
    and stacked so ``lax.scan`` slices them per layer. ≥4-D stacks (MoE
    expert grids) stay on the masked-dense path.
    """
    from repro.core.sparse import stack_bsr

    flat = dict(jax.tree_util.tree_flatten_with_path(params)[0])
    overlay: Params = {}
    for path, mask in masks.items():
        w = np.asarray(flat[path], np.float32)
        m = np.asarray(mask)
        keys = _path_keys(path)
        *parent, mat, _ = keys
        K, N = w.shape[-2:]
        KB, NB = m.shape[-2:]
        bk, bn = K // KB, N // NB
        if w.ndim == 2:
            bsr = bsr_from_mask(w, m, bk, bn, quantize=sasp.quantize)
        elif w.ndim == 3:
            k_max = max(1, int(m.sum(axis=-2).max()))
            bsr = stack_bsr([
                bsr_from_mask(w[i], m[i], bk, bn, quantize=sasp.quantize,
                              k_max=k_max)
                for i in range(w.shape[0])
            ])
        else:
            continue                     # MoE expert stacks: masked path
        node = overlay
        for k in parent:
            node = node.setdefault(k, {})
        node.setdefault("sasp_bsr", {})[mat] = bsr
    return overlay


def sasp_summary(overlay: Params) -> Dict[str, float]:
    masks = []

    def collect(node):
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "sasp_masks":
                    masks.extend(v.values())
                else:
                    collect(v)
        elif isinstance(node, tuple):
            for v in node:
                collect(v)

    collect(overlay)
    total = sum(int(np.prod(m.shape)) for m in masks)
    kept = sum(int(jnp.sum(m)) for m in masks)
    return {
        "n_masked_matrices": len(masks),
        "total_tiles": total,
        "kept_tiles": kept,
        "sparsity": 1.0 - kept / max(total, 1),
    }
