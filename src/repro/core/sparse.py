"""Weight containers for the SASP "skip" paths — and the reference for
the packed-container FORMAT (DESIGN.md §9–§10), so the layout is
discoverable without reading kernel code.

All containers are built offline from a concrete pruning mask (masks
are static by deployment time — pruning happens before the serving
graph is jitted), so every shape below is static.

**BlockSparseWeight (BSR-style, training/reference paths).** Per
output-column-block list of surviving K-blocks, padded to the
per-matrix max (`k_max`). Padding entries point at block 0 with zero
values, so no masking is needed in the inner loop. Consumers: the
pure-jnp gathered matmul (`bsr_matmul`) — FLOPs/bytes drop ∝ sparsity
*inside the compiled HLO*, which is how the dry-run roofline exhibits
the paper's saving without hardware — and the Pallas tile-skip kernel
path, which re-flattens it per call (why serving uses packed instead).

**Visit lists (the packed format's core idea).** A "visit" is one
surviving weight block the kernel will touch, in a fixed precomputed
order. `PackedSASPWeight` stores visits sorted by (n, k): all visits
of output-column block n are consecutive, so the kernel keeps one
VMEM-resident accumulator per output block and flushes it exactly once
(bias + activation fold into that flush). Every output column gets at
least one visit — a column with no surviving block carries one
zero-valued visit so its accumulator still initializes and flushes
`act(bias)`. `PackedFFN` visits are whole d_ff column-blocks of the
gated FFN (w1/w3 columns + the matching w2 row + bias slices), ordered
by d_ff block index; `jv` records that index per visit.

**Dup-last-visit nnz padding.** Containers stack per layer (the
`lax.scan`-over-layers layout) and per TP shard, which forces ONE
static visit count across all (layer × shard) lists. Shorter lists are
padded by REPEATING the last visit's coordinates with zero-valued
blocks: the appended visits share the final n-block, so the visit
order stays n-major, the accumulator neither re-initializes nor
flushes early — it just adds zeros and flushes the same value once
more. (`PackedFFN` pads with zero-w2v visits, `jv = -1`: a zero down-
projection contributes exactly nothing.) Padding visits are
recognizable as all-zero blocks / `jv < 0`, which is what the elastic
re-deploy fast path (`core.deploy.reshard_packed`) keys on.

**Shard kinds (TP partitioning of the visit schedule, DESIGN.md §10).**
`shard_kind="col"` splits visits by output-column block: each shard's
kn n-coordinates are shard-LOCAL, bias is reshaped per shard and stays
fused, outputs concatenate. `shard_kind="row"` splits by input-row
block (down-projections whose input is already column-sharded): kn
k-coordinates are shard-local, outputs are PARTIAL and need a
cross-shard reduction, so bias stays whole and is added after it — and
a row shard never carries `act` (a nonlinear epilogue on a partial sum
would be wrong). `PackedFFN` shards the d_ff visit schedule
contiguously (always row-like: partials + one post-reduction b2).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class BlockSparseWeight:
    """vals: (k_max, NB, bk, bn) blocks (padded); idx: (k_max, NB) int32
    source K-block index; shape/block are static aux data. Optional int8:
    vals int8 + scale (k_max, NB) fp32."""

    def __init__(self, vals, idx, shape: Tuple[int, int],
                 block: Tuple[int, int], scale=None):
        self.vals = vals
        self.idx = idx
        self.shape = tuple(shape)
        self.block = tuple(block)
        self.scale = scale

    def tree_flatten(self):
        return (self.vals, self.idx, self.scale), (self.shape, self.block)

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("vals"), self.vals), (ga("idx"), self.idx),
                (ga("scale"), self.scale)), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        vals, idx, scale = children
        shape, block = aux
        return cls(vals, idx, shape, block, scale)

    def __repr__(self):
        return (f"BlockSparseWeight(shape={self.shape}, "
                f"block={self.block}, k_max={self.k_max})")

    @property
    def k_max(self) -> int:
        return self.vals.shape[0]

    @property
    def density(self) -> float:
        K, N = self.shape
        bk, bn = self.block
        return self.k_max / (K // bk)   # upper bound incl. padding

    def nbytes(self) -> int:
        b = self.vals.size * self.vals.dtype.itemsize + self.idx.size * 4
        if self.scale is not None:
            b += self.scale.size * 4
        return b


jax.tree_util.register_pytree_with_keys(
    BlockSparseWeight,
    lambda b: b.tree_flatten_with_keys(),
    lambda aux, ch: BlockSparseWeight.tree_unflatten(aux, ch),
    flatten_func=lambda b: b.tree_flatten(),
)


class PackedSASPWeight:
    """Serving-time container: the COMPACT sorted block list the Pallas
    tile-skip kernel consumes directly (DESIGN.md §9), built once at load
    time by ``core.deploy``. Unlike :class:`BlockSparseWeight`, whose
    trace-compatible flattening re-emits the padded k_max × NB visit list
    on every call, this pytree stores the final (nnz, bk, bn) values +
    (2, nnz) coordinates — zero per-call repacking.

    vals: (…, nnz, bk, bn) surviving blocks (fp32/bf16, or int8 with
    ``scale``); kn: (…, 2, nnz) int32 visit coordinates sorted by (n, k);
    scale: optional (…, nnz) fp32 per-block dequant scales; bias:
    optional (…, N) fused into the kernel's flush epilogue. A leading
    layer axis (…) makes the container sliceable under ``lax.scan`` —
    per-layer packs are padded to one shared static nnz by
    ``kernels.sasp_gemm.ops.pad_block_list``.

    Static aux: shape (K, N), block (bk, bn), act (epilogue activation,
    folded into the last-visit flush; None = identity).

    TP sharding (DESIGN.md §10): ``shards > 1`` means every array carries
    an extra shard axis right before the visit dims — vals
    (…, tp, nnz_s, bk, bn), kn (…, tp, 2, nnz_s) — holding one
    shard-LOCAL visit list per TP rank. ``shard_kind`` says how the block
    list was partitioned: ``"col"`` by output-column block (kn n-coords
    are shard-local; bias reshaped to (…, tp, N/tp) and still fused),
    ``"row"`` by input-row block (kn k-coords shard-local; partial
    outputs need a cross-shard reduction, so bias stays (…, N) and is
    added after it). Per-shard lists are padded to one shared static
    nnz_s with the same dup-last-visit trick as the layer stacking.
    """

    def __init__(self, vals, kn, shape: Tuple[int, int],
                 block: Tuple[int, int], scale=None, bias=None,
                 act: Optional[str] = None, shards: int = 1,
                 shard_kind: Optional[str] = None):
        self.vals = vals
        self.kn = kn
        self.shape = tuple(shape)
        self.block = tuple(block)
        self.scale = scale
        self.bias = bias
        self.act = act
        self.shards = shards
        self.shard_kind = shard_kind

    def tree_flatten(self):
        return ((self.vals, self.kn, self.scale, self.bias),
                (self.shape, self.block, self.act, self.shards,
                 self.shard_kind))

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("vals"), self.vals), (ga("kn"), self.kn),
                (ga("scale"), self.scale), (ga("bias"), self.bias)), \
            (self.shape, self.block, self.act, self.shards,
             self.shard_kind)

    @classmethod
    def tree_unflatten(cls, aux, children):
        vals, kn, scale, bias = children
        shape, block, act, shards, shard_kind = aux
        return cls(vals, kn, shape, block, scale, bias, act, shards,
                   shard_kind)

    @property
    def nnz(self) -> int:
        return self.vals.shape[-3]

    def nbytes(self) -> int:
        b = self.vals.size * self.vals.dtype.itemsize + self.kn.size * 4
        if self.scale is not None:
            b += self.scale.size * 4
        if self.bias is not None:
            b += self.bias.size * 4
        return b

    def __repr__(self):
        sh = f", shards={self.shards}:{self.shard_kind}" \
            if self.shards > 1 else ""
        return (f"PackedSASPWeight(shape={self.shape}, "
                f"block={self.block}, nnz={self.nnz}, act={self.act}"
                f"{sh})")


jax.tree_util.register_pytree_with_keys(
    PackedSASPWeight,
    lambda p: p.tree_flatten_with_keys(),
    lambda aux, ch: PackedSASPWeight.tree_unflatten(aux, ch),
    flatten_func=lambda p: p.tree_flatten(),
)


class PackedFFN:
    """Whole-FFN deployment container for the fused gated-FFN kernel:
    surviving d_ff column-blocks of w1/w3 + matching w2 row-blocks +
    per-visit bias slices, one visit schedule, zero HBM intermediate.

    w1v/w3v: (…, nv, d, bf); w2v: (…, nv, bf, d); b1/b3: (…, nv, bf);
    b2: (…, d); s1/s3/s2: optional (…, nv) int8 scales. A leading layer
    axis makes it ``lax.scan``-sliceable (per-layer packs padded to one
    shared nv with zero-w2v visits). Static aux: d_model, d_ff, block_f,
    act.

    TP sharding (DESIGN.md §10): ``shards > 1`` adds a shard axis before
    the visit dims — w1v (…, tp, nv_s, d, bf) — partitioning the d_ff
    visit schedule contiguously by d_ff column-block shard. Each shard's
    w2 down-projection yields a PARTIAL (M, d); drivers reduce across
    shards (psum or reduce-scatter + int8 all-gather). b2 stays (…, d)
    and is added once, after the reduction.

    ``jv`` (…, nv) int32 records each visit's GLOBAL d_ff block index
    (-1 for padding/empty-shard visits). The kernels never read it — it
    exists so the container is self-describing: the elastic re-deploy
    fast path (``core.deploy.reshard_packed``) re-partitions the visit
    schedule for a new mesh shape by slicing on ``jv`` instead of
    rebuilding from the dense weights.
    """

    def __init__(self, w1v, w3v, w2v, b1, b3, b2, d_model: int,
                 d_ff: int, block_f: int, act: str, s1=None, s3=None,
                 s2=None, shards: int = 1, jv=None):
        self.w1v, self.w3v, self.w2v = w1v, w3v, w2v
        self.b1, self.b3, self.b2 = b1, b3, b2
        self.s1, self.s3, self.s2 = s1, s3, s2
        self.jv = jv
        self.d_model = d_model
        self.d_ff = d_ff
        self.block_f = block_f
        self.act = act
        self.shards = shards

    def tree_flatten(self):
        return ((self.w1v, self.w3v, self.w2v, self.b1, self.b3, self.b2,
                 self.s1, self.s3, self.s2, self.jv),
                (self.d_model, self.d_ff, self.block_f, self.act,
                 self.shards))

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        names = ("w1v", "w3v", "w2v", "b1", "b3", "b2", "s1", "s3",
                 "s2", "jv")
        return tuple((ga(n), getattr(self, n)) for n in names), \
            (self.d_model, self.d_ff, self.block_f, self.act,
             self.shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        w1v, w3v, w2v, b1, b3, b2, s1, s3, s2, jv = children
        d_model, d_ff, block_f, act, shards = aux
        return cls(w1v, w3v, w2v, b1, b3, b2, d_model, d_ff, block_f,
                   act, s1, s3, s2, shards, jv)

    @property
    def nv(self) -> int:
        return self.w1v.shape[-3]

    def __repr__(self):
        sh = f", shards={self.shards}" if self.shards > 1 else ""
        return (f"PackedFFN(d={self.d_model}, d_ff={self.d_ff}, "
                f"bf={self.block_f}, nv={self.nv}, act={self.act!r}"
                f"{sh})")


jax.tree_util.register_pytree_with_keys(
    PackedFFN,
    lambda p: p.tree_flatten_with_keys(),
    lambda aux, ch: PackedFFN.tree_unflatten(aux, ch),
    flatten_func=lambda p: p.tree_flatten(),
)


def bsr_from_mask(w: np.ndarray, mask: np.ndarray, bk: int, bn: int,
                  *, quantize: bool = False,
                  k_max: Optional[int] = None) -> BlockSparseWeight:
    """w: (K, N); mask: (KB, NB) bool (True = keep). Offline (numpy).
    ``k_max`` forces the padded depth (stacked per-layer BSRs must share
    one k_max so ``lax.scan`` can slice them)."""
    K, N = w.shape
    KB, NB = K // bk, N // bn
    assert mask.shape == (KB, NB), (mask.shape, (KB, NB))
    w = np.asarray(w, dtype=np.float32)
    mask = np.asarray(mask, dtype=bool)

    counts = mask.sum(axis=0)                       # kept K-blocks per col
    needed = int(counts.max()) if counts.size else 0
    k_max = max(needed, 1) if k_max is None else k_max
    assert k_max >= needed, (k_max, needed)

    vals = np.zeros((k_max, NB, bk, bn), dtype=np.float32)
    idx = np.zeros((k_max, NB), dtype=np.int32)
    wb = w.reshape(KB, bk, NB, bn)
    for n in range(NB):
        kept = np.nonzero(mask[:, n])[0]
        for j, kb in enumerate(kept):
            vals[j, n] = wb[kb, :, n, :]
            idx[j, n] = kb

    scale = None
    if quantize:
        amax = np.abs(vals).max(axis=(2, 3))        # (k_max, NB)
        scale = np.maximum(amax, 1e-12) / 127.0
        vals = np.clip(np.round(vals / scale[:, :, None, None]),
                       -127, 127).astype(np.int8)

    return BlockSparseWeight(
        vals=jnp.asarray(vals), idx=jnp.asarray(idx), shape=(K, N),
        block=(bk, bn),
        scale=None if scale is None else jnp.asarray(scale),
    )


def stack_bsr(bsrs) -> BlockSparseWeight:
    """Stack per-layer BSRs (same shape/block/k_max) along a new leading
    axis — the scan-over-layers layout."""
    b0 = bsrs[0]
    return BlockSparseWeight(
        vals=jnp.stack([b.vals for b in bsrs]),
        idx=jnp.stack([b.idx for b in bsrs]),
        shape=b0.shape, block=b0.block,
        scale=None if b0.scale is None else
        jnp.stack([b.scale for b in bsrs]),
    )


def flat_block_list(mask: np.ndarray) -> np.ndarray:
    """(nnz, 2) [k_block, n_block] pairs sorted by (n, k) — the visit order
    of the Pallas tile-skip kernel (accumulator re-inits when n changes)."""
    mask = np.asarray(mask, dtype=bool)
    ks, ns = np.nonzero(mask)
    order = np.lexsort((ks, ns))
    return np.stack([ks[order], ns[order]], axis=1).astype(np.int32)


def bsr_matmul(x: jnp.ndarray, w: BlockSparseWeight,
               *, compute_dtype=None) -> jnp.ndarray:
    """x: (M, K) @ block-sparse (K, N) -> (M, N), skipping pruned tiles.

    scan over k_max steps; each step gathers one K-block of x per output
    column-block and does a batched (M, bk) @ (bk, bn) — total FLOPs
    = 2·M·bk·bn·NB·k_max, i.e. dense FLOPs × (k_max / KB).
    """
    K, N = w.shape
    bk, bn = w.block
    KB, NB = K // bk, N // bn
    M = x.shape[0]
    dt = compute_dtype or x.dtype
    xb = jnp.moveaxis(x.reshape(M, KB, bk), 1, 0).astype(dt)   # (KB, M, bk)

    vals = w.vals
    if w.scale is not None:
        # fused dequant: int8 blocks × per-block scale
        vals = vals.astype(jnp.float32) * w.scale[:, :, None, None]
    vals = vals.astype(dt)

    def body(acc, step):
        v_j, idx_j = step                      # (NB, bk, bn), (NB,)
        xg = xb[idx_j]                         # (NB, M, bk)
        acc = acc + jnp.einsum("nmk,nkb->nmb", xg, v_j,
                               preferred_element_type=jnp.float32)
        return acc, None

    acc0 = jnp.zeros((NB, M, bn), dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (vals, w.idx))
    return jnp.moveaxis(acc, 0, 1).reshape(M, N).astype(x.dtype)


def bsr_to_dense(w: BlockSparseWeight) -> jnp.ndarray:
    """Reference reconstruction (tests)."""
    K, N = w.shape
    bk, bn = w.block
    KB, NB = K // bk, N // bn
    vals = w.vals
    if w.scale is not None:
        vals = vals.astype(jnp.float32) * w.scale[:, :, None, None]
    dense = jnp.zeros((KB, bk, NB, bn), dtype=jnp.float32)
    # padding entries have zero vals, so scatter-add is safe
    nb = jnp.arange(NB)
    for j in range(w.k_max):
        dense = dense.at[w.idx[j], :, nb, :].add(
            vals[j].astype(jnp.float32))
    return dense.reshape(K, N)
