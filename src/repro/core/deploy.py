"""Packed SASP deployment pipeline (DESIGN.md §9).

``deploy_packed`` is the single load-time conversion entry point for
serving: it walks a (pruned, optionally INT8) param tree and attaches
compact kernel-ready containers so that NO per-call repacking happens on
the serving path:

* per-matrix :class:`~repro.core.sparse.PackedSASPWeight` — the sorted
  (nnz, bk, bn) block list ``kernels.sasp_gemm`` consumes directly, with
  bias and activation folded into the kernel's flush epilogue. Attached
  under ``sasp_packed`` next to the weights (FFN w1/w2/w3 and, for
  ``scope="all"``, attention wq/wk/wv/wo).
* whole-FFN :class:`~repro.core.sparse.PackedFFN` — the fused gated-FFN
  schedule (single kernel launch, no HBM (M, d_ff) intermediate),
  attached under ``sasp_fused``.

Layer stacks (the ``lax.scan``-over-layers layout, leading ``repeat``
axis) are packed per layer and padded to one shared static nnz/nv so the
containers slice under scan exactly like every other stacked param
(padding = duplicated last visit with zero values; see
``kernels.sasp_gemm.ops.pad_block_list``).

Masks are recovered from the nonzero tile structure of the (already
pruned) weights, so the conversion needs nothing beyond the deployed
params themselves — pruning is static by deployment time (DESIGN.md §4).

Container format in one breath (full spec: ``core/sparse.py``
docstring): a sorted VISIT LIST per matrix — (n, k)-ordered surviving
blocks for ``PackedSASPWeight``, d_ff column-blocks with global
indices ``jv`` for ``PackedFFN`` — padded across layers/shards to one
shared static nnz by duplicating the last visit with zero values, and
TP-partitioned by SHARD KIND: ``col`` (shard-local output columns,
fused bias/act) or ``row`` (shard-local input rows, partial outputs,
bias after the cross-shard reduction, never an activation).

Mesh-shape changes do NOT rebuild from here: ``reshard_packed`` (end
of this file) slices and re-pads the existing visit lists to the new
shard count, bit-identically to a from-scratch ``deploy_packed`` —
see DESIGN.md §10 "Elastic re-deploy".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sparse import PackedFFN, PackedSASPWeight
from repro.kernels.sasp_gemm import ops as sasp_ops

Params = Dict[str, Any]

_ATTN_MATS = ("wq", "wk", "wv", "wo")
_FFN_MATS = ("w1", "w2", "w3")


def _fit_block(dim: int, want: int) -> int:
    """Largest block ≤ ``want`` that divides ``dim`` (mask granularity is
    free at deploy time — nonzero-tile detection is correct at any tile
    size, so we pick the best-fitting one)."""
    b = min(max(1, want), dim)
    while dim % b:
        b -= 1
    return b


def _dense_weight(entry: Params) -> Optional[np.ndarray]:
    """Materialize one matrix dict {w}|{qw} to dense fp32 (numpy)."""
    if not isinstance(entry, dict):
        return None
    if "w" in entry:
        return np.asarray(entry["w"], np.float32)
    if "qw" in entry:
        qw = entry["qw"]
        bk, bn = qw.block
        q = np.asarray(qw.q, np.float32)
        sc = np.asarray(qw.scale, np.float32)
        K, N = q.shape[-2:]
        KB, NB = K // bk, N // bn
        qb = q.reshape(*q.shape[:-2], KB, bk, NB, bn)
        qb = qb * sc[..., :, None, :, None]
        return qb.reshape(q.shape)
    return None


def pack_weight(w: np.ndarray, *, block_k: int, block_n: int,
                bias: Optional[np.ndarray] = None,
                act: Optional[str] = None,
                quantize: bool = False,
                tp: int = 1,
                shard_kind: str = "col") -> PackedSASPWeight:
    """(K, N) or layer-stacked (L, K, N) dense weight (pruned tiles
    already zeroed) -> PackedSASPWeight. Stacked inputs are packed per
    layer and padded to a shared nnz (dup-last-visit zero padding).

    ``tp > 1`` partitions each layer's sorted block list into ``tp``
    shard-local lists (DESIGN.md §10): ``shard_kind="col"`` slices the
    output-column blocks (kn n-coords become shard-local; each shard's
    pruning savings stay local instead of averaging away), ``"row"``
    slices the input-row blocks (for down-projections whose INPUT is
    already column-sharded; outputs are partial and need a reduction,
    so ``act`` must be None and ``bias`` is kept whole, to be added
    after the reduction). All (layer × shard) lists share one static
    nnz via the same dup-last-visit padding as the scan layout.
    """
    w = np.asarray(w, np.float32)
    if w.ndim == 2:
        w = w[None]
        bias = None if bias is None else np.asarray(bias)[None]
        squeeze = True
    else:
        squeeze = False
    L, K, N = w.shape
    bk = _fit_block(K, block_k)
    bn = _fit_block(N, block_n)
    KB, NB = K // bk, N // bn
    assert shard_kind in ("col", "row"), shard_kind
    if tp > 1:
        blocks = NB if shard_kind == "col" else KB
        assert blocks % tp == 0, (shard_kind, blocks, tp)
        assert shard_kind == "col" or act is None, \
            "row-sharded outputs are partial; no nonlinear epilogue"

    def _slice(wi, s):
        if tp == 1:
            return wi
        if shard_kind == "col":
            ns = N // tp
            return wi[:, s * ns:(s + 1) * ns]
        ks = K // tp
        return wi[s * ks:(s + 1) * ks, :]

    packs = []                            # [L][tp] of (vals, kn, scale)
    for i in range(L):
        layer = []
        for s in range(tp):
            ws = _slice(w[i], s)
            kb, nb = ws.shape[0] // bk, ws.shape[1] // bn
            m = np.any(ws.reshape(kb, bk, nb, bn), axis=(1, 3))
            layer.append(sasp_ops.build_kernel_weight(
                ws, m, bk, bn, quantize=quantize))
        packs.append(layer)
    nnz = max(np.asarray(p[0]).shape[0] for lp in packs for p in lp)

    def _pad_stack(layer):
        vs, ks, ss = [], [], []
        for v, kn, sc in layer:
            v, kn, sc = sasp_ops.pad_block_list(
                np.asarray(v), np.asarray(kn),
                None if sc is None else np.asarray(sc), nnz)
            vs.append(v)
            ks.append(kn)
            ss.append(sc)
        if tp == 1:
            return vs[0], ks[0], ss[0]
        return (np.stack(vs), np.stack(ks),
                None if ss[0] is None else np.stack(ss))

    per_layer = [_pad_stack(lp) for lp in packs]
    vals = jnp.asarray(np.stack([p[0] for p in per_layer]))
    kn = jnp.asarray(np.stack([p[1] for p in per_layer]))
    scale = None if per_layer[0][2] is None else jnp.asarray(
        np.stack([p[2] for p in per_layer]).astype(np.float32))
    b = None
    if bias is not None:
        b = np.asarray(bias, np.float32)
        if tp > 1 and shard_kind == "col":     # fused per column shard
            b = b.reshape(L, tp, N // tp)
        b = jnp.asarray(b)
    if squeeze:
        vals, kn = vals[0], kn[0]
        scale = None if scale is None else scale[0]
        b = None if b is None else b[0]
    return PackedSASPWeight(vals, kn, (K, N), (bk, bn), scale=scale,
                            bias=b, act=act, shards=tp,
                            shard_kind=shard_kind if tp > 1 else None)


def pack_ffn(w1: np.ndarray, w3: np.ndarray, w2: np.ndarray, *,
             block_f: int, act: str,
             b1: Optional[np.ndarray] = None,
             b3: Optional[np.ndarray] = None,
             b2: Optional[np.ndarray] = None,
             quantize: bool = False,
             tp: int = 1) -> PackedFFN:
    """Gated-FFN triple (each (d, F)/(F, d) or layer-stacked with a
    leading L axis) -> PackedFFN for the fused kernel.

    ``tp > 1`` partitions the d_ff visit schedule contiguously by d_ff
    column-block shard (DESIGN.md §10): shard s packs d_ff columns
    [s·F/tp, (s+1)·F/tp) of w1/w3 and the matching w2 rows, so every
    shard runs the fused kernel over ITS surviving blocks only and
    yields a partial (M, d). b2 is NOT folded into the per-shard flush
    (it would be added tp times under the cross-shard reduction); it
    stays whole on the container for the driver to add once.
    """
    w1 = np.asarray(w1, np.float32)
    squeeze = w1.ndim == 2

    def _lift(a):
        return None if a is None else np.asarray(a, np.float32)[
            None] if squeeze else np.asarray(a, np.float32)

    if squeeze:
        w1 = w1[None]
    w3 = _lift(w3)
    w2 = _lift(w2)
    b1, b3, b2 = _lift(b1), _lift(b3), _lift(b2)
    L, d, F = w1.shape
    bf = _fit_block(F, block_f)
    if tp > 1:
        assert (F // bf) % tp == 0, (F, bf, tp)

    def _build(i, s):
        if tp == 1:
            sl = slice(None)
        else:
            fs = F // tp
            sl = slice(s * fs, (s + 1) * fs)
        pk = sasp_ops.build_fused_ffn(
            w1[i][:, sl], w3[i][:, sl], w2[i][sl, :], block_f=bf,
            b1=None if b1 is None else b1[i][sl],
            b3=None if b3 is None else b3[i][sl],
            b2=None if (b2 is None or tp > 1) else b2[i],
            quantize=quantize, return_visits=True)
        jv = np.asarray(pk[-1])
        if tp > 1:          # shard-local keep indices -> global d_ff blocks
            jv = np.where(jv >= 0, jv + s * ((F // bf) // tp), -1)
        return pk[:-1] + (jv.astype(np.int32),)

    packs = [_build(i, s) for i in range(L) for s in range(tp)]
    nv = max(np.asarray(p[0]).shape[0] for p in packs)

    def _pad_visits(p):
        """Append zero visits up to the shared nv (zero w2v => padded
        visits contribute exactly nothing) — pack once, pad in place."""
        w1v, w3v, w2v, b1v, b3v, b2v, sc, jv = [
            np.asarray(a) if a is not None and not isinstance(a, tuple)
            else a for a in p]
        pad = nv - w1v.shape[0]
        if pad:
            def z(a):
                return np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
            w1v, w3v, w2v = z(w1v), z(w3v), z(w2v)
            b1v, b3v = z(b1v), z(b3v)
            jv = np.concatenate([jv, np.full((pad,), -1, np.int32)])
            if sc is not None:
                sc = tuple(z(np.asarray(s)) for s in sc)
        return w1v, w3v, w2v, b1v, b3v, b2v, sc, jv

    repacked = [_pad_visits(p) for p in packs]

    def _stack(idx):
        a = np.stack([np.asarray(p[idx]) for p in repacked])
        if tp > 1:                         # (L·tp, …) -> (L, tp, …)
            a = a.reshape((L, tp) + a.shape[1:])
        return jnp.asarray(a)

    w1v, w3v, w2v = _stack(0), _stack(1), _stack(2)
    b1v, b3v = _stack(3), _stack(4)
    jv = _stack(7)
    if tp > 1:
        # per-shard packs carried zero b2 placeholders; keep the real
        # bias whole — drivers add it once after the shard reduction
        b2v = jnp.asarray(b2 if b2 is not None
                          else np.zeros((L, d), np.float32))
    else:
        b2v = _stack(5)
    if repacked[0][6] is None:
        s1 = s3 = s2 = None
    else:
        def _stack_s(idx):
            a = np.stack([np.asarray(p[6][idx]) for p in repacked])
            if tp > 1:
                a = a.reshape((L, tp) + a.shape[1:])
            return jnp.asarray(a)
        s1, s3, s2 = _stack_s(0), _stack_s(1), _stack_s(2)
    if squeeze:
        w1v, w3v, w2v = w1v[0], w3v[0], w2v[0]
        b1v, b3v, b2v = b1v[0], b3v[0], b2v[0]
        jv = jv[0]
        s1 = None if s1 is None else s1[0]
        s3 = None if s3 is None else s3[0]
        s2 = None if s2 is None else s2[0]
    return PackedFFN(w1v, w3v, w2v, b1v, b3v, b2v, d_model=d, d_ff=F,
                     block_f=bf, act=act, s1=s1, s3=s3, s2=s2,
                     shards=tp, jv=jv)


# ---------------------------------------------------------------------------
# Apply (serving hot path)
# ---------------------------------------------------------------------------


def packed_matmul(x: jnp.ndarray, pw: PackedSASPWeight, *,
                  block_m: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """(…, K) @ packed weight -> (…, N) through the tile-skip kernel,
    bias + activation fused into the flush. Zero per-call repacking."""
    scales = None if pw.scale is None else pw.scale
    return sasp_ops.sasp_matmul_packed(
        x, pw.vals, pw.kn, scales, n=pw.shape[1], block_m=block_m,
        bias=pw.bias, act=pw.act, interpret=interpret)


def packed_ffn_apply(x: jnp.ndarray, pf: PackedFFN, *,
                     block_m: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """Whole gated FFN in one fused kernel launch."""
    scales = None if pf.s1 is None else (pf.s1, pf.s3, pf.s2)
    return sasp_ops.fused_ffn_matmul(
        x, pf.w1v, pf.w3v, pf.w2v, pf.b1, pf.b3, pf.b2, scales=scales,
        act=pf.act, block_m=block_m, interpret=interpret)


# ---------------------------------------------------------------------------
# deploy_packed — the load-time conversion entry point
# ---------------------------------------------------------------------------


# TP-eligibility gates, SHARED by deploy_packed and reshard_packed so
# the two walks cannot silently diverge (the parity tests in
# tests/test_deploy_packed.py assert bit-identity between them; a rule
# added to only one side would break that contract for configs the
# tests don't cover).


def _shard_blocks(kind: str, K: int, N: int, bk: int, bn: int) -> int:
    """Block count along the dimension a shard kind partitions."""
    return (N // bn) if kind == "col" else (K // bk)


def _fused_tp(d_ff: int, block_f: int, tp: int) -> int:
    """Shard count the fused d_ff visit schedule supports (1 = stay
    unsharded)."""
    return tp if tp > 1 and (d_ff // block_f) % tp == 0 else 1


def _attn_tp(cfg: ModelConfig, tp: int) -> int:
    """col shards of wq/wk/wv must land on head boundaries (RoPE and
    the (B, S, H, D) reshape are per head)."""
    return tp if (tp > 1 and cfg.num_heads % tp == 0
                  and cfg.num_kv_heads % tp == 0) else 1


def _tp_fits(w: np.ndarray, kind: str, cfg: ModelConfig, tp: int) -> bool:
    """Does the matrix's block grid split evenly into ``tp`` shards?"""
    K, N = w.shape[-2:]
    bk = _fit_block(K, cfg.sasp.block_k)
    bn = _fit_block(N, cfg.sasp.block_n)
    return _shard_blocks(kind, K, N, bk, bn) % tp == 0


def _pack_matrix_group(node: Params, names, cfg: ModelConfig,
                       quantize: bool, act_for: Dict[str, Optional[str]],
                       tp: int = 1,
                       kinds: Optional[Dict[str, str]] = None
                       ) -> Optional[Dict[str, PackedSASPWeight]]:
    """Pack a group of matrices that serve together. TP sharding is
    all-or-nothing across the group (the sharded driver keeps the whole
    group inside one shard_map body, so every matrix must split)."""
    kinds = kinds or {}
    mats = []
    for name in names:
        entry = node.get(name)
        w = None if entry is None else _dense_weight(entry)
        if w is None:
            continue
        if w.ndim not in (2, 3):        # MoE expert grids etc.
            return None
        bias = None
        if isinstance(entry, dict) and "b" in entry:
            bias = np.asarray(entry["b"], np.float32)
        mats.append((name, w, bias))
    if tp > 1 and any(not _tp_fits(w, kinds.get(n, "col"), cfg, tp)
                      for n, w, _ in mats):
        tp = 1
    out = {}
    for name, w, bias in mats:
        out[name] = pack_weight(
            w, block_k=cfg.sasp.block_k, block_n=cfg.sasp.block_n,
            bias=bias, act=act_for.get(name), quantize=quantize,
            tp=tp, shard_kind=kinds.get(name, "col"))
    return out or None


_FFN_KINDS = {"w1": "col", "w3": "col", "w2": "row"}
_ATTN_KINDS = {"wq": "col", "wk": "col", "wv": "col", "wo": "row"}


def _deploy_slot(slot: Params, cfg: ModelConfig, *, quantize: bool,
                 fuse_ffn: bool, attn: bool, tp: int = 1) -> Params:
    slot = dict(slot)

    ffn = slot.get("ffn")
    if (isinstance(ffn, dict) and "w1" in ffn and "w2" in ffn
            and "router" not in ffn):       # MoE expert grids: masked path
        ffn = {k: v for k, v in ffn.items()
               if k not in ("sasp_bsr",)}      # packed replaces BSR
        gated = "w3" in ffn
        w1 = _dense_weight(ffn.get("w1"))
        w2 = _dense_weight(ffn.get("w2"))
        w3 = _dense_weight(ffn.get("w3")) if gated else None
        if w1 is not None and w2 is not None and w1.ndim in (2, 3):
            b2 = ffn["w2"].get("b") if isinstance(ffn["w2"], dict) \
                else None
            if gated and fuse_ffn and w3 is not None:
                F = w1.shape[-1]
                tp_f = _fused_tp(F, _fit_block(F, cfg.sasp.block_n), tp)
                ffn["sasp_fused"] = pack_ffn(
                    w1, w3, w2, block_f=cfg.sasp.block_n, act=cfg.act,
                    b1=ffn["w1"].get("b"), b3=ffn["w3"].get("b"),
                    b2=b2, quantize=quantize, tp=tp_f)
            else:
                # per-matrix packed: act folds into w1's flush epilogue,
                # the gate product (if any) stays in jnp (models/ffn.py)
                act_for = {"w1": cfg.act}
                packed = _pack_matrix_group(
                    ffn, _FFN_MATS, cfg, quantize, act_for, tp=tp,
                    kinds=_FFN_KINDS)
                if packed is not None:
                    ffn["sasp_packed"] = packed
            slot["ffn"] = ffn

    mixer = slot.get("mixer")
    if attn and isinstance(mixer, dict) and all(
            m in mixer for m in _ATTN_MATS):
        mixer = dict(mixer)
        tp_a = _attn_tp(cfg, tp)
        packed = _pack_matrix_group(mixer, _ATTN_MATS, cfg, quantize, {},
                                    tp=tp_a, kinds=_ATTN_KINDS)
        if packed is not None:
            mixer["sasp_packed"] = packed
            slot["mixer"] = mixer

    return slot


def deploy_packed(params: Params, cfg: ModelConfig, *,
                  quantize: Optional[bool] = None,
                  fuse_ffn: bool = True,
                  attn: Optional[bool] = None,
                  mesh=None,
                  tp: Optional[int] = None) -> Tuple[Params,
                                                     ModelConfig]:
    """Convert a (pruned) param tree into packed serving form.

    Returns ``(params', cfg')`` where every dense/MoE-free FFN (and, for
    ``scope="all"`` or ``attn=True``, every attention projection) carries
    a kernel-ready packed container, and ``cfg'`` has
    ``sasp.path="kernel"`` so the model routes through them. Dense
    weights stay in the tree as the source of truth (XLA dead-code
    eliminates them from the serving graph); ``sasp_bsr`` overlays are
    dropped — the compact block list replaces the padded k_max × NB
    trace-time list.

    quantize: pack values as int8 + per-block scales (default: follow
    ``cfg.sasp.quantize``). fuse_ffn: use the whole-FFN fused container
    for gated FFNs (False = per-matrix packed GEMMs). mesh / tp:
    TP-shard every visit list by output-block shard for the mesh's
    'model' axis (DESIGN.md §10) — each shard carries only ITS surviving
    blocks, so per-shard pruning savings stay local; matrices whose
    block grid does not divide fall back to unsharded containers.
    """
    quantize = cfg.sasp.quantize if quantize is None else quantize
    attn = (cfg.sasp.scope == "all") if attn is None else attn
    if tp is None:
        tp = mesh.shape.get("model", 1) if mesh is not None else 1

    out = dict(params)
    segs = []
    for seg in params.get("segments", ()):
        new_seg = {}
        for slot_name, slot in seg.items():
            new_seg[slot_name] = _deploy_slot(
                slot, cfg, quantize=quantize, fuse_ffn=fuse_ffn,
                attn=attn, tp=tp)
        segs.append(new_seg)
    out["segments"] = tuple(segs)
    cfg = dataclasses.replace(
        cfg, sasp=dataclasses.replace(cfg.sasp, enabled=True,
                                      path="kernel"))
    return out, cfg


_PACKED_OVERLAYS = ("sasp_packed", "sasp_fused", "sasp_bsr")


def strip_packed(params: Params) -> Params:
    """Drop every deployment overlay (packed / fused / BSR containers)
    from a deployed tree, leaving the dense source-of-truth weights —
    the starting point for re-deploying the SAME weights at a different
    fidelity (``draft_pack``, ``reshard_packed`` rebuilds)."""
    out = dict(params)
    segs = []
    for seg in params.get("segments", ()):
        new_seg = {}
        for slot_name, slot in seg.items():
            slot = dict(slot)
            for part in ("ffn", "mixer"):
                sub = slot.get(part)
                if isinstance(sub, dict) and any(
                        k in sub for k in _PACKED_OVERLAYS):
                    slot[part] = {k: v for k, v in sub.items()
                                  if k not in _PACKED_OVERLAYS}
            new_seg[slot_name] = slot
        segs.append(new_seg)
    out["segments"] = tuple(segs)
    return out


def draft_pack(params: Params, cfg: ModelConfig, *,
               sparsity: float, quantize: bool = False,
               fuse_ffn: bool = True, mesh=None,
               tp: Optional[int] = None) -> Tuple[Params, ModelConfig]:
    """Self-speculation drafter on the sparsity ladder (DESIGN.md §17).

    Re-prune the DEPLOYED weights at a HIGHER sparsity and pack the
    result: the returned ``(params', cfg')`` is a cheap drafter for the
    full-fidelity target built from the SAME weights — identical
    architecture, so identical cache geometry, so drafter and target
    share one paged KV pool. Greedy exactness never depends on the
    drafter (every emitted token is a target argmax); drafter fidelity
    only moves the acceptance rate.

    sparsity: the drafter's global tile sparsity (normally well above
    the target's — equal or lower is legal but buys nothing).
    quantize: additionally pack drafter values as int8 + per-block
    scales (the ladder's other axis)."""
    if not 0.0 < float(sparsity) < 1.0:
        raise ValueError(
            f"draft sparsity={sparsity} must lie in (0, 1)")
    from repro.core.pruning import prune_params
    dsasp = dataclasses.replace(
        cfg.sasp, enabled=True, sparsity=float(sparsity),
        quantize=bool(quantize))
    dcfg = dataclasses.replace(cfg, sasp=dsasp)
    dense = strip_packed(params)
    pruned, _ = prune_params(dense, dsasp)
    return deploy_packed(pruned, dcfg, quantize=bool(quantize),
                         fuse_ffn=fuse_ffn, mesh=mesh, tp=tp)


# ---------------------------------------------------------------------------
# Elastic re-deploy: reshard existing containers (ROADMAP fast path)
# ---------------------------------------------------------------------------


def _zero_block_scale() -> np.float32:
    """Per-block int8 scale of an all-zero block, computed with the SAME
    array arithmetic as build_kernel_weight / build_fused_ffn so
    resharded containers stay bit-identical to from-scratch packs."""
    amax = np.zeros((1,), np.float32)
    return (np.maximum(amax, 1e-12) / 127.0).astype(np.float32)[0]


def _reshard_weight(pw: PackedSASPWeight, tp: int,
                    kind: str) -> PackedSASPWeight:
    """Slice-and-pad one packed matrix to ``tp`` shards: live visits are
    re-binned by output-column (col) / input-row (row) block shard with
    coordinates remapped shard-local, empty output columns get their
    zero flush visit, and per-(layer, shard) lists re-pad to one shared
    nnz — no dense/BSR rebuild. Bit-identical to ``pack_weight`` on the
    sliced dense weight."""
    K, N = pw.shape
    bk, bn = pw.block
    KB, NB = K // bk, N // bn
    quant = pw.scale is not None
    assert kind in ("col", "row"), kind
    assert tp == 1 or kind == "col" or pw.act is None

    vals = np.asarray(pw.vals)
    kn = np.asarray(pw.kn)
    sc = np.asarray(pw.scale) if quant else None
    stacked = vals.ndim == (5 if pw.shards > 1 else 4)
    if not stacked:
        vals, kn = vals[None], kn[None]
        sc = None if sc is None else sc[None]
    if pw.shards == 1:
        vals, kn = vals[:, None], kn[:, None]
        sc = None if sc is None else sc[:, None]
    L = vals.shape[0]

    # 1) merge shards back to per-layer GLOBAL live-visit lists (zero
    #    blocks are padding or empty-column flush entries — both get
    #    rebuilt below, so dropping every zero block is lossless)
    layers = []
    for li in range(L):
        ks, ns, vs, ss = [], [], [], []
        for s in range(pw.shards):
            v = vals[li, s]
            live = np.any(v != 0, axis=(1, 2))
            k = kn[li, s][0].astype(np.int64)
            n = kn[li, s][1].astype(np.int64)
            if pw.shards > 1:
                if (pw.shard_kind or kind) == "col":
                    n = n + s * (NB // pw.shards)
                else:
                    k = k + s * (KB // pw.shards)
            ks.append(k[live])
            ns.append(n[live])
            vs.append(v[live])
            if quant:
                ss.append(sc[li, s][live])
        layers.append((np.concatenate(ks), np.concatenate(ns),
                       np.concatenate(vs),
                       np.concatenate(ss) if quant else None))

    # 2) re-bin to the new shards, exactly as build_kernel_weight would
    #    pack the sliced dense weight
    NB_s = NB // tp if kind == "col" else NB
    KB_s = KB if kind == "col" else KB // tp
    assert (NB % tp == 0) if kind == "col" else (KB % tp == 0), (
        kind, pw.shape, pw.block, tp)
    packs = []                             # [L][tp] of (vals, kn, scale)
    for ks, ns, v, s_ in layers:
        row = []
        for s in range(tp):
            if kind == "col":
                sel = (ns >= s * NB_s) & (ns < (s + 1) * NB_s)
                k_loc, n_loc = ks[sel], ns[sel] - s * NB_s
            else:
                sel = (ks >= s * KB_s) & (ks < (s + 1) * KB_s)
                k_loc, n_loc = ks[sel] - s * KB_s, ns[sel]
            v_loc = v[sel]
            s_loc = s_[sel] if quant else None
            # zero flush visit per empty output column + (n, k) sort —
            # the one shared convention (ops.flush_sorted_order)
            k_loc, n_loc, order, n_flush = sasp_ops.flush_sorted_order(
                k_loc, n_loc, NB_s)
            if n_flush:
                v_loc = np.concatenate(
                    [v_loc, np.zeros((n_flush, bk, bn), v_loc.dtype)])
                if quant:
                    s_loc = np.concatenate(
                        [s_loc, np.full((n_flush,), _zero_block_scale(),
                                        np.float32)])
            row.append((v_loc[order],
                        np.stack([k_loc[order], n_loc[order]])
                        .astype(np.int32),
                        s_loc[order] if quant else None))
        packs.append(row)

    # 3) shared-nnz padding + stacking (mirror of pack_weight)
    nnz = max(p[0].shape[0] for lp in packs for p in lp)
    per_layer = []
    for lp in packs:
        vs, kks, sss = [], [], []
        for v, kkn, s_ in lp:
            v, kkn, s_ = sasp_ops.pad_block_list(v, kkn, s_, nnz)
            vs.append(v)
            kks.append(kkn)
            sss.append(s_)
        if tp == 1:
            per_layer.append((vs[0], kks[0], sss[0]))
        else:
            per_layer.append((np.stack(vs), np.stack(kks),
                              None if sss[0] is None else np.stack(sss)))
    new_vals = jnp.asarray(np.stack([p[0] for p in per_layer]))
    new_kn = jnp.asarray(np.stack([p[1] for p in per_layer]))
    new_sc = None if not quant else jnp.asarray(
        np.stack([p[2] for p in per_layer]).astype(np.float32))

    bias = None
    if pw.bias is not None:
        b = np.asarray(pw.bias, np.float32)
        b = b.reshape(b.shape[:-2] + (-1,)) \
            if pw.shards > 1 and pw.shard_kind == "col" else b
        if not stacked:
            b = b[None]
        if tp > 1 and kind == "col":
            b = b.reshape(L, tp, N // tp)
        bias = jnp.asarray(b)
    if not stacked:
        new_vals, new_kn = new_vals[0], new_kn[0]
        new_sc = None if new_sc is None else new_sc[0]
        bias = None if bias is None else bias[0]
    return PackedSASPWeight(new_vals, new_kn, (K, N), (bk, bn),
                            scale=new_sc, bias=bias, act=pw.act,
                            shards=tp,
                            shard_kind=kind if tp > 1 else None)


def _reshard_ffn(pf: PackedFFN, tp: int) -> PackedFFN:
    """Slice-and-pad the fused gated-FFN schedule to ``tp`` d_ff shards
    using the stored global visit indices ``jv`` (so no dense rebuild
    and exact agreement with ``pack_ffn`` on the sliced weights)."""
    assert pf.jv is not None, \
        "container predates jv visit indices; rebuild via deploy_packed"
    d, bf = pf.d_model, pf.block_f
    FB = pf.d_ff // bf
    assert tp == 1 or FB % tp == 0, (pf.d_ff, bf, tp)
    quant = pf.s1 is not None

    names = ["w1v", "w3v", "w2v", "b1", "b3", "jv"] + (
        ["s1", "s3", "s2"] if quant else [])
    base = {"w1v": 3, "w3v": 3, "w2v": 3, "b1": 2, "b3": 2, "jv": 1,
            "s1": 1, "s3": 1, "s2": 1}
    arrs = {n: np.asarray(getattr(pf, n)) for n in names}
    stacked = arrs["w1v"].ndim == base["w1v"] + (
        2 if pf.shards > 1 else 1)

    def norm(n):
        a = arrs[n]
        if not stacked:
            a = a[None]
        if pf.shards == 1:
            a = a[:, None]
        return a

    A = {n: norm(n) for n in names}
    L = A["w1v"].shape[0]
    b2 = np.asarray(pf.b2, np.float32)
    if not stacked:
        b2 = b2[None]

    def zero_visit():
        z = {"w1v": np.zeros((1, d, bf), np.float32),
             "w3v": np.zeros((1, d, bf), np.float32),
             "w2v": np.zeros((1, bf, d), np.float32),
             "b1": np.zeros((1, bf), np.float32),
             "b3": np.zeros((1, bf), np.float32),
             "jv": np.full((1,), -1, np.int32)}
        if quant:
            zs = _zero_block_scale()
            for n in ("s1", "s3", "s2"):
                z[n] = np.full((1,), zs, np.float32)
            for n in ("w1v", "w3v", "w2v"):
                z[n] = z[n].astype(np.int8)
        return z

    packs = []                              # [L][tp] dicts
    FBs = FB // tp
    for li in range(L):
        live_parts = {n: [] for n in names}
        for s in range(pf.shards):
            live = A["jv"][li, s] >= 0
            for n in names:
                live_parts[n].append(A[n][li, s][live])
        cat = {n: np.concatenate(live_parts[n]) for n in names}
        order = np.argsort(cat["jv"], kind="stable")
        cat = {n: a[order] for n, a in cat.items()}
        row = []
        for s in range(tp):
            sel = (cat["jv"] >= s * FBs) & (cat["jv"] < (s + 1) * FBs)
            if not sel.any():               # all-pruned shard: one zero
                row.append(zero_visit())    # visit so output flushes b2
                continue
            row.append({n: a[sel] for n, a in cat.items()})
        packs.append(row)

    nv = max(p["jv"].shape[0] for lp in packs for p in lp)

    def pad(p):
        n_pad = nv - p["jv"].shape[0]
        if not n_pad:
            return p
        out = {}
        for n, a in p.items():
            if n == "jv":
                out[n] = np.concatenate(
                    [a, np.full((n_pad,), -1, np.int32)])
            else:
                out[n] = np.concatenate(
                    [a, np.zeros((n_pad,) + a.shape[1:], a.dtype)])
        return out

    packs = [[pad(p) for p in lp] for lp in packs]

    def stack(n):
        a = np.stack([np.stack([p[n] for p in lp]) for lp in packs])
        if tp == 1:
            a = a[:, 0]
        if not stacked:
            a = a[0]
        return jnp.asarray(a)

    if not stacked:
        b2 = b2[0]
    return PackedFFN(
        stack("w1v"), stack("w3v"), stack("w2v"), stack("b1"),
        stack("b3"), jnp.asarray(b2), d_model=d, d_ff=pf.d_ff,
        block_f=bf, act=pf.act,
        s1=stack("s1") if quant else None,
        s3=stack("s3") if quant else None,
        s2=stack("s2") if quant else None,
        shards=tp, jv=stack("jv"))


def reshard_packed(params: Params, cfg: ModelConfig, *, mesh=None,
                   tp: Optional[int] = None) -> Params:
    """Elastic re-deploy fast path: re-partition every packed container
    for a NEW mesh shape by slicing and padding the existing visit
    lists — cheap numpy at load time, no dense/BSR rebuild and no
    pruning-mask recovery. The result is bit-identical to a
    from-scratch ``deploy_packed(pruned, cfg, tp=tp)`` of the same
    weights (the per-shard padding, empty-column flush visits, and int8
    epsilon scales use the same arithmetic). Containers whose block
    grid (or, for attention, head count) does not divide the new ``tp``
    fall back to unsharded — the same rule as ``deploy_packed``.
    Accepts containers at ANY current shard count, so mesh shape
    changes go sharded→sharded without keeping the unsharded pack
    around."""
    if tp is None:
        tp = mesh.shape.get("model", 1) if mesh is not None else 1

    def fits(pw: PackedSASPWeight, kind: str) -> bool:
        K, N = pw.shape
        bk, bn = pw.block
        return _shard_blocks(kind, K, N, bk, bn) % tp == 0

    if "segments" not in params:
        raise ValueError("reshard_packed expects a deployed param tree "
                         "with a 'segments' entry (see deploy_packed)")
    out = dict(params)
    segs = []
    for seg in params["segments"]:
        new_seg = {}
        for slot_name, slot in seg.items():
            slot = dict(slot)
            ffn = slot.get("ffn")
            if isinstance(ffn, dict):
                ffn = dict(ffn)
                pf = ffn.get("sasp_fused")
                if isinstance(pf, PackedFFN):
                    ffn["sasp_fused"] = _reshard_ffn(
                        pf, _fused_tp(pf.d_ff, pf.block_f, tp))
                grp = ffn.get("sasp_packed")
                if isinstance(grp, dict):
                    tp_g = tp if tp > 1 and all(
                        fits(w, _FFN_KINDS.get(n, "col"))
                        for n, w in grp.items()) else 1
                    ffn["sasp_packed"] = {
                        n: _reshard_weight(w, tp_g,
                                           _FFN_KINDS.get(n, "col"))
                        for n, w in grp.items()}
                slot["ffn"] = ffn
            mixer = slot.get("mixer")
            if isinstance(mixer, dict) and isinstance(
                    mixer.get("sasp_packed"), dict):
                mixer = dict(mixer)
                grp = mixer["sasp_packed"]
                tp_a = _attn_tp(cfg, tp)
                if not all(fits(w, _ATTN_KINDS.get(n, "col"))
                           for n, w in grp.items()):
                    tp_a = 1
                mixer["sasp_packed"] = {
                    n: _reshard_weight(w, tp_a,
                                       _ATTN_KINDS.get(n, "col"))
                    for n, w in grp.items()}
                slot["mixer"] = mixer
            new_seg[slot_name] = slot
        segs.append(new_seg)
    out["segments"] = tuple(segs)
    return out


def packed_summary(params: Params) -> Dict[str, float]:
    """Deployment report: container counts + compression vs dense."""
    n_packed = n_fused = 0
    packed_bytes = dense_bytes = 0

    def visit(node):
        nonlocal n_packed, n_fused, packed_bytes, dense_bytes
        if isinstance(node, PackedSASPWeight):
            n_packed += 1
            packed_bytes += node.nbytes()
            K, N = node.shape
            lead = node.vals.shape[:-3]     # (L?, tp?) — tp spans ONE
            dense_bytes += int(np.prod(lead, dtype=np.int64)) \
                // node.shards * K * N * 4  # dense matrix, not tp of them
        elif isinstance(node, PackedFFN):
            n_fused += 1
            for a in (node.w1v, node.w3v, node.w2v):
                packed_bytes += a.size * a.dtype.itemsize
            lead = node.w1v.shape[:-3]
            dense_bytes += int(np.prod(lead, dtype=np.int64)) \
                // node.shards * 3 * node.d_model * node.d_ff * 4
        elif isinstance(node, dict):
            for v in node.values():
                visit(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                visit(v)

    visit(params)
    return {
        "n_packed_matrices": n_packed,
        "n_fused_ffns": n_fused,
        "packed_bytes": packed_bytes,
        "dense_bytes": dense_bytes,
        "compression": packed_bytes / max(dense_bytes, 1),
    }
