"""Packed SASP deployment pipeline (DESIGN.md §9).

``deploy_packed`` is the single load-time conversion entry point for
serving: it walks a (pruned, optionally INT8) param tree and attaches
compact kernel-ready containers so that NO per-call repacking happens on
the serving path:

* per-matrix :class:`~repro.core.sparse.PackedSASPWeight` — the sorted
  (nnz, bk, bn) block list ``kernels.sasp_gemm`` consumes directly, with
  bias and activation folded into the kernel's flush epilogue. Attached
  under ``sasp_packed`` next to the weights (FFN w1/w2/w3 and, for
  ``scope="all"``, attention wq/wk/wv/wo).
* whole-FFN :class:`~repro.core.sparse.PackedFFN` — the fused gated-FFN
  schedule (single kernel launch, no HBM (M, d_ff) intermediate),
  attached under ``sasp_fused``.

Layer stacks (the ``lax.scan``-over-layers layout, leading ``repeat``
axis) are packed per layer and padded to one shared static nnz/nv so the
containers slice under scan exactly like every other stacked param
(padding = duplicated last visit with zero values; see
``kernels.sasp_gemm.ops.pad_block_list``).

Masks are recovered from the nonzero tile structure of the (already
pruned) weights, so the conversion needs nothing beyond the deployed
params themselves — pruning is static by deployment time (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sparse import PackedFFN, PackedSASPWeight
from repro.kernels.sasp_gemm import ops as sasp_ops

Params = Dict[str, Any]

_ATTN_MATS = ("wq", "wk", "wv", "wo")
_FFN_MATS = ("w1", "w2", "w3")


def _fit_block(dim: int, want: int) -> int:
    """Largest block ≤ ``want`` that divides ``dim`` (mask granularity is
    free at deploy time — nonzero-tile detection is correct at any tile
    size, so we pick the best-fitting one)."""
    b = min(max(1, want), dim)
    while dim % b:
        b -= 1
    return b


def _dense_weight(entry: Params) -> Optional[np.ndarray]:
    """Materialize one matrix dict {w}|{qw} to dense fp32 (numpy)."""
    if not isinstance(entry, dict):
        return None
    if "w" in entry:
        return np.asarray(entry["w"], np.float32)
    if "qw" in entry:
        qw = entry["qw"]
        bk, bn = qw.block
        q = np.asarray(qw.q, np.float32)
        sc = np.asarray(qw.scale, np.float32)
        K, N = q.shape[-2:]
        KB, NB = K // bk, N // bn
        qb = q.reshape(*q.shape[:-2], KB, bk, NB, bn)
        qb = qb * sc[..., :, None, :, None]
        return qb.reshape(q.shape)
    return None


def pack_weight(w: np.ndarray, *, block_k: int, block_n: int,
                bias: Optional[np.ndarray] = None,
                act: Optional[str] = None,
                quantize: bool = False,
                tp: int = 1,
                shard_kind: str = "col") -> PackedSASPWeight:
    """(K, N) or layer-stacked (L, K, N) dense weight (pruned tiles
    already zeroed) -> PackedSASPWeight. Stacked inputs are packed per
    layer and padded to a shared nnz (dup-last-visit zero padding).

    ``tp > 1`` partitions each layer's sorted block list into ``tp``
    shard-local lists (DESIGN.md §10): ``shard_kind="col"`` slices the
    output-column blocks (kn n-coords become shard-local; each shard's
    pruning savings stay local instead of averaging away), ``"row"``
    slices the input-row blocks (for down-projections whose INPUT is
    already column-sharded; outputs are partial and need a reduction,
    so ``act`` must be None and ``bias`` is kept whole, to be added
    after the reduction). All (layer × shard) lists share one static
    nnz via the same dup-last-visit padding as the scan layout.
    """
    w = np.asarray(w, np.float32)
    if w.ndim == 2:
        w = w[None]
        bias = None if bias is None else np.asarray(bias)[None]
        squeeze = True
    else:
        squeeze = False
    L, K, N = w.shape
    bk = _fit_block(K, block_k)
    bn = _fit_block(N, block_n)
    KB, NB = K // bk, N // bn
    assert shard_kind in ("col", "row"), shard_kind
    if tp > 1:
        blocks = NB if shard_kind == "col" else KB
        assert blocks % tp == 0, (shard_kind, blocks, tp)
        assert shard_kind == "col" or act is None, \
            "row-sharded outputs are partial; no nonlinear epilogue"

    def _slice(wi, s):
        if tp == 1:
            return wi
        if shard_kind == "col":
            ns = N // tp
            return wi[:, s * ns:(s + 1) * ns]
        ks = K // tp
        return wi[s * ks:(s + 1) * ks, :]

    packs = []                            # [L][tp] of (vals, kn, scale)
    for i in range(L):
        layer = []
        for s in range(tp):
            ws = _slice(w[i], s)
            kb, nb = ws.shape[0] // bk, ws.shape[1] // bn
            m = np.any(ws.reshape(kb, bk, nb, bn), axis=(1, 3))
            layer.append(sasp_ops.build_kernel_weight(
                ws, m, bk, bn, quantize=quantize))
        packs.append(layer)
    nnz = max(np.asarray(p[0]).shape[0] for lp in packs for p in lp)

    def _pad_stack(layer):
        vs, ks, ss = [], [], []
        for v, kn, sc in layer:
            v, kn, sc = sasp_ops.pad_block_list(
                np.asarray(v), np.asarray(kn),
                None if sc is None else np.asarray(sc), nnz)
            vs.append(v)
            ks.append(kn)
            ss.append(sc)
        if tp == 1:
            return vs[0], ks[0], ss[0]
        return (np.stack(vs), np.stack(ks),
                None if ss[0] is None else np.stack(ss))

    per_layer = [_pad_stack(lp) for lp in packs]
    vals = jnp.asarray(np.stack([p[0] for p in per_layer]))
    kn = jnp.asarray(np.stack([p[1] for p in per_layer]))
    scale = None if per_layer[0][2] is None else jnp.asarray(
        np.stack([p[2] for p in per_layer]).astype(np.float32))
    b = None
    if bias is not None:
        b = np.asarray(bias, np.float32)
        if tp > 1 and shard_kind == "col":     # fused per column shard
            b = b.reshape(L, tp, N // tp)
        b = jnp.asarray(b)
    if squeeze:
        vals, kn = vals[0], kn[0]
        scale = None if scale is None else scale[0]
        b = None if b is None else b[0]
    return PackedSASPWeight(vals, kn, (K, N), (bk, bn), scale=scale,
                            bias=b, act=act, shards=tp,
                            shard_kind=shard_kind if tp > 1 else None)


def pack_ffn(w1: np.ndarray, w3: np.ndarray, w2: np.ndarray, *,
             block_f: int, act: str,
             b1: Optional[np.ndarray] = None,
             b3: Optional[np.ndarray] = None,
             b2: Optional[np.ndarray] = None,
             quantize: bool = False,
             tp: int = 1) -> PackedFFN:
    """Gated-FFN triple (each (d, F)/(F, d) or layer-stacked with a
    leading L axis) -> PackedFFN for the fused kernel.

    ``tp > 1`` partitions the d_ff visit schedule contiguously by d_ff
    column-block shard (DESIGN.md §10): shard s packs d_ff columns
    [s·F/tp, (s+1)·F/tp) of w1/w3 and the matching w2 rows, so every
    shard runs the fused kernel over ITS surviving blocks only and
    yields a partial (M, d). b2 is NOT folded into the per-shard flush
    (it would be added tp times under the cross-shard reduction); it
    stays whole on the container for the driver to add once.
    """
    w1 = np.asarray(w1, np.float32)
    squeeze = w1.ndim == 2

    def _lift(a):
        return None if a is None else np.asarray(a, np.float32)[
            None] if squeeze else np.asarray(a, np.float32)

    if squeeze:
        w1 = w1[None]
    w3 = _lift(w3)
    w2 = _lift(w2)
    b1, b3, b2 = _lift(b1), _lift(b3), _lift(b2)
    L, d, F = w1.shape
    bf = _fit_block(F, block_f)
    if tp > 1:
        assert (F // bf) % tp == 0, (F, bf, tp)

    def _build(i, s):
        if tp == 1:
            sl = slice(None)
        else:
            fs = F // tp
            sl = slice(s * fs, (s + 1) * fs)
        return sasp_ops.build_fused_ffn(
            w1[i][:, sl], w3[i][:, sl], w2[i][sl, :], block_f=bf,
            b1=None if b1 is None else b1[i][sl],
            b3=None if b3 is None else b3[i][sl],
            b2=None if (b2 is None or tp > 1) else b2[i],
            quantize=quantize)

    packs = [_build(i, s) for i in range(L) for s in range(tp)]
    nv = max(np.asarray(p[0]).shape[0] for p in packs)

    def _pad_visits(p):
        """Append zero visits up to the shared nv (zero w2v => padded
        visits contribute exactly nothing) — pack once, pad in place."""
        w1v, w3v, w2v, b1v, b3v, b2v, sc = [np.asarray(a) if a is not
                                            None and not isinstance(
                                                a, tuple) else a
                                            for a in p]
        pad = nv - w1v.shape[0]
        if pad:
            def z(a):
                return np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
            w1v, w3v, w2v = z(w1v), z(w3v), z(w2v)
            b1v, b3v = z(b1v), z(b3v)
            if sc is not None:
                sc = tuple(z(np.asarray(s)) for s in sc)
        return w1v, w3v, w2v, b1v, b3v, b2v, sc

    repacked = [_pad_visits(p) for p in packs]

    def _stack(idx):
        a = np.stack([np.asarray(p[idx]) for p in repacked])
        if tp > 1:                         # (L·tp, …) -> (L, tp, …)
            a = a.reshape((L, tp) + a.shape[1:])
        return jnp.asarray(a)

    w1v, w3v, w2v = _stack(0), _stack(1), _stack(2)
    b1v, b3v = _stack(3), _stack(4)
    if tp > 1:
        # per-shard packs carried zero b2 placeholders; keep the real
        # bias whole — drivers add it once after the shard reduction
        b2v = jnp.asarray(b2 if b2 is not None
                          else np.zeros((L, d), np.float32))
    else:
        b2v = _stack(5)
    if repacked[0][6] is None:
        s1 = s3 = s2 = None
    else:
        def _stack_s(idx):
            a = np.stack([np.asarray(p[6][idx]) for p in repacked])
            if tp > 1:
                a = a.reshape((L, tp) + a.shape[1:])
            return jnp.asarray(a)
        s1, s3, s2 = _stack_s(0), _stack_s(1), _stack_s(2)
    if squeeze:
        w1v, w3v, w2v = w1v[0], w3v[0], w2v[0]
        b1v, b3v, b2v = b1v[0], b3v[0], b2v[0]
        s1 = None if s1 is None else s1[0]
        s3 = None if s3 is None else s3[0]
        s2 = None if s2 is None else s2[0]
    return PackedFFN(w1v, w3v, w2v, b1v, b3v, b2v, d_model=d, d_ff=F,
                     block_f=bf, act=act, s1=s1, s3=s3, s2=s2,
                     shards=tp)


# ---------------------------------------------------------------------------
# Apply (serving hot path)
# ---------------------------------------------------------------------------


def packed_matmul(x: jnp.ndarray, pw: PackedSASPWeight, *,
                  block_m: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """(…, K) @ packed weight -> (…, N) through the tile-skip kernel,
    bias + activation fused into the flush. Zero per-call repacking."""
    scales = None if pw.scale is None else pw.scale
    return sasp_ops.sasp_matmul_packed(
        x, pw.vals, pw.kn, scales, n=pw.shape[1], block_m=block_m,
        bias=pw.bias, act=pw.act, interpret=interpret)


def packed_ffn_apply(x: jnp.ndarray, pf: PackedFFN, *,
                     block_m: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """Whole gated FFN in one fused kernel launch."""
    scales = None if pf.s1 is None else (pf.s1, pf.s3, pf.s2)
    return sasp_ops.fused_ffn_matmul(
        x, pf.w1v, pf.w3v, pf.w2v, pf.b1, pf.b3, pf.b2, scales=scales,
        act=pf.act, block_m=block_m, interpret=interpret)


# ---------------------------------------------------------------------------
# deploy_packed — the load-time conversion entry point
# ---------------------------------------------------------------------------


def _tp_fits(w: np.ndarray, kind: str, cfg: ModelConfig, tp: int) -> bool:
    """Does the matrix's block grid split evenly into ``tp`` shards?"""
    if kind == "col":
        N = w.shape[-1]
        return (N // _fit_block(N, cfg.sasp.block_n)) % tp == 0
    K = w.shape[-2]
    return (K // _fit_block(K, cfg.sasp.block_k)) % tp == 0


def _pack_matrix_group(node: Params, names, cfg: ModelConfig,
                       quantize: bool, act_for: Dict[str, Optional[str]],
                       tp: int = 1,
                       kinds: Optional[Dict[str, str]] = None
                       ) -> Optional[Dict[str, PackedSASPWeight]]:
    """Pack a group of matrices that serve together. TP sharding is
    all-or-nothing across the group (the sharded driver keeps the whole
    group inside one shard_map body, so every matrix must split)."""
    kinds = kinds or {}
    mats = []
    for name in names:
        entry = node.get(name)
        w = None if entry is None else _dense_weight(entry)
        if w is None:
            continue
        if w.ndim not in (2, 3):        # MoE expert grids etc.
            return None
        bias = None
        if isinstance(entry, dict) and "b" in entry:
            bias = np.asarray(entry["b"], np.float32)
        mats.append((name, w, bias))
    if tp > 1 and any(not _tp_fits(w, kinds.get(n, "col"), cfg, tp)
                      for n, w, _ in mats):
        tp = 1
    out = {}
    for name, w, bias in mats:
        out[name] = pack_weight(
            w, block_k=cfg.sasp.block_k, block_n=cfg.sasp.block_n,
            bias=bias, act=act_for.get(name), quantize=quantize,
            tp=tp, shard_kind=kinds.get(name, "col"))
    return out or None


_FFN_KINDS = {"w1": "col", "w3": "col", "w2": "row"}
_ATTN_KINDS = {"wq": "col", "wk": "col", "wv": "col", "wo": "row"}


def _deploy_slot(slot: Params, cfg: ModelConfig, *, quantize: bool,
                 fuse_ffn: bool, attn: bool, tp: int = 1) -> Params:
    slot = dict(slot)

    ffn = slot.get("ffn")
    if (isinstance(ffn, dict) and "w1" in ffn and "w2" in ffn
            and "router" not in ffn):       # MoE expert grids: masked path
        ffn = {k: v for k, v in ffn.items()
               if k not in ("sasp_bsr",)}      # packed replaces BSR
        gated = "w3" in ffn
        w1 = _dense_weight(ffn.get("w1"))
        w2 = _dense_weight(ffn.get("w2"))
        w3 = _dense_weight(ffn.get("w3")) if gated else None
        if w1 is not None and w2 is not None and w1.ndim in (2, 3):
            b2 = ffn["w2"].get("b") if isinstance(ffn["w2"], dict) \
                else None
            if gated and fuse_ffn and w3 is not None:
                F = w1.shape[-1]
                bf = _fit_block(F, cfg.sasp.block_n)
                tp_f = tp if tp > 1 and (F // bf) % tp == 0 else 1
                ffn["sasp_fused"] = pack_ffn(
                    w1, w3, w2, block_f=cfg.sasp.block_n, act=cfg.act,
                    b1=ffn["w1"].get("b"), b3=ffn["w3"].get("b"),
                    b2=b2, quantize=quantize, tp=tp_f)
            else:
                # per-matrix packed: act folds into w1's flush epilogue,
                # the gate product (if any) stays in jnp (models/ffn.py)
                act_for = {"w1": cfg.act}
                packed = _pack_matrix_group(
                    ffn, _FFN_MATS, cfg, quantize, act_for, tp=tp,
                    kinds=_FFN_KINDS)
                if packed is not None:
                    ffn["sasp_packed"] = packed
            slot["ffn"] = ffn

    mixer = slot.get("mixer")
    if attn and isinstance(mixer, dict) and all(
            m in mixer for m in _ATTN_MATS):
        mixer = dict(mixer)
        # col shards of wq/wk/wv must land on head boundaries (RoPE and
        # the (B, S, H, D) reshape are per head)
        tp_a = tp if (tp > 1 and cfg.num_heads % tp == 0
                      and cfg.num_kv_heads % tp == 0) else 1
        packed = _pack_matrix_group(mixer, _ATTN_MATS, cfg, quantize, {},
                                    tp=tp_a, kinds=_ATTN_KINDS)
        if packed is not None:
            mixer["sasp_packed"] = packed
            slot["mixer"] = mixer

    return slot


def deploy_packed(params: Params, cfg: ModelConfig, *,
                  quantize: Optional[bool] = None,
                  fuse_ffn: bool = True,
                  attn: Optional[bool] = None,
                  mesh=None,
                  tp: Optional[int] = None) -> Tuple[Params,
                                                     ModelConfig]:
    """Convert a (pruned) param tree into packed serving form.

    Returns ``(params', cfg')`` where every dense/MoE-free FFN (and, for
    ``scope="all"`` or ``attn=True``, every attention projection) carries
    a kernel-ready packed container, and ``cfg'`` has
    ``sasp.path="kernel"`` so the model routes through them. Dense
    weights stay in the tree as the source of truth (XLA dead-code
    eliminates them from the serving graph); ``sasp_bsr`` overlays are
    dropped — the compact block list replaces the padded k_max × NB
    trace-time list.

    quantize: pack values as int8 + per-block scales (default: follow
    ``cfg.sasp.quantize``). fuse_ffn: use the whole-FFN fused container
    for gated FFNs (False = per-matrix packed GEMMs). mesh / tp:
    TP-shard every visit list by output-block shard for the mesh's
    'model' axis (DESIGN.md §10) — each shard carries only ITS surviving
    blocks, so per-shard pruning savings stay local; matrices whose
    block grid does not divide fall back to unsharded containers.
    """
    quantize = cfg.sasp.quantize if quantize is None else quantize
    attn = (cfg.sasp.scope == "all") if attn is None else attn
    if tp is None:
        tp = mesh.shape.get("model", 1) if mesh is not None else 1

    out = dict(params)
    segs = []
    for seg in params.get("segments", ()):
        new_seg = {}
        for slot_name, slot in seg.items():
            new_seg[slot_name] = _deploy_slot(
                slot, cfg, quantize=quantize, fuse_ffn=fuse_ffn,
                attn=attn, tp=tp)
        segs.append(new_seg)
    out["segments"] = tuple(segs)
    cfg = dataclasses.replace(
        cfg, sasp=dataclasses.replace(cfg.sasp, enabled=True,
                                      path="kernel"))
    return out, cfg


def packed_summary(params: Params) -> Dict[str, float]:
    """Deployment report: container counts + compression vs dense."""
    n_packed = n_fused = 0
    packed_bytes = dense_bytes = 0

    def visit(node):
        nonlocal n_packed, n_fused, packed_bytes, dense_bytes
        if isinstance(node, PackedSASPWeight):
            n_packed += 1
            packed_bytes += node.nbytes()
            K, N = node.shape
            lead = node.vals.shape[:-3]     # (L?, tp?) — tp spans ONE
            dense_bytes += int(np.prod(lead, dtype=np.int64)) \
                // node.shards * K * N * 4  # dense matrix, not tp of them
        elif isinstance(node, PackedFFN):
            n_fused += 1
            for a in (node.w1v, node.w3v, node.w2v):
                packed_bytes += a.size * a.dtype.itemsize
            lead = node.w1v.shape[:-3]
            dense_bytes += int(np.prod(lead, dtype=np.int64)) \
                // node.shards * 3 * node.d_model * node.d_ff * 4
        elif isinstance(node, dict):
            for v in node.values():
                visit(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                visit(v)

    visit(params)
    return {
        "n_packed_matrices": n_packed,
        "n_fused_ffns": n_fused,
        "packed_bytes": packed_bytes,
        "dense_bytes": dense_bytes,
        "compression": packed_bytes / max(dense_bytes, 1),
    }
