"""Packed SASP deployment pipeline (DESIGN.md §9).

``deploy_packed`` is the single load-time conversion entry point for
serving: it walks a (pruned, optionally INT8) param tree and attaches
compact kernel-ready containers so that NO per-call repacking happens on
the serving path:

* per-matrix :class:`~repro.core.sparse.PackedSASPWeight` — the sorted
  (nnz, bk, bn) block list ``kernels.sasp_gemm`` consumes directly, with
  bias and activation folded into the kernel's flush epilogue. Attached
  under ``sasp_packed`` next to the weights (FFN w1/w2/w3 and, for
  ``scope="all"``, attention wq/wk/wv/wo).
* whole-FFN :class:`~repro.core.sparse.PackedFFN` — the fused gated-FFN
  schedule (single kernel launch, no HBM (M, d_ff) intermediate),
  attached under ``sasp_fused``.

Layer stacks (the ``lax.scan``-over-layers layout, leading ``repeat``
axis) are packed per layer and padded to one shared static nnz/nv so the
containers slice under scan exactly like every other stacked param
(padding = duplicated last visit with zero values; see
``kernels.sasp_gemm.ops.pad_block_list``).

Masks are recovered from the nonzero tile structure of the (already
pruned) weights, so the conversion needs nothing beyond the deployed
params themselves — pruning is static by deployment time (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sparse import PackedFFN, PackedSASPWeight
from repro.kernels.sasp_gemm import ops as sasp_ops

Params = Dict[str, Any]

_ATTN_MATS = ("wq", "wk", "wv", "wo")
_FFN_MATS = ("w1", "w2", "w3")


def _fit_block(dim: int, want: int) -> int:
    """Largest block ≤ ``want`` that divides ``dim`` (mask granularity is
    free at deploy time — nonzero-tile detection is correct at any tile
    size, so we pick the best-fitting one)."""
    b = min(max(1, want), dim)
    while dim % b:
        b -= 1
    return b


def _dense_weight(entry: Params) -> Optional[np.ndarray]:
    """Materialize one matrix dict {w}|{qw} to dense fp32 (numpy)."""
    if not isinstance(entry, dict):
        return None
    if "w" in entry:
        return np.asarray(entry["w"], np.float32)
    if "qw" in entry:
        qw = entry["qw"]
        bk, bn = qw.block
        q = np.asarray(qw.q, np.float32)
        sc = np.asarray(qw.scale, np.float32)
        K, N = q.shape[-2:]
        KB, NB = K // bk, N // bn
        qb = q.reshape(*q.shape[:-2], KB, bk, NB, bn)
        qb = qb * sc[..., :, None, :, None]
        return qb.reshape(q.shape)
    return None


def pack_weight(w: np.ndarray, *, block_k: int, block_n: int,
                bias: Optional[np.ndarray] = None,
                act: Optional[str] = None,
                quantize: bool = False) -> PackedSASPWeight:
    """(K, N) or layer-stacked (L, K, N) dense weight (pruned tiles
    already zeroed) -> PackedSASPWeight. Stacked inputs are packed per
    layer and padded to a shared nnz (dup-last-visit zero padding)."""
    w = np.asarray(w, np.float32)
    if w.ndim == 2:
        w = w[None]
        bias = None if bias is None else np.asarray(bias)[None]
        squeeze = True
    else:
        squeeze = False
    L, K, N = w.shape
    bk = _fit_block(K, block_k)
    bn = _fit_block(N, block_n)
    KB, NB = K // bk, N // bn

    packs = []
    for i in range(L):
        m = np.any(
            w[i].reshape(KB, bk, NB, bn), axis=(1, 3))      # nonzero tiles
        packs.append(sasp_ops.build_kernel_weight(
            w[i], m, bk, bn, quantize=quantize))
    nnz = max(np.asarray(p[0]).shape[0] for p in packs)
    vs, ks, ss = [], [], []
    for v, kn, sc in packs:
        v, kn, sc = sasp_ops.pad_block_list(
            np.asarray(v), np.asarray(kn),
            None if sc is None else np.asarray(sc), nnz)
        vs.append(v)
        ks.append(kn)
        ss.append(sc)
    vals = jnp.asarray(np.stack(vs))
    kn = jnp.asarray(np.stack(ks))
    scale = None if ss[0] is None else jnp.asarray(
        np.stack(ss).astype(np.float32))
    b = None if bias is None else jnp.asarray(
        np.asarray(bias, np.float32))
    if squeeze:
        vals, kn = vals[0], kn[0]
        scale = None if scale is None else scale[0]
        b = None if b is None else b[0]
    return PackedSASPWeight(vals, kn, (K, N), (bk, bn), scale=scale,
                            bias=b, act=act)


def pack_ffn(w1: np.ndarray, w3: np.ndarray, w2: np.ndarray, *,
             block_f: int, act: str,
             b1: Optional[np.ndarray] = None,
             b3: Optional[np.ndarray] = None,
             b2: Optional[np.ndarray] = None,
             quantize: bool = False) -> PackedFFN:
    """Gated-FFN triple (each (d, F)/(F, d) or layer-stacked with a
    leading L axis) -> PackedFFN for the fused kernel."""
    w1 = np.asarray(w1, np.float32)
    squeeze = w1.ndim == 2

    def _lift(a):
        return None if a is None else np.asarray(a, np.float32)[
            None] if squeeze else np.asarray(a, np.float32)

    if squeeze:
        w1 = w1[None]
    w3 = _lift(w3)
    w2 = _lift(w2)
    b1, b3, b2 = _lift(b1), _lift(b3), _lift(b2)
    L, d, F = w1.shape
    bf = _fit_block(F, block_f)

    packs = [sasp_ops.build_fused_ffn(
        w1[i], w3[i], w2[i], block_f=bf,
        b1=None if b1 is None else b1[i],
        b3=None if b3 is None else b3[i],
        b2=None if b2 is None else b2[i],
        quantize=quantize) for i in range(L)]
    nv = max(np.asarray(p[0]).shape[0] for p in packs)

    def _pad_visits(p):
        """Append zero visits up to the shared nv (zero w2v => padded
        visits contribute exactly nothing) — pack once, pad in place."""
        w1v, w3v, w2v, b1v, b3v, b2v, sc = [np.asarray(a) if a is not
                                            None and not isinstance(
                                                a, tuple) else a
                                            for a in p]
        pad = nv - w1v.shape[0]
        if pad:
            def z(a):
                return np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
            w1v, w3v, w2v = z(w1v), z(w3v), z(w2v)
            b1v, b3v = z(b1v), z(b3v)
            if sc is not None:
                sc = tuple(z(np.asarray(s)) for s in sc)
        return w1v, w3v, w2v, b1v, b3v, b2v, sc

    repacked = [_pad_visits(p) for p in packs]

    def _stack(idx):
        return jnp.asarray(np.stack([np.asarray(p[idx]) for p in
                                     repacked]))

    w1v, w3v, w2v = _stack(0), _stack(1), _stack(2)
    b1v, b3v, b2v = _stack(3), _stack(4), _stack(5)
    if repacked[0][6] is None:
        s1 = s3 = s2 = None
    else:
        s1 = jnp.asarray(np.stack([np.asarray(p[6][0]) for p in repacked]))
        s3 = jnp.asarray(np.stack([np.asarray(p[6][1]) for p in repacked]))
        s2 = jnp.asarray(np.stack([np.asarray(p[6][2]) for p in repacked]))
    if squeeze:
        w1v, w3v, w2v = w1v[0], w3v[0], w2v[0]
        b1v, b3v, b2v = b1v[0], b3v[0], b2v[0]
        s1 = None if s1 is None else s1[0]
        s3 = None if s3 is None else s3[0]
        s2 = None if s2 is None else s2[0]
    return PackedFFN(w1v, w3v, w2v, b1v, b3v, b2v, d_model=d, d_ff=F,
                     block_f=bf, act=act, s1=s1, s3=s3, s2=s2)


# ---------------------------------------------------------------------------
# Apply (serving hot path)
# ---------------------------------------------------------------------------


def packed_matmul(x: jnp.ndarray, pw: PackedSASPWeight, *,
                  block_m: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """(…, K) @ packed weight -> (…, N) through the tile-skip kernel,
    bias + activation fused into the flush. Zero per-call repacking."""
    scales = None if pw.scale is None else pw.scale
    return sasp_ops.sasp_matmul_packed(
        x, pw.vals, pw.kn, scales, n=pw.shape[1], block_m=block_m,
        bias=pw.bias, act=pw.act, interpret=interpret)


def packed_ffn_apply(x: jnp.ndarray, pf: PackedFFN, *,
                     block_m: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """Whole gated FFN in one fused kernel launch."""
    scales = None if pf.s1 is None else (pf.s1, pf.s3, pf.s2)
    return sasp_ops.fused_ffn_matmul(
        x, pf.w1v, pf.w3v, pf.w2v, pf.b1, pf.b3, pf.b2, scales=scales,
        act=pf.act, block_m=block_m, interpret=interpret)


# ---------------------------------------------------------------------------
# deploy_packed — the load-time conversion entry point
# ---------------------------------------------------------------------------


def _pack_matrix_group(node: Params, names, cfg: ModelConfig,
                       quantize: bool, act_for: Dict[str, Optional[str]]
                       ) -> Optional[Dict[str, PackedSASPWeight]]:
    out = {}
    for name in names:
        entry = node.get(name)
        w = None if entry is None else _dense_weight(entry)
        if w is None:
            continue
        if w.ndim not in (2, 3):        # MoE expert grids etc.
            return None
        bias = None
        if isinstance(entry, dict) and "b" in entry:
            bias = np.asarray(entry["b"], np.float32)
        out[name] = pack_weight(
            w, block_k=cfg.sasp.block_k, block_n=cfg.sasp.block_n,
            bias=bias, act=act_for.get(name), quantize=quantize)
    return out or None


def _deploy_slot(slot: Params, cfg: ModelConfig, *, quantize: bool,
                 fuse_ffn: bool, attn: bool) -> Params:
    slot = dict(slot)

    ffn = slot.get("ffn")
    if (isinstance(ffn, dict) and "w1" in ffn and "w2" in ffn
            and "router" not in ffn):       # MoE expert grids: masked path
        ffn = {k: v for k, v in ffn.items()
               if k not in ("sasp_bsr",)}      # packed replaces BSR
        gated = "w3" in ffn
        w1 = _dense_weight(ffn.get("w1"))
        w2 = _dense_weight(ffn.get("w2"))
        w3 = _dense_weight(ffn.get("w3")) if gated else None
        if w1 is not None and w2 is not None and w1.ndim in (2, 3):
            b2 = ffn["w2"].get("b") if isinstance(ffn["w2"], dict) \
                else None
            if gated and fuse_ffn and w3 is not None:
                ffn["sasp_fused"] = pack_ffn(
                    w1, w3, w2, block_f=cfg.sasp.block_n, act=cfg.act,
                    b1=ffn["w1"].get("b"), b3=ffn["w3"].get("b"),
                    b2=b2, quantize=quantize)
            else:
                # per-matrix packed: act folds into w1's flush epilogue,
                # the gate product (if any) stays in jnp (models/ffn.py)
                act_for = {"w1": cfg.act}
                packed = _pack_matrix_group(
                    ffn, _FFN_MATS, cfg, quantize, act_for)
                if packed is not None:
                    ffn["sasp_packed"] = packed
            slot["ffn"] = ffn

    mixer = slot.get("mixer")
    if attn and isinstance(mixer, dict) and all(
            m in mixer for m in _ATTN_MATS):
        mixer = dict(mixer)
        packed = _pack_matrix_group(mixer, _ATTN_MATS, cfg, quantize, {})
        if packed is not None:
            mixer["sasp_packed"] = packed
            slot["mixer"] = mixer

    return slot


def deploy_packed(params: Params, cfg: ModelConfig, *,
                  quantize: Optional[bool] = None,
                  fuse_ffn: bool = True,
                  attn: Optional[bool] = None) -> Tuple[Params,
                                                        ModelConfig]:
    """Convert a (pruned) param tree into packed serving form.

    Returns ``(params', cfg')`` where every dense/MoE-free FFN (and, for
    ``scope="all"`` or ``attn=True``, every attention projection) carries
    a kernel-ready packed container, and ``cfg'`` has
    ``sasp.path="kernel"`` so the model routes through them. Dense
    weights stay in the tree as the source of truth (XLA dead-code
    eliminates them from the serving graph); ``sasp_bsr`` overlays are
    dropped — the compact block list replaces the padded k_max × NB
    trace-time list.

    quantize: pack values as int8 + per-block scales (default: follow
    ``cfg.sasp.quantize``). fuse_ffn: use the whole-FFN fused container
    for gated FFNs (False = per-matrix packed GEMMs).
    """
    quantize = cfg.sasp.quantize if quantize is None else quantize
    attn = (cfg.sasp.scope == "all") if attn is None else attn

    out = dict(params)
    segs = []
    for seg in params.get("segments", ()):
        new_seg = {}
        for slot_name, slot in seg.items():
            new_seg[slot_name] = _deploy_slot(
                slot, cfg, quantize=quantize, fuse_ffn=fuse_ffn,
                attn=attn)
        segs.append(new_seg)
    out["segments"] = tuple(segs)
    cfg = dataclasses.replace(
        cfg, sasp=dataclasses.replace(cfg.sasp, enabled=True,
                                      path="kernel"))
    return out, cfg


def packed_summary(params: Params) -> Dict[str, float]:
    """Deployment report: container counts + compression vs dense."""
    n_packed = n_fused = 0
    packed_bytes = dense_bytes = 0

    def visit(node):
        nonlocal n_packed, n_fused, packed_bytes, dense_bytes
        if isinstance(node, PackedSASPWeight):
            n_packed += 1
            packed_bytes += node.nbytes()
            K, N = node.shape
            lead = node.vals.shape[:-3]
            dense_bytes += int(np.prod(lead, dtype=np.int64)) * K * N * 4
        elif isinstance(node, PackedFFN):
            n_fused += 1
            for a in (node.w1v, node.w3v, node.w2v):
                packed_bytes += a.size * a.dtype.itemsize
            lead = node.w1v.shape[:-3]
            dense_bytes += int(np.prod(lead, dtype=np.int64)) * \
                3 * node.d_model * node.d_ff * 4
        elif isinstance(node, dict):
            for v in node.values():
                visit(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                visit(v)

    visit(params)
    return {
        "n_packed_matrices": n_packed,
        "n_fused_ffns": n_fused,
        "packed_bytes": packed_bytes,
        "dense_bytes": dense_bytes,
        "compression": packed_bytes / max(dense_bytes, 1),
    }
