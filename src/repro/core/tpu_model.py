"""TPU v5e hardware constants + roofline terms (deployment tier).

The three-term roofline (system prompt §ROOFLINE):
    compute    = HLO_FLOPs      / (chips × PEAK_FLOPS)
    memory     = HLO_bytes      / (chips × HBM_BW)
    collective = collective_B   / (chips × ICI_BW)
Derived from the compiled dry-run artifact, not measured (CPU container).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

PEAK_BF16_FLOPS = 197e12      # per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (≈ per chip for ring traffic)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB HBM per v5e chip
CHIPS_PER_POD = 256
# constant-power approximation for the energy axis (v5e chip ~200 W board
# power under load; used only for relative J comparisons)
CHIP_POWER_W = 200.0


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_coll: float
    chips: int

    @property
    def bound_s(self) -> float:
        """Lower-bound step time = max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        """Upper bound: no overlap at all."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def mfu(self) -> float:
        """FLOP-roofline fraction if the step ran at bound_s."""
        if self.bound_s <= 0:
            return 0.0
        return self.compute_s / self.bound_s

    def energy_j(self) -> float:
        return self.bound_s * self.chips * CHIP_POWER_W

    def row(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound_s": self.bound_s,
            "bottleneck": self.bottleneck, "flops": self.flops,
            "bytes_hbm": self.bytes_hbm, "bytes_coll": self.bytes_coll,
        }


def roofline(flops: float, bytes_hbm: float, bytes_coll: float,
             chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_BF16_FLOPS),
        memory_s=bytes_hbm / (chips * HBM_BW),
        collective_s=bytes_coll / (chips * ICI_BW),
        flops=flops, bytes_hbm=bytes_hbm, bytes_coll=bytes_coll,
        chips=chips,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for a train step;
    2·N·D for forward-only (prefill); 2·N_active per decoded token."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens
