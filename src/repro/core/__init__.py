from repro.core import pruning, quantization, sparse  # noqa: F401
