"""Weight-only INT8 quantization (paper §3.1/§3.3, the FP32_INT8 setting).

The paper programs sign-magnitude INT8 weights into the array and keeps
FP32 activations; the hybrid multiplier dequantizes implicitly. The TPU
analogue: weights live as INT8 (+ per-block fp32 scales) in HBM/VMEM —
4× fewer weight bytes, exactly the paper's 4-weights-per-bus-word — and
are dequantized right before the MXU (fused in the Pallas kernel).

Symmetric per-(block_k × block_n) scales; block matched to the SASP tile so
pruning metadata and quant metadata share a layout.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class QuantizedWeight:
    """q: int8 (..., K, N); scale: fp32 (..., KB, NB); block is static."""

    def __init__(self, q, scale, block: Tuple[int, int]):
        self.q = q
        self.scale = scale
        self.block = tuple(block)

    def tree_flatten(self):
        return (self.q, self.scale), self.block

    @classmethod
    def tree_unflatten(cls, block, children):
        q, scale = children
        return cls(q, scale, block)

    @property
    def shape(self):
        return self.q.shape

    @property
    def nbytes_weights(self) -> int:
        return self.q.size  # 1 byte each

    def __repr__(self):
        return (f"QuantizedWeight(q={getattr(self.q, 'shape', None)}, "
                f"block={self.block})")


def quantize_int8(w: jnp.ndarray, bk: int, bn: int) -> QuantizedWeight:
    *lead, K, N = w.shape
    bk, bn = min(bk, K), min(bn, N)
    KB, NB = K // bk, N // bn
    wb = w.reshape(*lead, KB, bk, NB, bn).astype(jnp.float32)
    amax = jnp.max(jnp.abs(wb), axis=(-3, -1), keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(wb / scale), -127, 127).astype(jnp.int8)
    return QuantizedWeight(
        q=q.reshape(*lead, K, N),
        scale=scale.reshape(*lead, KB, NB),
        block=(bk, bn),
    )


def dequantize_int8(qw: QuantizedWeight, dtype=jnp.float32) -> jnp.ndarray:
    bk, bn = qw.block
    *lead, K, N = qw.q.shape
    KB, NB = K // bk, N // bn
    qb = qw.q.reshape(*lead, KB, bk, NB, bn).astype(jnp.float32)
    wb = qb * qw.scale[..., :, None, :, None]
    return wb.reshape(*lead, K, N).astype(dtype)


def quant_error(w: jnp.ndarray, bk: int, bn: int) -> float:
    """Relative Frobenius reconstruction error — used by tests and the QoS
    tier to bound the INT8 degradation independently of pruning."""
    qw = quantize_int8(w, bk, bn)
    wd = dequantize_int8(qw)
    num = jnp.linalg.norm((w.astype(jnp.float32) - wd).reshape(-1))
    den = jnp.maximum(jnp.linalg.norm(w.astype(jnp.float32).reshape(-1)),
                      1e-12)
    return float(num / den)


# ---------------------------------------------------------------------------
# Packing: 4 × int8 per 32-bit word (paper's bus layout). On TPU this is a
# storage/bandwidth statement — we keep int8 arrays (XLA already stores them
# at 1 byte) and expose pack/unpack for the cost model + checkpoint format.
# ---------------------------------------------------------------------------


def pack_int8_to_u32(q: jnp.ndarray) -> jnp.ndarray:
    """int8 (..., N) with N % 4 == 0 -> uint32 (..., N // 4)."""
    *lead, N = q.shape
    assert N % 4 == 0, N
    u = q.astype(jnp.uint8).astype(jnp.uint32).reshape(*lead, N // 4, 4)
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.uint32)
    return jnp.sum(u << shifts, axis=-1).astype(jnp.uint32)


def unpack_u32_to_int8(p: jnp.ndarray) -> jnp.ndarray:
    *lead, M = p.shape
    shifts = jnp.array([0, 8, 16, 24], dtype=jnp.uint32)
    u = (p[..., None] >> shifts) & jnp.uint32(0xFF)
    return u.astype(jnp.uint8).astype(jnp.int8).reshape(*lead, M * 4)


# ---------------------------------------------------------------------------
# int8 with error feedback — reused by optimizer-state quant and gradient
# compression (beyond-paper: the paper's quantization theme applied to the
# distributed-training side).
# ---------------------------------------------------------------------------


def quantize_1d_blocks(x: jnp.ndarray, block: int = 256
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flat per-block symmetric int8. Returns (q int8 (n,), scale (nb,))."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    fb = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(fb), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(fb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n + pad], scale[:, 0]


def dequantize_1d_blocks(q: jnp.ndarray, scale: jnp.ndarray,
                         shape, block: int = 256) -> jnp.ndarray:
    qb = q.reshape(-1, block).astype(jnp.float32)
    x = qb * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return x.reshape(-1)[:n].reshape(shape)
