"""The SASP co-design explorer (paper Fig 2): sweep hyper-parameters
(array/tile size × pruning rate × quantization), collect figures of merit
from every tier — QoS (algorithm), runtime (system model), area/energy
(hardware model) — and expose the trade-off views of Figs 7/9/10/11 and
Table 3.

QoS enters as a callable ``qos_fn(tile, sparsity, quant) -> float``
(degradation metric, lower = better, e.g. WER %). The QoS reproduction
tier (benchmarks/qos_harness.py) trains a real model and measures it;
`exponential_qos_proxy` provides the paper-shaped closed form for quick
sweeps and tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.cost_model import (
    GEMMWork,
    SystolicConfig,
    encoder_gemms,
    energy_j,
    scale_to_t_base,
    speedup_vs_cpu,
    workload_time_s,
)


@dataclass
class DesignPoint:
    tile: int
    sparsity: float
    quant: str
    qos: float                  # degradation metric (e.g. WER %)
    speedup: float              # vs non-accelerated, non-quantized CPU
    time_s: float
    energy_j: float
    area_mm2: float

    @property
    def area_energy(self) -> float:
        return self.area_mm2 * self.energy_j


def exponential_qos_proxy(base_qos: float = 3.5,
                          brittleness: float = 21.0,
                          tile_slope: float = 0.19,
                          amp: float = 0.5,
                          tile_ref: int = 4) -> Callable:
    """Paper-shaped QoS model (Fig 9): WER grows exponentially in the
    pruning rate, steeper for larger tiles (large-tile brittleness, §4.4),
    small constant offset for INT8. Calibrated to the paper's inflection
    points: ΔWER ≈ 1.5 % at 25 % pruning on 4×4/8×8 and at 20 % on
    16×16/32×32 (Table 3's 5 % WER selections)."""

    def qos(tile: int, sparsity: float, quant: str) -> float:
        steep = brittleness * (1.0 + tile_slope * math.log2(
            max(tile, tile_ref) / tile_ref))
        q = amp * (math.exp(steep * sparsity ** 2) - 1.0)
        if quant == "int8":
            q += 0.08
        return base_qos + q

    return qos


def sweep(gemm_builder: Callable[[float], Sequence[GEMMWork]],
          qos_fn: Callable[[int, float, str], float],
          tiles: Sequence[int] = (4, 8, 16, 32),
          sparsities: Sequence[float] = (0.0, 0.05, 0.10, 0.15, 0.20,
                                         0.25, 0.30, 0.40, 0.50),
          quants: Sequence[str] = ("fp32", "int8")) -> List[DesignPoint]:
    """gemm_builder(ffn_sparsity) -> GEMM list (tile-size independent —
    tiling happens inside the cost model)."""
    base = gemm_builder(0.0)
    scale = scale_to_t_base(base)
    pts = []
    for tile in tiles:
        for q in quants:
            sa = SystolicConfig(size=tile, quant=q)
            for s in sparsities:
                gs = gemm_builder(s)
                pts.append(DesignPoint(
                    tile=tile, sparsity=s, quant=q,
                    qos=qos_fn(tile, s, q),
                    speedup=speedup_vs_cpu(sa, gs),
                    time_s=workload_time_s(sa, gs) * scale,
                    energy_j=energy_j(sa, gs, scale),
                    area_mm2=sa.area_mm2,
                ))
    return pts


def best_under_qos(points: Sequence[DesignPoint], qos_target: float
                   ) -> Dict[tuple, DesignPoint]:
    """Per (tile, quant): the fastest point meeting the QoS target —
    Table 3's 'SASP @ 5% WER' selection."""
    out: Dict[tuple, DesignPoint] = {}
    for p in points:
        if p.qos > qos_target:
            continue
        key = (p.tile, p.quant)
        if key not in out or p.speedup > out[key].speedup:
            out[key] = p
    return out


def pareto_front(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Non-dominated set over (qos ↓, time ↓, area_energy ↓)."""
    front = []
    for p in points:
        dominated = any(
            (o.qos <= p.qos and o.time_s <= p.time_s
             and o.area_energy <= p.area_energy)
            and (o.qos < p.qos or o.time_s < p.time_s
                 or o.area_energy < p.area_energy)
            for o in points)
        if not dominated:
            front.append(p)
    return front


def speedup_at_fixed_qos(points: Sequence[DesignPoint], qos_target: float,
                         quant: str) -> Dict[int, float]:
    """Fig 11: speedup vs array size at a fixed QoS level (sublinear)."""
    sel = best_under_qos([p for p in points if p.quant == quant],
                         qos_target)
    return {tile: p.speedup for (tile, q), p in sorted(sel.items())}
