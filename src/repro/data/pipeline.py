"""Deterministic, shardable, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step) — resuming after a
failure is "set step, continue"; the only pipeline state is one integer,
checkpointed in the manifest's ``extra`` dict. Per-host sharding slices
the global batch by host id, so multi-host deployments read disjoint
rows with no coordination.

Two generators:
  * ``lm_batches`` — Zipf-ish token stream with local structure (repeats
    + ngram templates) so a real LM has something to learn;
  * ``asr_batches`` — the QoS tier's synthetic transcription task:
    targets are token sequences; inputs are their embeddings passed
    through a fixed random "acoustic" projection + noise; per-position
    token error rate ≙ WER (paper's metric shape).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


@dataclass
class DataState:
    step: int = 0

    def to_dict(self) -> Dict:
        return {"data_step": self.step}

    @staticmethod
    def from_dict(d: Dict) -> "DataState":
        return DataState(step=int(d.get("data_step", 0)))


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def lm_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Structured synthetic LM data: Zipf unigrams + periodic copy
    patterns (so loss decreases measurably within a few hundred steps)."""
    rng = _rng_for(cfg, step)
    B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    # zipf-ish unigram draw
    ranks = np.arange(1, V + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(V, size=(B, S), p=probs)
    # inject copy structure: second half of each period repeats the first
    period = min(64, S)
    for b in range(B):
        for start in range(0, S - period, period):
            half = period // 2
            toks[b, start + half:start + period] = \
                toks[b, start:start + half]
    return {"tokens": toks.astype(np.int32)}


def asr_batch(cfg: DataConfig, step: int, d_model: int,
              noise: float = 0.25) -> Dict[str, np.ndarray]:
    """Synthetic 'transcription': inputs = fixed random projection of
    target-token one-hots + noise; labels = the tokens. A transformer
    encoder learns to denoise/transcribe; per-position error rate plays
    WER (paper Table 1 metric)."""
    rng = _rng_for(cfg, step)
    B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    toks = rng.integers(0, V, size=(B, S))
    # fixed "acoustic frontend": deterministic projection from token id —
    # constant across DataConfig seeds (train and eval streams must share
    # the same frontend; only tokens/noise vary with (seed, step))
    proj_rng = np.random.default_rng(np.random.SeedSequence([4242]))
    table = proj_rng.normal(size=(V, d_model)).astype(np.float32)
    feats = table[toks] + noise * rng.normal(size=(B, S, d_model))
    return {"tokens": toks.astype(np.int32),
            "embeds": feats.astype(np.float32)}


class Pipeline:
    """Stateful iterator facade over the pure batch functions."""

    def __init__(self, cfg: DataConfig, kind: str = "lm",
                 d_model: int = 0, state: Optional[DataState] = None,
                 noise: float = 0.25):
        self.cfg = cfg
        self.kind = kind
        self.d_model = d_model
        self.noise = noise
        self.state = state or DataState()

    def next(self) -> Dict[str, np.ndarray]:
        if self.kind == "lm":
            b = lm_batch(self.cfg, self.state.step)
        else:
            b = asr_batch(self.cfg, self.state.step, self.d_model,
                          noise=self.noise)
        self.state.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()
