"""Jit'd wrapper for the dense weight-INT8 GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantization import QuantizedWeight
from repro.kernels.int8_gemm.kernel import int8_gemm


@functools.partial(jax.jit, static_argnames=("interpret",))
def _int8_matmul_jit(x, w_q, scale, *, interpret):
    return int8_gemm(x, w_q, scale, interpret=interpret)


def int8_matmul(x: jnp.ndarray, qw: QuantizedWeight, *,
                interpret: bool = True) -> jnp.ndarray:
    """(…, K) @ QuantizedWeight -> (…, N), dequant fused in the kernel."""
    *lead, K = x.shape
    y = _int8_matmul_jit(x.reshape(-1, K), qw.q, qw.scale,
                         interpret=interpret)
    return y.reshape(*lead, qw.q.shape[-1]).astype(x.dtype)
