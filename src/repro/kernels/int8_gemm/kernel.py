"""Dense weight-INT8 GEMM with fused per-block dequantization — the
paper's FP32_INT8 configuration (§3.3) without pruning.

Weights stay int8 through HBM→VMEM (4× fewer weight bytes: the paper's
four-weights-per-bus-word), are widened in-register and the per-(k,n)-block
scale is applied as an epilogue on the MXU partial — functionally the
paper's hybrid FP32×INT8 multiplier (sign ⊕, magnitude multiply, exponent
fixup ≡ scale multiply). NaN/Inf/subnormal weights are not special-cased,
matching the paper's design choice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32) \
        * s_ref[0, 0]

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def int8_gemm(x: jnp.ndarray, w_q: jnp.ndarray, scale: jnp.ndarray, *,
              block_m: int = 128, block_k: int = 128, block_n: int = 128,
              out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) fp; w_q: (K, N) int8; scale: (KB, NB) fp32 per-block.
    Kernel blocks must align with quant blocks (bk | quant_bk etc.); here
    we require the quant grid to equal the kernel grid for a scale to be
    constant per kernel block."""
    M, K = x.shape
    K2, N = w_q.shape
    KB, NB = scale.shape
    bk, bn = K // KB, N // NB
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        _int8_kernel,
        grid=(M // bm, NB, KB),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(x, w_q, scale)
