"""Pure-jnp oracle for the dense weight-INT8 GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def int8_gemm_ref(x: jnp.ndarray, w_q: jnp.ndarray,
                  scale: jnp.ndarray) -> jnp.ndarray:
    """Dequantize-then-matmul reference. x: (M, K); w_q: (K, N) int8;
    scale: (KB, NB)."""
    K, N = w_q.shape
    KB, NB = scale.shape
    bk, bn = K // KB, N // NB
    wq = w_q.reshape(KB, bk, NB, bn).astype(jnp.float32)
    w = (wq * scale[:, None, :, None]).reshape(K, N)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
