"""Pure-jnp oracle for the SASP tile-skip GEMM."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def masked_dense_ref(x: jnp.ndarray, w: jnp.ndarray,
                     mask: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K); w: (K, N); mask: (KB, NB) bool -> (M, N) with pruned
    tiles zeroed. THE semantic ground truth for every SASP path."""
    K, N = w.shape
    KB, NB = mask.shape
    bk, bn = K // KB, N // NB
    wb = w.reshape(KB, bk, NB, bn) * mask[:, None, :, None].astype(w.dtype)
    return x @ wb.reshape(K, N)


def block_list_ref(x: jnp.ndarray, w_vals, block_kn, n: int,
                   scales=None) -> jnp.ndarray:
    """Oracle consuming the kernel's own inputs (blocks + coordinates):
    reconstruct the dense masked weight, then one dense matmul."""
    M, K = x.shape
    nnz, bk, bn = w_vals.shape
    KB, NB = K // bk, n // bn
    wd = np.zeros((KB, bk, NB, bn), dtype=np.float32)
    vals = np.asarray(w_vals, dtype=np.float32)
    if scales is not None:
        vals = vals * np.asarray(scales)[:, None, None]
    kn = np.asarray(block_kn)
    for s in range(nnz):
        wd[kn[0, s], :, kn[1, s], :] += vals[s]
    return (np.asarray(x, np.float32) @ wd.reshape(K, n))
