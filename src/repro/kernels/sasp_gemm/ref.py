"""Pure-jnp oracles for the SASP tile-skip GEMM and its fused variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_ACTS_REF = {
    None: lambda x: x,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def masked_dense_ref(x: jnp.ndarray, w: jnp.ndarray,
                     mask: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K); w: (K, N); mask: (KB, NB) bool -> (M, N) with pruned
    tiles zeroed. THE semantic ground truth for every SASP path."""
    K, N = w.shape
    KB, NB = mask.shape
    bk, bn = K // KB, N // NB
    wb = w.reshape(KB, bk, NB, bn) * mask[:, None, :, None].astype(w.dtype)
    return x @ wb.reshape(K, N)


def block_list_ref(x: jnp.ndarray, w_vals, block_kn, n: int,
                   scales=None) -> jnp.ndarray:
    """Oracle consuming the kernel's own inputs (blocks + coordinates):
    reconstruct the dense masked weight, then one dense matmul."""
    M, K = x.shape
    nnz, bk, bn = w_vals.shape
    KB, NB = K // bk, n // bn
    wd = np.zeros((KB, bk, NB, bn), dtype=np.float32)
    vals = np.asarray(w_vals, dtype=np.float32)
    if scales is not None:
        vals = vals * np.asarray(scales)[:, None, None]
    kn = np.asarray(block_kn)
    for s in range(nnz):
        wd[kn[0, s], :, kn[1, s], :] += vals[s]
    return (np.asarray(x, np.float32) @ wd.reshape(K, n))


def epilogue_ref(y: jnp.ndarray, bias=None, act=None) -> jnp.ndarray:
    """Ground truth for the flush-time epilogue: act(y + bias)."""
    if bias is not None:
        y = y + jnp.asarray(bias, y.dtype)
    return _ACTS_REF[act](y)


def fused_ffn_ref(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
                  w2: jnp.ndarray, b1=None, b3=None, b2=None,
                  act: str = "silu") -> jnp.ndarray:
    """Semantic ground truth for the fused gated-FFN kernel: plain-jnp
    act(x@W1 + b1) * (x@W3 + b3) @ W2 + b2 over ALREADY-MASKED dense
    weights (pruned tiles zeroed in place)."""
    x = jnp.asarray(x, jnp.float32)
    u = x @ jnp.asarray(w1, jnp.float32)
    g = x @ jnp.asarray(w3, jnp.float32)
    if b1 is not None:
        u = u + jnp.asarray(b1, jnp.float32)
    if b3 is not None:
        g = g + jnp.asarray(b3, jnp.float32)
    y = (_ACTS_REF[act](u) * g) @ jnp.asarray(w2, jnp.float32)
    if b2 is not None:
        y = y + jnp.asarray(b2, jnp.float32)
    return y
