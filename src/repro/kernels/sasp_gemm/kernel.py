"""SASP tile-skip GEMM — the paper's systolic-array tile skipping (Fig 3)
as a TPU Pallas kernel.

TPU adaptation (DESIGN.md §2): instead of skipping weight-programming +
streaming on an edge array, the kernel's grid enumerates ONLY the surviving
weight blocks. The grid is (M-blocks × nnz): scalar-prefetched (k, n) block
coordinates drive the BlockSpec index maps, so pruned blocks are never
DMA'd from HBM and never enter the MXU — both the FLOP term and the
weight-byte term drop ∝ sparsity, exactly the paper's saving.

Visit order is sorted by (n, k) (see ops.kernel_block_list): all surviving
K-blocks of an output column-block are consecutive, so the output block
stays VMEM-resident; a float32 VMEM scratch accumulator re-initializes
when the n-coordinate changes and flushes on its last visit. Output
column-blocks with zero surviving weight blocks get one zero-valued
padding entry so every output block is written.

Variants:
  * fp32/bf16 values (``_sasp_kernel``);
  * fused INT8 dequant (``_sasp_kernel_int8``): int8 blocks ride HBM→VMEM
    at 1 byte/weight (the paper's 4-per-bus-word), and the per-block scale
    is applied as an epilogue after the MXU dot — the TPU analogue of the
    paper's hybrid FP32×INT8 multiplier (§3.3).

Block shapes default to MXU-aligned 128 multiples; validated with
``interpret=True`` against ref.py on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flags(kn_ref, nnz: int):
    s = pl.program_id(1)
    n_cur = kn_ref[1, s]
    n_prev = kn_ref[1, jnp.maximum(s, 1) - 1]
    first = jnp.logical_or(s == 0, n_cur != n_prev)
    n_next = kn_ref[1, jnp.minimum(s + 1, nnz - 1)]
    last = jnp.logical_or(s == nnz - 1, n_cur != n_next)
    return first, last


def _sasp_kernel(kn_ref, x_ref, w_ref, o_ref, acc_ref, *, nnz: int):
    first, last = _flags(kn_ref, nnz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[0].astype(x.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _sasp_kernel_int8(kn_ref, x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                      nnz: int):
    first, last = _flags(kn_ref, nnz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # int8 magnitude -> f32
    part = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_ref[...] += part * s_ref[0]           # dequant epilogue

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sasp_gemm(x: jnp.ndarray, w_vals: jnp.ndarray, block_kn: jnp.ndarray,
              *, n: int, block_m: int = 128,
              scales: Optional[jnp.ndarray] = None,
              out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) @ block-sparse weight -> (M, n), skipping pruned tiles.

    w_vals: (nnz, bk, bn) surviving blocks (fp, or int8 with ``scales``);
    block_kn: (2, nnz) int32 [k_block; n_block] sorted by (n, k), every
    n-block present ≥ once (ops.kernel_block_list guarantees this);
    scales: (nnz,) fp32 per-block dequant scales for the int8 variant.
    """
    M, K = x.shape
    nnz, bk, bn = w_vals.shape
    assert n % bn == 0 and K % bk == 0, (K, n, bk, bn)
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    grid = (M // bm, nnz)
    out_dtype = out_dtype or x.dtype

    x_spec = pl.BlockSpec((bm, bk), lambda i, s, kn: (i, kn[0, s]))
    w_spec = pl.BlockSpec((1, bk, bn), lambda i, s, kn: (s, 0, 0))
    o_spec = pl.BlockSpec((bm, bn), lambda i, s, kn: (i, kn[1, s]))

    if scales is None:
        return pl.pallas_call(
            functools.partial(_sasp_kernel, nnz=nnz),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=grid,
                in_specs=[x_spec, w_spec],
                out_specs=o_spec,
                scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((M, n), out_dtype),
            interpret=interpret,
        )(block_kn, x, w_vals)

    s_spec = pl.BlockSpec((1,), lambda i, s, kn: (s,))
    return pl.pallas_call(
        functools.partial(_sasp_kernel_int8, nnz=nnz),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[x_spec, w_spec, s_spec],
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, n), out_dtype),
        interpret=interpret,
    )(block_kn, x, w_vals, scales)


# ---------------------------------------------------------------------------
# Dense-grid masked variant (ablation): visits every (k, n) block and
# predicates the MXU issue on the mask — saves FLOPs but not DMA bytes,
# mirroring clock-gating designs the paper cites ([18]) as the inferior
# alternative to full tile skipping.
# ---------------------------------------------------------------------------


def _masked_kernel(mask_ref, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)

    @pl.when(mask_ref[k, j] > 0)
    def _mac():
        x = x_ref[...]
        acc_ref[...] += jnp.dot(x, w_ref[...].astype(x.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sasp_gemm_masked(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray,
                     *, block_m: int = 128, block_k: int = 128,
                     block_n: int = 128, out_dtype=None,
                     interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) @ w (K, N) with mask (KB, NB) int32; compute-skip only."""
    M, K = x.shape
    K2, N = w.shape
    KB, NB = mask.shape
    bk, bn = K // KB, N // NB
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        _masked_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // bm, NB, KB),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, m: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, m: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, m: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(mask.astype(jnp.int32), x, w)
