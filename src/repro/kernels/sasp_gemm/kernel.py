"""SASP tile-skip GEMM — the paper's systolic-array tile skipping (Fig 3)
as a TPU Pallas kernel.

TPU adaptation (DESIGN.md §2): instead of skipping weight-programming +
streaming on an edge array, the kernel's grid enumerates ONLY the surviving
weight blocks. The grid is (M-blocks × nnz): scalar-prefetched (k, n) block
coordinates drive the BlockSpec index maps, so pruned blocks are never
DMA'd from HBM and never enter the MXU — both the FLOP term and the
weight-byte term drop ∝ sparsity, exactly the paper's saving.

Visit order is sorted by (n, k) (see ops.kernel_block_list): all surviving
K-blocks of an output column-block are consecutive, so the output block
stays VMEM-resident; a float32 VMEM scratch accumulator re-initializes
when the n-coordinate changes and flushes on its last visit. Output
column-blocks with zero surviving weight blocks get one zero-valued
padding entry so every output block is written.

Variants:
  * fp32/bf16 values (``_sasp_kernel``);
  * fused INT8 dequant (``_sasp_kernel_int8``): int8 blocks ride HBM→VMEM
    at 1 byte/weight (the paper's 4-per-bus-word), and the per-block scale
    is applied as an epilogue after the MXU dot — the TPU analogue of the
    paper's hybrid FP32×INT8 multiplier (§3.3).

Fused epilogues (DESIGN.md §9): every variant optionally applies a
per-output-column bias and an elementwise activation inside the
``last``-visit flush — the (M, N) pre-activation never round-trips to
HBM, so serving-side bias+act costs zero extra memory traffic.

``sasp_fused_ffn`` goes one level further: the whole gated FFN
(w1/w3 up-projections, gate product, w2 down-projection) runs through a
single visit schedule over surviving d_ff column-blocks; the (M, d_ff)
intermediate lives only as one (bm, bf) VMEM tile per visit and is never
materialized in HBM.

Block shapes default to MXU-aligned 128 multiples; validated with
``interpret=True`` against ref.py on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Activations legal as flush-time epilogues. All map 0 -> 0 (except the
# identity), which the fused-FFN visit-skip rule relies on.
_ACTS = {
    None: lambda x: x,
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def _flags(kn_ref, nnz: int):
    s = pl.program_id(1)
    n_cur = kn_ref[1, s]
    n_prev = kn_ref[1, jnp.maximum(s, 1) - 1]
    first = jnp.logical_or(s == 0, n_cur != n_prev)
    n_next = kn_ref[1, jnp.minimum(s + 1, nnz - 1)]
    last = jnp.logical_or(s == nnz - 1, n_cur != n_next)
    return first, last


def _sasp_kernel(kn_ref, x_ref, w_ref, o_ref, acc_ref, *, nnz: int,
                 act: Optional[str] = None):
    first, last = _flags(kn_ref, nnz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[0].astype(x.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        o_ref[...] = _ACTS[act](acc_ref[...]).astype(o_ref.dtype)


def _sasp_kernel_bias(kn_ref, x_ref, w_ref, b_ref, o_ref, acc_ref, *,
                      nnz: int, act: Optional[str] = None):
    first, last = _flags(kn_ref, nnz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[0].astype(x.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        o_ref[...] = _ACTS[act](acc_ref[...] + b_ref[...]).astype(
            o_ref.dtype)


def _sasp_kernel_int8(kn_ref, x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                      nnz: int, act: Optional[str] = None):
    first, last = _flags(kn_ref, nnz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # int8 magnitude -> f32
    part = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_ref[...] += part * s_ref[0]           # dequant epilogue

    @pl.when(last)
    def _flush():
        o_ref[...] = _ACTS[act](acc_ref[...]).astype(o_ref.dtype)


def _sasp_kernel_int8_bias(kn_ref, x_ref, w_ref, s_ref, b_ref, o_ref,
                           acc_ref, *, nnz: int,
                           act: Optional[str] = None):
    first, last = _flags(kn_ref, nnz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    part = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_ref[...] += part * s_ref[0]

    @pl.when(last)
    def _flush():
        o_ref[...] = _ACTS[act](acc_ref[...] + b_ref[...]).astype(
            o_ref.dtype)


def sasp_gemm(x: jnp.ndarray, w_vals: jnp.ndarray, block_kn: jnp.ndarray,
              *, n: int, block_m: int = 128,
              scales: Optional[jnp.ndarray] = None,
              bias: Optional[jnp.ndarray] = None,
              act: Optional[str] = None,
              out_dtype=None, interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) @ block-sparse weight -> (M, n), skipping pruned tiles.

    w_vals: (nnz, bk, bn) surviving blocks (fp, or int8 with ``scales``);
    block_kn: (2, nnz) int32 [k_block; n_block] sorted by (n, k), every
    n-block present ≥ once (ops.kernel_block_list guarantees this);
    scales: (nnz,) fp32 per-block dequant scales for the int8 variant;
    bias: (n,) fp32 fused into the last-visit flush;
    act: None|"silu"|"gelu"|"relu" flush-time activation epilogue
    (applied after bias). Empty output columns flush ``act(bias)`` —
    exactly the masked-dense semantics ``act(x @ (w ⊙ mask) + b)``.
    """
    M, K = x.shape
    nnz, bk, bn = w_vals.shape
    assert n % bn == 0 and K % bk == 0, (K, n, bk, bn)
    assert act in _ACTS, act
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    grid = (M // bm, nnz)
    out_dtype = out_dtype or x.dtype

    x_spec = pl.BlockSpec((bm, bk), lambda i, s, kn: (i, kn[0, s]))
    w_spec = pl.BlockSpec((1, bk, bn), lambda i, s, kn: (s, 0, 0))
    o_spec = pl.BlockSpec((bm, bn), lambda i, s, kn: (i, kn[1, s]))
    s_spec = pl.BlockSpec((1,), lambda i, s, kn: (s,))
    b_spec = pl.BlockSpec((1, bn), lambda i, s, kn: (0, kn[1, s]))

    in_specs = [x_spec, w_spec]
    operands = [x, w_vals]
    if scales is None:
        body = _sasp_kernel if bias is None else _sasp_kernel_bias
    else:
        body = _sasp_kernel_int8 if bias is None else _sasp_kernel_int8_bias
        in_specs.append(s_spec)
        operands.append(scales)
    if bias is not None:
        in_specs.append(b_spec)
        operands.append(bias.astype(jnp.float32).reshape(1, n))

    return pl.pallas_call(
        functools.partial(body, nnz=nnz, act=act),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, n), out_dtype),
        interpret=interpret,
    )(block_kn, *operands)


# ---------------------------------------------------------------------------
# Fused gated FFN: act(x@W1 + b1) * (x@W3 + b3) @ W2 + b2 in ONE visit
# schedule over surviving d_ff column-blocks. The (M, d_ff) intermediate
# exists only as a (bm, bf) VMEM tile per visit — never in HBM — and the
# three kernel launches of the unfused path collapse to one.
# ---------------------------------------------------------------------------


def _fused_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, b1_ref, b3_ref,
                      b2_ref, o_ref, acc_ref, *, nv: int, act: str):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    u = jnp.dot(x, w1_ref[0].astype(x.dtype),
                preferred_element_type=jnp.float32) + b1_ref[...]
    g = jnp.dot(x, w3_ref[0].astype(x.dtype),
                preferred_element_type=jnp.float32) + b3_ref[...]
    h = (_ACTS[act](u) * g).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, w2_ref[0].astype(h.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(s == nv - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] + b2_ref[...]).astype(o_ref.dtype)


def _fused_ffn_kernel_int8(x_ref, w1_ref, w3_ref, w2_ref, s1_ref, s3_ref,
                           s2_ref, b1_ref, b3_ref, b2_ref, o_ref, acc_ref,
                           *, nv: int, act: str):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    u = jnp.dot(x, w1_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32) * s1_ref[0] + b1_ref[...]
    g = jnp.dot(x, w3_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32) * s3_ref[0] + b3_ref[...]
    h = _ACTS[act](u) * g
    acc_ref[...] += jnp.dot(h, w2_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32) * s2_ref[0]

    @pl.when(s == nv - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] + b2_ref[...]).astype(o_ref.dtype)


def sasp_fused_ffn(x: jnp.ndarray, w1v: jnp.ndarray, w3v: jnp.ndarray,
                   w2v: jnp.ndarray, b1: jnp.ndarray, b3: jnp.ndarray,
                   b2: jnp.ndarray, *, act: str = "silu",
                   block_m: int = 128, scales=None, out_dtype=None,
                   interpret: bool = True) -> jnp.ndarray:
    """Gated FFN through one Pallas visit schedule.

    x: (M, d); w1v/w3v: (nv, d, bf) surviving d_ff column-blocks of the
    up-projections (masked tiles zeroed in place); w2v: (nv, bf, d)
    matching down-projection row-blocks; b1/b3: (nv, bf) per-visit bias
    slices; b2: (d,). ``scales``: optional (s1, s3, s2) each (nv,) fp32
    for int8 w1v/w3v/w2v. Pruned d_ff column-blocks (zero up-column with
    zero bias, or zero w2 row) are simply absent from the visit list —
    the skip criterion in ops.build_fused_ffn relies on act(0) == 0.
    Returns (M, d) = act(x@W1+b1) * (x@W3+b3) @ W2 + b2.
    """
    M, d = x.shape
    nv, d2, bf = w1v.shape
    assert d2 == d and w2v.shape == (nv, bf, d), (w1v.shape, w2v.shape)
    assert act in _ACTS and act is not None
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    grid = (M // bm, nv)
    out_dtype = out_dtype or x.dtype

    x_spec = pl.BlockSpec((bm, d), lambda i, s: (i, 0))
    up_spec = pl.BlockSpec((1, d, bf), lambda i, s: (s, 0, 0))
    dn_spec = pl.BlockSpec((1, bf, d), lambda i, s: (s, 0, 0))
    bu_spec = pl.BlockSpec((1, bf), lambda i, s: (s, 0))
    b2_spec = pl.BlockSpec((1, d), lambda i, s: (0, 0))
    o_spec = pl.BlockSpec((bm, d), lambda i, s: (i, 0))

    b1 = b1.astype(jnp.float32).reshape(nv, bf)
    b3 = b3.astype(jnp.float32).reshape(nv, bf)
    b2 = b2.astype(jnp.float32).reshape(1, d)

    if scales is None:
        return pl.pallas_call(
            functools.partial(_fused_ffn_kernel, nv=nv, act=act),
            grid=grid,
            in_specs=[x_spec, up_spec, up_spec, dn_spec, bu_spec, bu_spec,
                      b2_spec],
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
            out_shape=jax.ShapeDtypeStruct((M, d), out_dtype),
            interpret=interpret,
        )(x, w1v, w3v, w2v, b1, b3, b2)

    s1, s3, s2 = scales
    sc_spec = pl.BlockSpec((1,), lambda i, s: (s,))
    return pl.pallas_call(
        functools.partial(_fused_ffn_kernel_int8, nv=nv, act=act),
        grid=grid,
        in_specs=[x_spec, up_spec, up_spec, dn_spec, sc_spec, sc_spec,
                  sc_spec, bu_spec, bu_spec, b2_spec],
        out_specs=o_spec,
        scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, d), out_dtype),
        interpret=interpret,
    )(x, w1v, w3v, w2v, s1, s3, s2, b1, b3, b2)


# ---------------------------------------------------------------------------
# Dense-grid masked variant (ablation): visits every (k, n) block and
# predicates the MXU issue on the mask — saves FLOPs but not DMA bytes,
# mirroring clock-gating designs the paper cites ([18]) as the inferior
# alternative to full tile skipping.
# ---------------------------------------------------------------------------


def _masked_kernel(mask_ref, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)

    @pl.when(mask_ref[k, j] > 0)
    def _mac():
        x = x_ref[...]
        acc_ref[...] += jnp.dot(x, w_ref[...].astype(x.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sasp_gemm_masked(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray,
                     *, block_m: int = 128, block_k: int = 128,
                     block_n: int = 128, out_dtype=None,
                     interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) @ w (K, N) with mask (KB, NB) int32; compute-skip only."""
    M, K = x.shape
    K2, N = w.shape
    KB, NB = mask.shape
    bk, bn = K // KB, N // NB
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        _masked_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // bm, NB, KB),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k, m: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k, m: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, m: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(mask.astype(jnp.int32), x, w)
