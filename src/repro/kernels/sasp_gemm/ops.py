"""Jit'd wrappers + block-list builders for the SASP tile-skip kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse import BlockSparseWeight
from repro.kernels.sasp_gemm.kernel import (
    sasp_fused_ffn,
    sasp_gemm,
    sasp_gemm_masked,
)


def flush_sorted_order(ks: np.ndarray, ns: np.ndarray, nb: int):
    """THE visit-order convention, in one place: append a k=0 flush
    entry for every output column in [0, nb) with no visit (so every
    output block initializes/flushes exactly once), then sort by
    (n, k). Returns (ks', ns', order, n_flush) — callers append
    ``n_flush`` zero-valued blocks/scales before applying ``order``.
    Shared by :func:`kernel_block_list` (mask path) and the elastic
    re-deploy slice path (``core.deploy._reshard_weight``), whose
    bit-identity contract depends on the two never diverging."""
    empty = np.setdiff1d(np.arange(nb), np.unique(ns))
    if empty.size:
        ks = np.concatenate([ks, np.zeros_like(empty)])
        ns = np.concatenate([ns, empty])
    return ks, ns, np.lexsort((ks, ns)), int(empty.size)


def kernel_block_list(mask: np.ndarray) -> np.ndarray:
    """(2, nnz') visit list sorted by (n, k). Output column-blocks with no
    surviving weight block get one zero-value padding entry (k=0) so every
    output block is initialized; callers must zero the corresponding
    w_vals entry (``build_kernel_weight`` does)."""
    mask = np.asarray(mask, dtype=bool)
    KB, NB = mask.shape
    ks, ns = np.nonzero(mask)
    ks, ns, order, _ = flush_sorted_order(ks, ns, NB)
    return np.stack([ks[order], ns[order]]).astype(np.int32)


def build_kernel_weight(w: np.ndarray, mask: np.ndarray, bk: int, bn: int,
                        *, quantize: bool = False):
    """Offline packing: (w_vals, block_kn[, scales]) for ``sasp_matmul``.
    Padding entries (empty output columns) carry zero blocks."""
    w = np.asarray(w, np.float32)
    mask = np.asarray(mask, bool)
    K, N = w.shape
    KB, NB = K // bk, N // bn
    kn = kernel_block_list(mask)
    wb = w.reshape(KB, bk, NB, bn)
    vals = np.stack([
        wb[k, :, n, :] if mask[k, n] else np.zeros((bk, bn), np.float32)
        for k, n in kn.T
    ]) if kn.shape[1] else np.zeros((1, bk, bn), np.float32)

    if not quantize:
        return jnp.asarray(vals), jnp.asarray(kn), None
    amax = np.abs(vals).max(axis=(1, 2))
    scales = (np.maximum(amax, 1e-12) / 127.0).astype(np.float32)
    q = np.clip(np.round(vals / scales[:, None, None]), -127, 127
                ).astype(np.int8)
    return jnp.asarray(q), jnp.asarray(kn), jnp.asarray(scales)


def pad_block_list(vals: np.ndarray, kn: np.ndarray,
                   scales: Optional[np.ndarray], nnz_to: int):
    """Pad a compact (vals, kn, scales) visit list to ``nnz_to`` entries by
    repeating the LAST visit's (k, n) coordinates with zero-valued blocks.

    Duplicating the last coordinate keeps the n-major visit order intact
    (the appended visits share the final n-block, so the accumulator is
    neither re-initialized nor flushed early — it just accumulates zeros
    and flushes the same value once more). This is what lets per-layer
    packs of different true nnz share one static nnz under
    ``lax.scan`` over stacked layers.
    """
    nnz = vals.shape[0]
    assert nnz_to >= nnz, (nnz_to, nnz)
    if nnz_to == nnz:
        return vals, kn, scales
    pad = nnz_to - nnz
    vals = np.concatenate(
        [vals, np.zeros((pad,) + vals.shape[1:], vals.dtype)])
    kn = np.concatenate([kn, np.repeat(kn[:, -1:], pad, axis=1)], axis=1)
    if scales is not None:
        scales = np.concatenate(
            [scales, np.zeros((pad,), scales.dtype)])
    return vals, kn, scales


def build_fused_ffn(w1: np.ndarray, w3: np.ndarray, w2: np.ndarray, *,
                    block_f: int, b1=None, b3=None, b2=None,
                    quantize: bool = False, nv_pad: Optional[int] = None,
                    return_visits: bool = False):
    """Offline packing for the fused gated-FFN kernel.

    w1/w3: (d, F) up-projections with pruned tiles already zeroed; w2:
    (F, d) down-projection likewise. A d_ff column-block j (width
    ``block_f``) is VISITED iff it can contribute to the output:

        any(w2[j·bf:(j+1)·bf, :] != 0)            # down row survives
        and (any(w1[:, j·bf:…] != 0) or any(b1_j))  # act(0 + 0) == 0
        and (any(w3[:, j·bf:…] != 0) or any(b3_j))  # 0 * anything == 0

    so fully pruned d_ff columns cost zero FLOPs AND zero weight bytes.
    Returns (w1v, w3v, w2v, b1v, b3v, b2, scales) — scales is None for fp
    or (s1, s3, s2) per-visit int8 scales. ``nv_pad`` pads the visit list
    with zero-w2v entries (for layer-stacked sharing of one static nv).
    ``return_visits`` appends jv, the (nv,) int32 d_ff block index of
    each visit (-1 for padding/empty entries) — consumed by
    ``core.deploy`` so packed containers stay re-shardable.
    """
    w1 = np.asarray(w1, np.float32)
    w3 = np.asarray(w3, np.float32)
    w2 = np.asarray(w2, np.float32)
    d, F = w1.shape
    assert w3.shape == (d, F) and w2.shape == (F, d), (
        w1.shape, w3.shape, w2.shape)
    bf = block_f
    assert F % bf == 0, (F, bf)
    FB = F // bf
    b1 = np.zeros((F,), np.float32) if b1 is None else np.asarray(
        b1, np.float32)
    b3 = np.zeros((F,), np.float32) if b3 is None else np.asarray(
        b3, np.float32)
    b2 = np.zeros((d,), np.float32) if b2 is None else np.asarray(
        b2, np.float32)

    keep = []
    for j in range(FB):
        sl = slice(j * bf, (j + 1) * bf)
        if not np.any(w2[sl]):
            continue
        if not (np.any(w1[:, sl]) or np.any(b1[sl])):
            continue
        if not (np.any(w3[:, sl]) or np.any(b3[sl])):
            continue
        keep.append(j)

    jv = np.asarray(keep if keep else [-1], np.int32)
    if keep:
        w1v = np.stack([w1[:, j * bf:(j + 1) * bf] for j in keep])
        w3v = np.stack([w3[:, j * bf:(j + 1) * bf] for j in keep])
        w2v = np.stack([w2[j * bf:(j + 1) * bf] for j in keep])
        b1v = np.stack([b1[j * bf:(j + 1) * bf] for j in keep])
        b3v = np.stack([b3[j * bf:(j + 1) * bf] for j in keep])
    else:
        # all of d_ff pruned: one zero visit so the output block still
        # initializes/flushes (result is exactly b2)
        w1v = np.zeros((1, d, bf), np.float32)
        w3v = np.zeros((1, d, bf), np.float32)
        w2v = np.zeros((1, bf, d), np.float32)
        b1v = np.zeros((1, bf), np.float32)
        b3v = np.zeros((1, bf), np.float32)

    if nv_pad is not None:
        nv = w1v.shape[0]
        assert nv_pad >= nv, (nv_pad, nv)
        if nv_pad > nv:
            pad = nv_pad - nv
            # zero w2v => padded visits contribute exactly nothing
            w1v = np.concatenate(
                [w1v, np.zeros((pad, d, bf), np.float32)])
            w3v = np.concatenate(
                [w3v, np.zeros((pad, d, bf), np.float32)])
            w2v = np.concatenate(
                [w2v, np.zeros((pad, bf, d), np.float32)])
            b1v = np.concatenate([b1v, np.zeros((pad, bf), np.float32)])
            b3v = np.concatenate([b3v, np.zeros((pad, bf), np.float32)])
            jv = np.concatenate([jv, np.full((pad,), -1, np.int32)])

    scales = None
    if quantize:
        def q(v):
            amax = np.abs(v).max(axis=tuple(range(1, v.ndim)))
            s = (np.maximum(amax, 1e-12) / 127.0).astype(np.float32)
            qv = np.clip(np.round(v / s.reshape((-1,) + (1,) * (v.ndim - 1))),
                         -127, 127).astype(np.int8)
            return qv, s
        w1v, s1 = q(w1v)
        w3v, s3 = q(w3v)
        w2v, s2 = q(w2v)
        scales = (jnp.asarray(s1), jnp.asarray(s3), jnp.asarray(s2))

    out = (jnp.asarray(w1v), jnp.asarray(w3v), jnp.asarray(w2v),
           jnp.asarray(b1v), jnp.asarray(b3v), jnp.asarray(b2), scales)
    if return_visits:
        out = out + (jnp.asarray(jv),)
    return out


@functools.partial(jax.jit,
                   static_argnames=("act", "block_m", "interpret"))
def _fused_ffn_jit(x, w1v, w3v, w2v, b1, b3, b2, scales, *, act, block_m,
                   interpret):
    return sasp_fused_ffn(x, w1v, w3v, w2v, b1, b3, b2, act=act,
                          block_m=block_m, scales=scales,
                          interpret=interpret)


def fused_ffn_matmul(x: jnp.ndarray, w1v, w3v, w2v, b1, b3, b2, *,
                     scales=None, act: str = "silu", block_m: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """(…, d) -> (…, d) gated FFN through the single fused kernel."""
    *lead, d = x.shape
    y = _fused_ffn_jit(x.reshape(-1, d), w1v, w3v, w2v, b1, b3, b2,
                       scales, act=act, block_m=block_m,
                       interpret=interpret)
    return y.reshape(*lead, d).astype(x.dtype)


def _kn_from_bsr(w: BlockSparseWeight) -> Tuple:
    """Flatten a BSR container to the kernel's flat-block-list form."""
    K, N = w.shape
    bk, bn = w.block
    KB, NB = K // bk, N // bn
    idx = np.asarray(w.idx)                       # (k_max, NB)
    vals = np.asarray(w.vals)                     # (k_max, NB, bk, bn)
    scale = None if w.scale is None else np.asarray(w.scale)
    kn_list, v_list, s_list = [], [], []
    for n in range(NB):
        seen = set()
        wrote = False
        for j in range(w.k_max):
            k = int(idx[j, n])
            vb = vals[j, n]
            if (k in seen) and not np.any(vb):
                continue                          # padding duplicate
            seen.add(k)
            kn_list.append((k, n))
            v_list.append(vb)
            s_list.append(1.0 if scale is None else float(scale[j, n]))
            wrote = True
        if not wrote:
            kn_list.append((0, n))
            v_list.append(np.zeros_like(vals[0, 0]))
            s_list.append(1.0)
    kn = np.asarray(kn_list, np.int32).T
    v = np.stack(v_list)
    s = None if scale is None else np.asarray(s_list, np.float32)
    return jnp.asarray(v), jnp.asarray(kn), \
        None if s is None else jnp.asarray(s)


@functools.partial(jax.jit,
                   static_argnames=("n", "block_m", "act", "interpret"))
def _sasp_matmul_jit(x, w_vals, block_kn, scales, bias=None, *, n,
                     block_m, act=None, interpret):
    return sasp_gemm(x, w_vals, block_kn, n=n, block_m=block_m,
                     scales=scales, bias=bias, act=act,
                     interpret=interpret)


def _kn_from_bsr_traced(w: BlockSparseWeight):
    """Trace-compatible BSR→flat-list: every padded (j, n) slot becomes a
    visit in n-major order (consecutive visits share the output block, as
    the kernel requires); padding slots carry zero values and contribute
    nothing. nnz = k_max × NB is static; the coordinates are runtime
    arrays (scalar-prefetch operands may be traced)."""
    K, N = w.shape
    bk, bn = w.block
    k_max, NB = w.idx.shape
    vals = jnp.moveaxis(w.vals, 0, 1).reshape(k_max * NB, bk, bn)
    kn = jnp.stack([
        jnp.moveaxis(w.idx, 0, 1).reshape(-1),
        jnp.repeat(jnp.arange(NB, dtype=jnp.int32), k_max),
    ]).astype(jnp.int32)
    scales = None
    if w.scale is not None:
        scales = jnp.moveaxis(w.scale, 0, 1).reshape(-1)
    return vals, kn, scales


def sasp_matmul(x: jnp.ndarray, w: BlockSparseWeight, *,
                block_m: int = 128, interpret: bool = True) -> jnp.ndarray:
    """(…, K) @ BSR weight -> (…, N) through the Pallas tile-skip kernel.
    Works under tracing (scan-over-layers) via the padded flat list;
    serving engines should pre-pack the compact form with
    ``build_kernel_weight`` + ``sasp_matmul_packed``."""
    *lead, K = x.shape
    x2 = x.reshape(-1, K)
    w_vals, block_kn, scales = _kn_from_bsr_traced(w)
    y = _sasp_matmul_jit(x2, w_vals, block_kn, scales, n=w.shape[1],
                         block_m=block_m, interpret=interpret)
    return y.reshape(*lead, w.shape[1]).astype(x.dtype)


def sasp_matmul_packed(x: jnp.ndarray, w_vals, block_kn, scales=None, *,
                       n: int, block_m: int = 128, bias=None,
                       act: Optional[str] = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Pre-packed fast path (serving): inputs from build_kernel_weight.
    ``bias``/``act`` run as flush-time epilogues inside the kernel."""
    *lead, K = x.shape
    y = _sasp_matmul_jit(x.reshape(-1, K), w_vals, block_kn, scales, bias,
                         n=n, block_m=block_m, act=act,
                         interpret=interpret)
    return y.reshape(*lead, n).astype(x.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_k", "block_n",
                                    "interpret"))
def masked_matmul(x, w, mask, *, block_m: int = 128, block_k: int = 128,
                  block_n: int = 128, interpret: bool = True):
    """Dense-grid compute-skip variant (ablation)."""
    return sasp_gemm_masked(x, w, mask, block_m=block_m, block_k=block_k,
                            block_n=block_n, interpret=interpret)
