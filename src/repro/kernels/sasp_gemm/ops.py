"""Jit'd wrappers + block-list builders for the SASP tile-skip kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse import BlockSparseWeight
from repro.kernels.sasp_gemm.kernel import sasp_gemm, sasp_gemm_masked


def kernel_block_list(mask: np.ndarray) -> np.ndarray:
    """(2, nnz') visit list sorted by (n, k). Output column-blocks with no
    surviving weight block get one zero-value padding entry (k=0) so every
    output block is initialized; callers must zero the corresponding
    w_vals entry (``build_kernel_weight`` does)."""
    mask = np.asarray(mask, dtype=bool)
    KB, NB = mask.shape
    ks, ns = np.nonzero(mask)
    empty_cols = np.setdiff1d(np.arange(NB), np.unique(ns))
    if empty_cols.size:
        ks = np.concatenate([ks, np.zeros_like(empty_cols)])
        ns = np.concatenate([ns, empty_cols])
    order = np.lexsort((ks, ns))
    return np.stack([ks[order], ns[order]]).astype(np.int32)


def build_kernel_weight(w: np.ndarray, mask: np.ndarray, bk: int, bn: int,
                        *, quantize: bool = False):
    """Offline packing: (w_vals, block_kn[, scales]) for ``sasp_matmul``.
    Padding entries (empty output columns) carry zero blocks."""
    w = np.asarray(w, np.float32)
    mask = np.asarray(mask, bool)
    K, N = w.shape
    KB, NB = K // bk, N // bn
    kn = kernel_block_list(mask)
    wb = w.reshape(KB, bk, NB, bn)
    vals = np.stack([
        wb[k, :, n, :] if mask[k, n] else np.zeros((bk, bn), np.float32)
        for k, n in kn.T
    ]) if kn.shape[1] else np.zeros((1, bk, bn), np.float32)

    if not quantize:
        return jnp.asarray(vals), jnp.asarray(kn), None
    amax = np.abs(vals).max(axis=(1, 2))
    scales = (np.maximum(amax, 1e-12) / 127.0).astype(np.float32)
    q = np.clip(np.round(vals / scales[:, None, None]), -127, 127
                ).astype(np.int8)
    return jnp.asarray(q), jnp.asarray(kn), jnp.asarray(scales)


def _kn_from_bsr(w: BlockSparseWeight) -> Tuple:
    """Flatten a BSR container to the kernel's flat-block-list form."""
    K, N = w.shape
    bk, bn = w.block
    KB, NB = K // bk, N // bn
    idx = np.asarray(w.idx)                       # (k_max, NB)
    vals = np.asarray(w.vals)                     # (k_max, NB, bk, bn)
    scale = None if w.scale is None else np.asarray(w.scale)
    kn_list, v_list, s_list = [], [], []
    for n in range(NB):
        seen = set()
        wrote = False
        for j in range(w.k_max):
            k = int(idx[j, n])
            vb = vals[j, n]
            if (k in seen) and not np.any(vb):
                continue                          # padding duplicate
            seen.add(k)
            kn_list.append((k, n))
            v_list.append(vb)
            s_list.append(1.0 if scale is None else float(scale[j, n]))
            wrote = True
        if not wrote:
            kn_list.append((0, n))
            v_list.append(np.zeros_like(vals[0, 0]))
            s_list.append(1.0)
    kn = np.asarray(kn_list, np.int32).T
    v = np.stack(v_list)
    s = None if scale is None else np.asarray(s_list, np.float32)
    return jnp.asarray(v), jnp.asarray(kn), \
        None if s is None else jnp.asarray(s)


@functools.partial(jax.jit, static_argnames=("n", "block_m", "interpret"))
def _sasp_matmul_jit(x, w_vals, block_kn, scales, *, n, block_m,
                     interpret):
    return sasp_gemm(x, w_vals, block_kn, n=n, block_m=block_m,
                     scales=scales, interpret=interpret)


def _kn_from_bsr_traced(w: BlockSparseWeight):
    """Trace-compatible BSR→flat-list: every padded (j, n) slot becomes a
    visit in n-major order (consecutive visits share the output block, as
    the kernel requires); padding slots carry zero values and contribute
    nothing. nnz = k_max × NB is static; the coordinates are runtime
    arrays (scalar-prefetch operands may be traced)."""
    K, N = w.shape
    bk, bn = w.block
    k_max, NB = w.idx.shape
    vals = jnp.moveaxis(w.vals, 0, 1).reshape(k_max * NB, bk, bn)
    kn = jnp.stack([
        jnp.moveaxis(w.idx, 0, 1).reshape(-1),
        jnp.repeat(jnp.arange(NB, dtype=jnp.int32), k_max),
    ]).astype(jnp.int32)
    scales = None
    if w.scale is not None:
        scales = jnp.moveaxis(w.scale, 0, 1).reshape(-1)
    return vals, kn, scales


def sasp_matmul(x: jnp.ndarray, w: BlockSparseWeight, *,
                block_m: int = 128, interpret: bool = True) -> jnp.ndarray:
    """(…, K) @ BSR weight -> (…, N) through the Pallas tile-skip kernel.
    Works under tracing (scan-over-layers) via the padded flat list;
    serving engines should pre-pack the compact form with
    ``build_kernel_weight`` + ``sasp_matmul_packed``."""
    *lead, K = x.shape
    x2 = x.reshape(-1, K)
    w_vals, block_kn, scales = _kn_from_bsr_traced(w)
    y = _sasp_matmul_jit(x2, w_vals, block_kn, scales, n=w.shape[1],
                         block_m=block_m, interpret=interpret)
    return y.reshape(*lead, w.shape[1]).astype(x.dtype)


def sasp_matmul_packed(x: jnp.ndarray, w_vals, block_kn, scales=None, *,
                       n: int, block_m: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """Pre-packed fast path (serving): inputs from build_kernel_weight."""
    *lead, K = x.shape
    y = _sasp_matmul_jit(x.reshape(-1, K), w_vals, block_kn, scales,
                         n=n, block_m=block_m, interpret=interpret)
    return y.reshape(*lead, n).astype(x.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_k", "block_n",
                                    "interpret"))
def masked_matmul(x, w, mask, *, block_m: int = 128, block_k: int = 128,
                  block_n: int = 128, interpret: bool = True):
    """Dense-grid compute-skip variant (ablation)."""
    return sasp_gemm_masked(x, w, mask, block_m=block_m, block_k=block_k,
                            block_n=block_n, interpret=interpret)
