"""Flash-attention Pallas kernel (TPU target, interpret-validated).

Complements the SASP GEMM kernels: attention is the other compute
hot-spot of every assigned transformer. Grid = (batch·kv-heads·groups,
Q-blocks); the kernel walks KV blocks with a VMEM-resident online-softmax
accumulator (m, l, acc) — the jnp chunked attention in models/attention.py
is the oracle-equivalent reference structure.

Supports causal masking and sliding windows (gemma3's local layers) via
absolute-position operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, kv_blocks: int, block_k: int,
                  window: int, scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (bq, d)
    k = k_ref[0]                                  # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    qp = qpos_ref[...]                            # (bq,)
    kp = kpos_ref[...]                            # (bk,)
    delta = qp[:, None] - kp[None, :]
    mask = (delta >= 0) & (delta < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == kv_blocks - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, q_pos, kv_pos, *, window: int,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (H, Sq, D); k/v: (H, Sk, D); positions absolute int32.
    Key j visible to query i iff 0 <= q_pos[i] - kv_pos[j] < window
    (window >= Sk => plain causal). Returns (H, Sq, D).

    Batch/GQA layouts fold into H upstream (ops.py)."""
    H, Sq, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Sk)
    while Sk % bk:
        bk -= 1
    grid = (H, Sq // bq, Sk // bk)
    scale = D ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_blocks=Sk // bk, block_k=bk,
                          window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda h, i, j: (i,)),       # q positions
            pl.BlockSpec((bk,), lambda h, i, j: (j,)),       # kv positions
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        out_shape=jax.ShapeDtypeStruct((H, Sq, D), q.dtype),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32), q, k, v)
