"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def flash_attention_ref(q, k, v, q_pos, kv_pos, *, window: int):
    """Dense masked softmax attention. q: (H, Sq, D); k/v: (H, Sk, D)."""
    D = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    delta = q_pos[:, None] - kv_pos[None, :]
    mask = (delta >= 0) & (delta < window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)
    return jnp.einsum("hqk,hkd->hqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
