"""Jit'd wrapper: fold (B, S, KH, G, D) GQA layouts into the kernel's
(H, S, D) form."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def mha(q, k, v, q_pos, kv_pos, *, window: int, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D) with H % KH == 0 (GQA).
    Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    o = flash_attention(qf, kf, vf, q_pos, kv_pos, window=window,
                        interpret=interpret)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
