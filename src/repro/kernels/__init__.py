from repro.kernels import sasp_gemm, int8_gemm, flash_attn  # noqa: F401
