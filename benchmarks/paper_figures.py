"""One benchmark per paper table/figure (DESIGN.md §8).

Each function returns a list of CSV rows (name, us_per_call, derived)
and prints a human-readable block. ``us_per_call`` is the modeled edge
runtime (µs) where the figure is model-driven, or a measured wall time
for kernel benches.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import emit, load_qos, measured_qos_fn
from repro.core.codesign import (
    best_under_qos,
    exponential_qos_proxy,
    pareto_front,
    speedup_at_fixed_qos,
    sweep,
)
from repro.core.cost_model import (
    GEMMWork,
    SystolicConfig,
    cpu_time_s,
    encoder_gemms,
    energy_j,
    scale_to_t_base,
    speedup_vs_cpu,
    workload_time_s,
)

# paper Table 1 rows (workload = encoder GEMM mix)
WORKLOADS = {
    "espnet-asr": dict(num_layers=18, d_model=512, d_ff=2048, seq=512),
    "espnet2-asr": dict(num_layers=12, d_model=512, d_ff=2048, seq=512),
    "espnet2-asr-mt": dict(num_layers=24, d_model=320, d_ff=1536,
                           seq=512),   # ASR+MT cascade (averaged dims)
}

PAPER_TABLE3 = {
    # (quant, size): (area mm2, nosasp speedup, nosasp E, prune%, sasp
    #                 speedup, sasp E)
    ("fp32", 4): (0.05, 8.42, 1.60, 25, 10.56, 1.27),
    ("fp32", 8): (0.21, 19.79, 3.09, 25, 25.01, 2.43),
    ("fp32", 16): (0.83, 35.22, 6.37, 20, 42.21, 5.28),
    ("fp32", 32): (3.34, 50.95, 15.32, 20, 60.91, 12.70),
    ("int8", 4): (0.03, 8.03, 1.18, 25, 10.08, 0.99),
    ("int8", 8): (0.14, 20.18, 2.67, 20, 24.23, 2.21),
    ("int8", 16): (0.53, 36.53, 4.57, 20, 43.74, 3.79),
    ("int8", 32): (2.13, 61.33, 10.64, 20, 73.25, 8.82),
}


def _qos_fn():
    qos = load_qos()
    if qos is not None:
        return measured_qos_fn(qos), "measured"
    return exponential_qos_proxy(), "proxy"


def _qos_target(default: float = 5.0) -> float:
    """Paper target = base WER + 1.5pt headroom (3.5% -> 5%). Our
    trained model's base TER differs slightly, so the fair target is
    base + 1.5 (not an absolute 5%)."""
    qos = load_qos()
    if qos is not None:
        return qos["base_ter"] + 1.5
    return default


def _builder(wl: str):
    kw = WORKLOADS[wl]
    return lambda s: encoder_gemms(ffn_sparsity=s, **kw)


# ---------------------------------------------------------------------------
# Fig 6 — area / power vs array size × quantization
# ---------------------------------------------------------------------------


def fig6_area_power() -> List:
    print("\n== Fig 6: synthesis (area/power) across array sizes ==")
    rows = []
    for size in (4, 8, 16, 32):
        for quant in ("fp32", "int8"):
            sa = SystolicConfig(size=size, quant=quant)
            print(f"  {size:2d}x{size:<2d} {quant}: area={sa.area_mm2:6.3f}"
                  f" mm2  power={sa.power_w*1e3:8.1f} mW")
            rows.append((f"fig6/{quant}/{size}x{size}", 0.0,
                         f"area_mm2={sa.area_mm2:.4f};"
                         f"power_w={sa.power_w:.4f}"))
    a_sav = 1 - SystolicConfig(8, "int8").area_mm2 / \
        SystolicConfig(8, "fp32").area_mm2
    p_sav = 1 - SystolicConfig(8, "int8").power_w / \
        SystolicConfig(8, "fp32").power_w
    print(f"  INT8 savings: area {a_sav:.1%} (paper avg 35.3%), "
          f"power {p_sav:.1%} (paper avg 19.5%)")
    return rows


# ---------------------------------------------------------------------------
# Fig 7 — SASP speedup/energy at QoS target, per workload × array size
# ---------------------------------------------------------------------------


def fig7_speedup_energy(qos_target: float = None) -> List:
    qos_target = qos_target or _qos_target()
    qos_fn, src = _qos_fn()
    print(f"\n== Fig 7: SASP gains at QoS<= {qos_target}% ({src} QoS), "
          f"vs non-pruned INT8 executions ==")
    rows = []
    for wl in WORKLOADS:
        builder = _builder(wl)
        pts = sweep(builder, qos_fn, quants=("int8",))
        sel = best_under_qos(pts, qos_target)
        for size in (4, 8, 16, 32):
            sa = SystolicConfig(size, "int8")
            base_t = workload_time_s(sa, builder(0.0))
            base_e = energy_j(sa, builder(0.0))
            p = sel.get((size, "int8"))
            if p is None:
                continue
            sp = base_t / (p.time_s / scale_to_t_base(builder(0.0)))
            en = 1 - p.energy_j / base_e
            print(f"  {wl:16s} {size:2d}x{size:<2d}: speedup +{sp-1:6.1%} "
                  f"energy -{en:6.1%} @prune {p.sparsity:.0%}")
            rows.append((f"fig7/{wl}/{size}", p.time_s * 1e6,
                         f"speedup_gain={sp-1:.3f};energy_gain={en:.3f};"
                         f"prune={p.sparsity}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 8 — per-layer runtime after global pruning (trained model)
# ---------------------------------------------------------------------------


def fig8_per_layer() -> List:
    qos = load_qos()
    rows = []
    print("\n== Fig 8: per-FFN-matrix sparsity under a global budget ==")
    if qos is None:
        print("  (qos cache missing — run benchmarks.qos_harness)")
        return rows
    for rate, per in qos["per_layer"].items():
        print(f"  global rate {rate}:")
        for name, sp in sorted(per.items()):
            short = name.replace("segments/0/", "").replace("/w", "")
            bar = "#" * int(sp * 40)
            print(f"    {short:28s} prune={sp:6.1%} |{bar}")
            rows.append((f"fig8/{rate}/{short}", 0.0,
                         f"layer_sparsity={sp:.4f};"
                         f"runtime_share={1-sp:.4f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 9 — QoS vs pruning rate × tile size (trained model)
# ---------------------------------------------------------------------------


def fig9_qos_curves() -> List:
    qos = load_qos()
    rows = []
    print("\n== Fig 9: TER (≙WER) vs SASP rate ==")
    if qos is None:
        print("  (qos cache missing)")
        return rows
    by = {}
    for r in qos["records"]:
        by.setdefault((r["tile"], r["quant"]), []).append(r)
    for (tile, quant), rs in sorted(by.items()):
        rs.sort(key=lambda r: r["rate"])
        curve = " ".join(f"{r['rate']:.1f}:{r['ter']:.2f}" for r in rs)
        print(f"  tile={tile:2d} {quant}: {curve}")
        for r in rs:
            rows.append((f"fig9/{quant}/t{tile}/r{r['rate']}", 0.0,
                         f"ter={r['ter']:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 10 — speedup × QoS × area-energy trade-off scatter
# ---------------------------------------------------------------------------


def fig10_tradeoff() -> List:
    qos_fn, src = _qos_fn()
    print(f"\n== Fig 10: trade-off scatter ({src} QoS) ==")
    builder = _builder("espnet-asr")
    pts = sweep(builder, qos_fn)
    front = pareto_front(pts)
    rows = []
    for p in sorted(front, key=lambda p: (p.tile, p.quant, p.sparsity)):
        print(f"  PARETO tile={p.tile:2d} {p.quant} prune={p.sparsity:.0%}"
              f" qos={p.qos:5.2f} speedup={p.speedup:6.2f}"
              f" AE={p.area_energy:8.3f}")
        rows.append((f"fig10/{p.quant}/t{p.tile}/s{p.sparsity:.2f}",
                     p.time_s * 1e6,
                     f"qos={p.qos:.3f};speedup={p.speedup:.2f};"
                     f"area_energy={p.area_energy:.4f};pareto=1"))
    print(f"  {len(front)}/{len(pts)} points on the Pareto front")
    return rows


# ---------------------------------------------------------------------------
# Fig 11 — sublinear speedup vs array size at fixed QoS
# ---------------------------------------------------------------------------


def fig11_sublinear() -> List:
    qos_fn, src = _qos_fn()
    builder = _builder("espnet-asr")
    pts = sweep(builder, qos_fn)
    rows = []
    print(f"\n== Fig 11: speedup vs array size at fixed QoS ({src}) ==")
    for target in (4.0, 5.0, 7.0):
        sel = speedup_at_fixed_qos(pts, target, "int8")
        if len(sel) < 2:
            continue
        sizes = sorted(sel)
        sps = [sel[s] for s in sizes]
        # sublinearity: speedup ratio grows slower than PE-count ratio
        ratio = (sps[-1] / sps[0]) / ((sizes[-1] / sizes[0]) ** 2)
        print(f"  QoS<={target}: " + " ".join(
            f"{s}x{s}:{v:.1f}" for s, v in sel.items())
            + f"   (vs quadratic PE growth: {ratio:.2f}x)")
        for s, v in sel.items():
            rows.append((f"fig11/q{target}/{s}", 0.0,
                         f"speedup={v:.2f};sublinearity={ratio:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 3 — reproduction vs paper, cell by cell
# ---------------------------------------------------------------------------


def table3() -> List:
    qos_fn, src = _qos_fn()
    builder = _builder("espnet-asr")
    pts = sweep(builder, qos_fn)
    sel = best_under_qos(pts, _qos_target())
    rows = []
    print(f"\n== Table 3 reproduction ({src} QoS) — ours vs paper ==")
    print("  cfg          area    speedup(noSASP)   prune%   "
          "speedup(SASP)")
    for (quant, size), pap in sorted(PAPER_TABLE3.items()):
        sa = SystolicConfig(size, quant)
        no_sp = speedup_vs_cpu(sa, builder(0.0))
        p = sel.get((size, quant))
        sp = p.speedup if p else float("nan")
        pr = p.sparsity * 100 if p else float("nan")
        print(f"  {quant}@{size:<3d} {sa.area_mm2:5.2f}/{pap[0]:5.2f}  "
              f"{no_sp:6.2f}/{pap[1]:6.2f}      {pr:3.0f}/{pap[3]:3.0f}  "
              f"  {sp:6.2f}/{pap[4]:6.2f}")
        rows.append((f"table3/{quant}/{size}", 0.0,
                     f"area={sa.area_mm2:.3f};paper_area={pap[0]};"
                     f"speedup={no_sp:.2f};paper_speedup={pap[1]};"
                     f"sasp_speedup={sp:.2f};paper_sasp={pap[4]}"))
    # headline: SASP+quant vs dense fp32 at 32x32
    base = speedup_vs_cpu(SystolicConfig(32, "fp32"), builder(0.0))
    p = sel.get((32, "int8"))
    if p:
        gain = p.speedup / base - 1
        print(f"  headline 32x32 SASP+INT8 vs dense FP32: +{gain:.0%} "
              f"(paper: +44%)")
        rows.append(("table3/headline", 0.0,
                     f"system_gain={gain:.3f};paper=0.44"))
    return rows
