"""Ablation of the paper's scope choice (§3.1: "feed-forward GEMMs are
much more amenable to pruning than attention ones"). Trains the QoS model
once and compares TER degradation with scope='ffn' (paper) vs scope='all'
(attention projections included) at matched GLOBAL sparsity.

Appends results to experiments/qos_scope_ablation.json.
"""
from __future__ import annotations

import json
import os

from repro.configs import SASPConfig
from repro.core.sasp import build_sasp_overlay

from benchmarks.qos_harness import token_error_rate, train_qos_model

OUT = os.path.join("experiments", "qos_scope_ablation.json")


def main(steps: int = 500):
    cfg, params, losses = train_qos_model(steps=steps)
    base = token_error_rate(params, cfg)
    print(f"base TER {base:.2f}%")
    rows = []
    for scope in ("ffn", "all"):
        for rate in (0.1, 0.2, 0.3, 0.4, 0.5):
            sasp = SASPConfig(enabled=True, block_k=8, block_n=8,
                              sparsity=rate, scope=scope)
            overlay, got = build_sasp_overlay(params, sasp)
            ter = token_error_rate(params, cfg, overlay=overlay)
            rows.append({"scope": scope, "rate": rate,
                         "achieved": got, "ter": ter})
            print(f"  scope={scope:4s} rate={rate:.1f} "
                  f"(achieved {got:.2f}) -> TER {ter:5.2f}%")
    os.makedirs("experiments", exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"base_ter": base, "rows": rows}, f, indent=1)

    # paper claim check: at every rate, scope='all' (attention included)
    # should degrade at least as much as scope='ffn'
    by = {(r["scope"], r["rate"]): r["ter"] for r in rows}
    worse = sum(int(by[("all", r)] >= by[("ffn", r)] - 0.1)
                for r in (0.1, 0.2, 0.3, 0.4, 0.5))
    print(f"\nattn-in-scope >= ffn-only degradation at {worse}/5 rates "
          f"(paper: attention is brittle)")


if __name__ == "__main__":
    main()
