"""Kernel microbenchmarks (interpret mode on CPU — wall numbers are for
relative comparison between paths; the TPU-relevant numbers are the
FLOP/byte reductions, which are exact)."""
from __future__ import annotations

from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core.sparse import bsr_from_mask, bsr_matmul
from repro.kernels.sasp_gemm import ops as sasp_ops

RNG = np.random.default_rng(0)


def bench_kernels() -> List:
    rows = []
    print("\n== kernel microbench (CPU; interpret mode) ==")
    for (M, K, N, bk, bn, sp) in [
        (128, 512, 512, 64, 64, 0.0),
        (128, 512, 512, 64, 64, 0.25),
        (128, 512, 512, 64, 64, 0.5),
        (128, 512, 512, 64, 64, 0.75),
    ]:
        x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
        w = RNG.normal(size=(K, N)).astype(np.float32)
        mask = RNG.random((K // bk, N // bn)) >= sp
        dense_t = time_fn(jax.jit(lambda a, b: a @ b), x, jnp.asarray(w))

        bsr = bsr_from_mask(w, mask, bk, bn)
        bsr_t = time_fn(jax.jit(bsr_matmul), x, bsr)

        wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
        kern = lambda xx: sasp_ops.sasp_matmul_packed(xx, wv, kn, n=N)
        kern_t = time_fn(kern, x)

        flop_frac = mask.mean()
        print(f"  M{M} K{K} N{N} b{bk} sp={sp:.2f}: dense={dense_t:8.0f}us"
              f" bsr={bsr_t:8.0f}us pallas(intp)={kern_t:9.0f}us "
              f" flops x{flop_frac:.2f}")
        rows.append((f"kern/sasp/sp{sp:.2f}", kern_t,
                     f"dense_us={dense_t:.0f};bsr_us={bsr_t:.0f};"
                     f"flop_frac={flop_frac:.3f}"))
    return rows
