"""The 40-cell roofline table, read from the dry-run artifacts
(experiments/dryrun/*.json). See EXPERIMENTS.md §Roofline."""
from __future__ import annotations

from typing import List

from benchmarks.common import load_dryrun_reports


def bench_roofline() -> List:
    reports = load_dryrun_reports()
    rows = []
    print("\n== roofline table (from dry-run artifacts) ==")
    if not reports:
        print("  (no dry-run artifacts — run python -m repro.launch.dryrun"
              " --all)")
        return rows
    print(f"  {'arch':26s} {'shape':12s} {'mesh':8s} "
          f"{'bound':>9s} {'bottleneck':10s} {'useful':>7s} {'fits':>4s}")
    for r in reports:
        if r.get("note"):
            continue                    # variants reported in §Perf
        print(f"  {r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['bound_s']*1e3:8.2f}ms {r['bottleneck']:10s} "
              f"{min(r['useful_flops_frac'],9.99):6.1%} "
              f"{'Y' if r['fits_hbm'] else 'N':>4s}")
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r["bound_s"] * 1e6,
            f"bottleneck={r['bottleneck']};"
            f"compute_s={r['compute_s']:.5f};"
            f"memory_s={r['memory_s']:.5f};"
            f"collective_s={r['collective_s']:.5f};"
            f"useful={r['useful_flops_frac']:.3f};"
            f"fits={int(r['fits_hbm'])}"))
    return rows
