"""QoS reproduction tier (paper §3.1/§4.4): train a transformer encoder
on the synthetic transcription task, then sweep SASP (tile size ×
pruning rate × quantization) and measure token error rate (≙ WER).

Results are cached to ``experiments/qos_results.json`` so the per-figure
benchmarks (Fig 8/9/10/11, Table 3) replay without retraining.

Model: a causal "encoder" predicting the token at each position from its
noisy embedding (per-position classification; TER = per-position error
rate — the same metric shape as WER). The pruning algorithm, scope
(FF GEMMs), global-L1 selection and sweep axes are exactly the paper's.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import (
    compute_sasp_masks,
    per_matrix_sparsity,
    prune_params,
)
from repro.core.sasp import build_sasp_overlay, merge_overlay, \
    quantize_params
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import lm
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step
from repro.train.schedule import warmup_cosine

CACHE = os.path.join("experiments", "qos_results.json")

# QoS-tier model: the paper's ESPnet2 MT encoder row, reduced to fit the
# 1-core CPU training budget while keeping its family (plain FFN, gelu).
QOS_VOCAB = 64
QOS_SEQ = 64
QOS_BATCH = 16
QOS_NOISE = 2.5   # calibrated so base TER lands near the paper's 3.5% WER


def qos_config():
    cfg = reduced(get_config("paper-espnet2-mt"), layers=4, d_model=128,
                  vocab=QOS_VOCAB)
    return dataclasses.replace(cfg, d_ff=512, num_heads=4, num_kv_heads=4,
                               head_dim=32)


def _per_position_loss(params, cfg, batch, overlay=None):
    pv = merge_overlay(params, overlay) if overlay is not None else params
    logits = lm.forward(pv, cfg, batch["tokens"],
                        embeds=batch.get("embeds"))
    tgt = batch["tokens"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    sel = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - sel)


def train_qos_model(steps: int = 400, seed: int = 0):
    cfg = qos_config()
    dcfg = DataConfig(vocab_size=QOS_VOCAB, seq_len=QOS_SEQ,
                      global_batch=QOS_BATCH, seed=seed)
    pipe = Pipeline(dcfg, kind="asr", d_model=cfg.d_model,
                    noise=QOS_NOISE)
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)
    sched = warmup_cosine(40, steps)

    @jax.jit
    def step(params, opt, batch, step_no):
        def loss(p):
            return _per_position_loss(p, cfg, batch), {}

        (l, _), g = jax.value_and_grad(loss, has_aux=True)(params)
        from repro.train.optimizer import adamw_update
        params, opt = adamw_update(g, opt, params, opt_cfg,
                                   lr_scale=sched(step_no))
        return params, opt, l

    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        params, opt, l = step(params, opt, b, jnp.asarray(i))
        losses.append(float(l))
    return cfg, params, losses


def token_error_rate(params, cfg, *, overlay=None, n_batches: int = 8,
                     seed: int = 999) -> float:
    dcfg = DataConfig(vocab_size=QOS_VOCAB, seq_len=QOS_SEQ,
                      global_batch=QOS_BATCH, seed=seed)
    pipe = Pipeline(dcfg, kind="asr", d_model=cfg.d_model,
                    noise=QOS_NOISE)
    pv = merge_overlay(params, overlay) if overlay is not None else params
    errs, total = 0, 0
    fwd = jax.jit(lambda p, t, e: lm.forward(p, cfg, t, embeds=e))
    for _ in range(n_batches):
        b = pipe.next()
        logits = fwd(pv, jnp.asarray(b["tokens"]),
                     jnp.asarray(b["embeds"]))
        pred = np.asarray(jnp.argmax(logits, -1))
        errs += int((pred != b["tokens"]).sum())
        total += b["tokens"].size
    return 100.0 * errs / total


def sweep_sasp(cfg, params, *, tiles=(4, 8, 16, 32),
               rates=(0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7),
               quants=("fp32", "int8")) -> List[Dict]:
    records = []
    for tile in tiles:
        for quant in quants:
            base = quantize_params(
                params, SASPConfig(enabled=True, block_k=tile,
                                   block_n=tile, quantize=True)) \
                if quant == "int8" else params
            for rate in rates:
                sasp = SASPConfig(enabled=True, block_k=tile,
                                  block_n=tile, sparsity=rate)
                overlay, got = build_sasp_overlay(params, sasp)
                ter = token_error_rate(base, cfg, overlay=overlay)
                records.append({
                    "tile": tile, "rate": rate, "quant": quant,
                    "achieved_sparsity": got, "ter": ter,
                })
                print(f"  tile={tile:2d} {quant} rate={rate:.1f} "
                      f"-> TER {ter:5.2f}%", flush=True)
    return records


def per_layer_profile(cfg, params, rates=(0.25, 0.5), tile=8) -> Dict:
    """Fig 8: heterogeneous per-FFN-matrix pruning under a global budget
    (+ the implied per-layer runtime share with tile skipping)."""
    out = {}
    for rate in rates:
        sasp = SASPConfig(enabled=True, block_k=tile, block_n=tile,
                          sparsity=rate)
        masks = compute_sasp_masks(params, sasp)
        out[str(rate)] = per_matrix_sparsity(masks)
    return out


def run_all(steps: int = 400, force: bool = False) -> Dict:
    if os.path.exists(CACHE) and not force:
        with open(CACHE) as f:
            return json.load(f)
    t0 = time.time()
    cfg, params, losses = train_qos_model(steps=steps)
    base_ter = token_error_rate(params, cfg)
    print(f"trained QoS model: {steps} steps, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"base TER {base_ter:.2f}% ({time.time()-t0:.0f}s)", flush=True)
    records = sweep_sasp(cfg, params)
    profile = per_layer_profile(cfg, params)
    result = {
        "base_ter": base_ter,
        "train_loss_first": losses[0],
        "train_loss_last": losses[-1],
        "records": records,
        "per_layer": profile,
        "model": dataclasses.asdict(cfg)["name"],
        "steps": steps,
    }
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    import sys
    run_all(steps=int(sys.argv[1]) if len(sys.argv) > 1 else 400,
            force="--force" in sys.argv)
