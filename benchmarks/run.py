"""Benchmark entry point: one function per paper table/figure plus the
kernel microbenches, the serving-engine path comparison, and the
roofline table.

Prints a human-readable block per benchmark followed by machine-readable
``name,us_per_call,derived`` CSV lines, and writes two JSON artifacts —
``BENCH_kernels.json`` (kernel + figure + roofline rows) and
``BENCH_engine.json`` (serving-engine rows) — so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import sys


def main() -> None:
    # (the engine mesh row runs in bench_engine's own 2-fake-device
    # subprocess; this process keeps 1 device so every other row stays
    # comparable across PRs)
    from benchmarks import (
        bench_engine,
        bench_kernels,
        bench_roofline,
        paper_figures,
    )

    rows = []
    rows += paper_figures.fig6_area_power()
    rows += paper_figures.fig7_speedup_energy()
    rows += paper_figures.fig8_per_layer()
    rows += paper_figures.fig9_qos_curves()
    rows += paper_figures.fig10_tradeoff()
    rows += paper_figures.fig11_sublinear()
    rows += paper_figures.table3()
    rows += bench_kernels.bench_kernels()
    rows += bench_roofline.bench_roofline()
    engine_rows = bench_engine.bench_engine()

    print("\nname,us_per_call,derived")
    for name, us, derived in rows + engine_rows:
        print(f"{name},{us:.2f},{derived}")

    bench_engine.rows_to_json(rows, "BENCH_kernels.json")
    bench_engine.rows_to_json(engine_rows, "BENCH_engine.json")


if __name__ == "__main__":
    main()
