"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.launch.mesh import ensure_fake_cpu_devices  # noqa: F401
# (canonical impl lives in src so launch/serve.py shares it)

QOS_CACHE = os.path.join("experiments", "qos_results.json")
DRYRUN_DIR = os.path.join("experiments", "dryrun")


def load_qos() -> Optional[Dict]:
    if os.path.exists(QOS_CACHE):
        with open(QOS_CACHE) as f:
            return json.load(f)
    return None


def measured_qos_fn(qos: Dict) -> Callable[[int, float, str], float]:
    """Interpolating qos_fn(tile, sparsity, quant) from the trained-model
    sweep — feeds the codesign explorer with MEASURED degradation."""
    table: Dict = {}
    for r in qos["records"]:
        table.setdefault((r["tile"], r["quant"]), []).append(
            (r["rate"], r["ter"]))
    for k in table:
        table[k].sort()

    def fn(tile, sparsity, quant):
        key = (tile, quant)
        if key not in table:
            key = min(table, key=lambda k: abs(k[0] - tile))
        xs, ys = zip(*table[key])
        return float(np.interp(sparsity, xs, ys))

    return fn


def load_dryrun_reports() -> List[Dict]:
    out = []
    if not os.path.isdir(DRYRUN_DIR):
        return out
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if f.endswith(".json"):
            with open(os.path.join(DRYRUN_DIR, f)) as fh:
                out.append(json.load(fh))
    return out


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        r = fn(*args)
    _block(r)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _block(r):
    try:
        import jax
        jax.block_until_ready(r)
    except Exception:
        pass


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
