"""End-to-end serving-engine throughput: dense vs masked vs the seed
per-call-repacking kernel path (BSR -> padded flat list re-emitted every
call) vs the packed deployment fast path (compact sorted block lists +
fused epilogues + fused gated FFN, built once at load time).

All greedy; the kernel and packed paths must emit IDENTICAL token
streams (same pruned weights, same visit order) — the benchmark checks
this. Wall numbers are CPU/interpret-mode, so they compare *paths*, not
hardware; the acceptance bar is packed strictly faster than the
per-call-repacking path at 50% tile sparsity.

The mesh section (DESIGN.md §10) re-runs the packed path under a
1×2 (data, model) TP mesh — shard-local visit lists + NamedSharding'd
caches — and checks the greedy streams stay bit-identical to the
single-device packed path. It runs in a SUBPROCESS with 2 fake CPU
devices so the parent bench keeps its 1-device environment and every
other row stays comparable to prior PRs' BENCH_engine.json. At 25%
tile sparsity, NOT 50%: this reduced config prunes the whole d_ff grid
at 0.5, which would make the bit-identity check vacuous for the
sharded FFN reduction. The mesh section also measures the
``tp_comm="rs_ag_int8"`` epilogue (reduce-scatter + int8 all-gather
instead of psum) on the same deployment — ROADMAP asked for a wire-
format decision datapoint beyond the psum-only rows.

The throughput-under-load section (DESIGN.md §11) drives the sharded
scheduler with Poisson arrivals and heterogeneous decode budgets, and
reports tokens/sec + p50/p95 request latency for continuous batching
vs the drain-batch baseline at the SAME slot count — the acceptance
bar is continuous strictly faster.

The mixed-SLO QoS section (DESIGN.md §12) serves an interleaved
interactive/batch Poisson load through FCFS (the PR-3 baseline) and
EDF + aging + preemption at the SAME slot count, reporting per-class
p50/p95 and per-class tokens/sec — the acceptance bar is
interactive-class p95 strictly better under EDF with batch-class
throughput within 10% of FCFS.

The speculative-decoding section (DESIGN.md §17) reruns the packed
paged engine with a higher-sparsity self-drafter (draft-k/verify-1
over shared scratch pages) at trained-model-like acceptance (crafted
prunable-tile magnitudes; see ``_spec_crafted_params``) plus a
natural-weights acceptance-floor row — the acceptance bar is >1.5x
decode tok/s at some draft sparsity in [0.5, 0.75] with streams
bit-identical to the spec-off engine.

The frontend-recovery section (DESIGN.md §14) drives the same fixed
Poisson load through the fault-tolerant cluster frontend over 2 hosts
with 0 vs 1 host chaos-killed mid-load — goodput and p50/p95 with a
death absorbed by retry + exact resume — and times ``revive_host``
(rank rebuild + fresh jit + replayed backlog + a probe request). The
acceptance bar is the killed run completing every request.

Standalone: PYTHONPATH=src python -m benchmarks.bench_engine
writes BENCH_engine.json next to the repo root.
"""
from __future__ import annotations

import sys

if __name__ == "__main__" and "--mesh-only" in sys.argv:
    # the mesh subprocess: force devices before jax backend init
    from benchmarks.common import ensure_fake_cpu_devices
    ensure_fake_cpu_devices(2)

import dataclasses
import json
import os
import subprocess
import time
from typing import List

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.launch.serve import build_serving_params
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.telemetry import Telemetry, pcts_ms as _pcts_ms

ARCH = "qwen3-32b"
SPARSITIES = (0.0, 0.25, 0.5, 0.75)
PATHS = ("masked", "kernel", "packed")
N_REQ = 3
MAX_NEW = 10
SLOTS = 2
CACHE_LEN = 64


def _requests(vocab: int) -> List[Request]:
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=(8 + 7 * i,))
                    .astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i in range(N_REQ)]


def _run_engine(params, cfg, mesh=None):
    """(tokens/s, token streams) for one warmed engine pass."""
    eng = Engine(params, cfg, batch_slots=SLOTS, cache_len=CACHE_LEN,
                 mesh=mesh)
    eng.run(_requests(cfg.vocab_size))          # warm-up: jit compiles
    reqs = _requests(cfg.vocab_size)
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    streams = {r.rid: list(r.out_tokens) for r in done}
    return toks / dt, streams


MESH_SPARSITY = 0.25


def bench_engine_mesh() -> List:
    """Packed path under a 1×2 TP mesh: tokens/s + bit-identity vs the
    single-device packed path. Needs ≥2 devices — run via
    ``--mesh-only`` (a subprocess of the full bench) or under your own
    fake-device flag. The meshless reference runs HERE, in the same
    process, so the comparison is apples-to-apples."""
    rows = []
    if len(jax.devices()) < 2:
        print("  mesh 1x2: skipped (<2 devices)")
        return rows
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    deploy = dict(path="packed", sparsity=MESH_SPARSITY,
                  block_k=8, block_n=8, verbose=False)
    p_ref, c_ref = build_serving_params(params0, cfg0, **deploy)
    _, ref_streams = _run_engine(p_ref, c_ref)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    p, c = build_serving_params(params0, cfg0, mesh=mesh, **deploy)
    tok_s, streams = _run_engine(p, c, mesh=mesh)
    agree = int(streams == ref_streams)
    rows.append((f"engine/packed/mesh1x2/sp{MESH_SPARSITY:.2f}",
                 1e6 / tok_s,
                 f"tok_s={tok_s:.2f};mesh=1x2;"
                 f"single_device_agree={agree}"))
    # rs+int8-ag epilogue on the same deployment (ROADMAP: psum was the
    # only measured TP reduction). int8 quantizes the reduced partials
    # on the wire, so streams may drift from the exact-psum reference —
    # the agree flag records whether greedy argmax survived at this size
    c8 = dataclasses.replace(c, tp_comm="rs_ag_int8")
    tok_s8, streams8 = _run_engine(p, c8, mesh=mesh)
    agree8 = int(streams8 == ref_streams)
    rows.append((f"engine/packed/mesh1x2_rs_ag_int8/"
                 f"sp{MESH_SPARSITY:.2f}",
                 1e6 / tok_s8,
                 f"tok_s={tok_s8:.2f};mesh=1x2;tp_comm=rs_ag_int8;"
                 f"single_device_agree={agree8};"
                 f"vs_psum_x{tok_s8 / tok_s:.3f}"))
    return rows


def _mesh_rows_subprocess() -> List:
    """Run the mesh section in a child with 2 fake CPU devices so THIS
    process keeps seeing 1 device (cross-PR row comparability; same
    policy as tests/test_distribution.py)."""
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_engine", "--mesh-only"],
        capture_output=True, text=True, env=dict(os.environ),
        timeout=1200)
    if p.returncode != 0:
        err = p.stderr.strip().splitlines()
        print(f"  mesh 1x2: subprocess failed (rc={p.returncode}): "
              f"{err[-1] if err else '<no stderr>'}")
        return []
    rows = []
    for line in p.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = [tuple(r) for r in json.loads(line[len("RESULT "):])]
    for name, us, derived in rows:
        tok_s = 1e6 / us
        agree = "single_device_agree=1" in derived
        comm = "rs_ag_int8" if "rs_ag_int8" in name else "psum"
        print(f"  mesh 1x2 packed ({comm:10s}): {tok_s:7.1f} tok/s "
              f"(vs single-device packed: {'==' if agree else '!='})")
    if not rows:
        print("  mesh 1x2: subprocess emitted no RESULT row")
    return rows


LOAD_REQ = 16
LOAD_SLOTS = 3
LOAD_MEAN_ARRIVAL_S = 0.005     # Poisson rate: fast enough to backlog
LOAD_PROMPT_LEN = 10            # ONE length → admission-group shapes
                                # (G, S) are all warmable up front
# wide budget spread: the drain baseline idles (slots, max-in-batch)
# on every batch, so heterogeneous budgets are exactly its weak spot
LOAD_MAX_NEW = (2, 40, 4, 48, 8, 2, 36, 4, 24, 2, 44, 6)


def _load_requests(vocab: int, n: int = LOAD_REQ,
                   max_new=None) -> List[Request]:
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab,
                                        size=(LOAD_PROMPT_LEN,))
                    .astype(np.int32),
                    max_new_tokens=(max_new or LOAD_MAX_NEW)[
                        i % len(max_new or LOAD_MAX_NEW)])
            for i in range(n)]


def _warm_scheduler(sched, vocab: int):
    """Compile every shape the timed run can hit: admission groups of
    G = slots…1 (one prompt length) plus the batched decode step."""
    for g in range(LOAD_SLOTS, 0, -1):
        sched.run(_load_requests(vocab, n=g, max_new=(4,)))


def bench_engine_load() -> List:
    """Throughput under load (DESIGN.md §11): the sharded scheduler
    serving Poisson arrivals with heterogeneous decode budgets —
    continuous batching vs the drain-batch baseline at the SAME slot
    count. Reports tokens/sec and p50/p95 submit-to-retire latency."""
    from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

    rows = []
    print("\n== scheduler under load (Poisson arrivals, "
          f"{LOAD_REQ} reqs, {LOAD_SLOTS} slots) ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    arrivals = list(np.random.default_rng(11).exponential(
        LOAD_MEAN_ARRIVAL_S, size=LOAD_REQ).cumsum())

    results = {}
    for mode, drain in (("continuous", False), ("drain", True)):
        sched = ShardedScheduler(
            params0, cfg0, ranks=1,
            sched=SchedulerConfig(slots_per_rank=LOAD_SLOTS,
                                  cache_len=64, drain=drain))
        _warm_scheduler(sched, cfg0.vocab_size)
        reqs = _load_requests(cfg0.vocab_size)
        t0 = time.perf_counter()
        done = sched.run(reqs, arrivals=arrivals)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        p50, p95 = _pcts_ms(sorted(r.latency for r in done))
        tok_s = toks / dt
        results[mode] = tok_s
        print(f"  {mode:10s}: {tok_s:7.1f} tok/s  "
              f"p50={p50:6.0f}ms p95={p95:6.0f}ms "
              f"({len(done)} reqs, {toks} tokens)")
        rows.append((f"engine/sched/{mode}/load", 1e6 / tok_s,
                     f"tok_s={tok_s:.2f};p50_ms={p50:.1f};"
                     f"p95_ms={p95:.1f};slots={LOAD_SLOTS};ranks=1;"
                     f"reqs={LOAD_REQ};"
                     f"poisson_mean_s={LOAD_MEAN_ARRIVAL_S}"))
    speedup = results["continuous"] / results["drain"]
    ok = speedup > 1.0
    print(f"  continuous/drain: x{speedup:.2f} "
          f"({'OK' if ok else 'REGRESSION: drain not slower!'})")
    rows.append(("engine/sched_speedup/load", 0.0,
                 f"x{speedup:.3f}_vs_drain_batch"))
    return rows


QOS_REQ = 16
QOS_MEAN_ARRIVAL_S = 0.004
# batch-class budgets: long decodes for interactive traffic to leapfrog
QOS_BATCH_NEW = (28, 44, 24, 48, 32, 40, 26, 36)
QOS_INTER_NEW = 4
QOS_INTER_DEADLINE_S = 0.25


def _qos_requests(vocab: int) -> List[Request]:
    rng = np.random.default_rng(9)
    reqs = []
    for i in range(QOS_REQ):
        interactive = i % 2 == 1
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, size=(LOAD_PROMPT_LEN,))
            .astype(np.int32),
            max_new_tokens=(QOS_INTER_NEW if interactive
                            else QOS_BATCH_NEW[(i // 2)
                                               % len(QOS_BATCH_NEW)]),
            slo="interactive" if interactive else "batch",
            deadline=QOS_INTER_DEADLINE_S if interactive else 30.0))
    return reqs


def _warm_preempt(sched, vocab: int):
    """Compile the preempt/resume path (cache snapshot + restore) so
    the timed QoS run measures scheduling, not one-off jit. Preemption
    only fires with EVERY slot busy, so fill the whole rank first."""
    rng = np.random.default_rng(21)
    mk = lambda rid, new, slo, dl: Request(
        rid=rid, prompt=rng.integers(0, vocab, size=(LOAD_PROMPT_LEN,))
        .astype(np.int32), max_new_tokens=new, slo=slo, deadline=dl)
    slots = sched.sched.slots_per_rank
    for s in range(slots):
        sched.submit(mk(10_000 + s, 12, "batch", 30.0))
    for _ in range(3):
        sched.step()
    sched.submit(mk(10_000 + slots, 2, "interactive", 0.0))
    while sched.has_work():
        sched.step()
    assert sched.stats()["preemptions"] >= 1, \
        "preempt warm-up failed to trigger a preemption"


def _class_stats(done, klass: str, dt: float):
    rs = [r for r in done if r.slo == klass]
    toks = sum(len(r.out_tokens) for r in rs)
    p50, p95 = _pcts_ms(sorted(r.latency for r in rs))
    ttfts = sorted(r.t_first - r.t_submit for r in rs
                   if r.t_first is not None and r.t_submit is not None)
    t50, t95 = _pcts_ms(ttfts) if ttfts else (0.0, 0.0)
    return dict(n=len(rs), tok_s=toks / dt, p50_ms=p50, p95_ms=p95,
                ttft_p50_ms=t50, ttft_p95_ms=t95)


def bench_engine_qos() -> List:
    """Mixed-SLO load (DESIGN.md §12): interleaved interactive (tight
    deadline, short decode) and batch (long decode) Poisson traffic
    through FCFS vs EDF + aging + preemption at the same slot count.
    Acceptance: interactive p95 improves under EDF, batch-class
    throughput stays within 10%."""
    from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

    rows = []
    print("\n== scheduler QoS: mixed-SLO Poisson load "
          f"({QOS_REQ} reqs, {LOAD_SLOTS} slots, fcfs vs edf) ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    arrivals = list(np.random.default_rng(13).exponential(
        QOS_MEAN_ARRIVAL_S, size=QOS_REQ).cumsum())

    results = {}
    for mode in ("fcfs", "edf"):
        scfg = SchedulerConfig(
            slots_per_rank=LOAD_SLOTS, cache_len=64,
            policy=mode, aging=0.05 if mode == "edf" else 0.0,
            preempt=mode == "edf", preempt_mode="kv")
        sched = ShardedScheduler(params0, cfg0, ranks=1, sched=scfg)
        _warm_scheduler(sched, cfg0.vocab_size)
        if scfg.preempt:
            _warm_preempt(sched, cfg0.vocab_size)
        reqs = _qos_requests(cfg0.vocab_size)
        warm_preempts = sched.stats()["preemptions"]
        t0 = time.perf_counter()
        done = sched.run(reqs, arrivals=arrivals)
        dt = time.perf_counter() - t0
        st = {k: _class_stats(done, k, dt)
              for k in ("interactive", "batch")}
        # delta over the warm-up: preemptions of the TIMED run only
        st["preemptions"] = sched.stats()["preemptions"] - warm_preempts
        results[mode] = st
        for k in ("interactive", "batch"):
            print(f"  {mode:5s} {k:12s}: p50={st[k]['p50_ms']:6.0f}ms "
                  f"p95={st[k]['p95_ms']:6.0f}ms "
                  f"{st[k]['tok_s']:6.1f} tok/s ({st[k]['n']} reqs)")
            rows.append((
                f"engine/sched/qos_{mode}/{k}", st[k]["p95_ms"] * 1e3,
                f"tok_s={st[k]['tok_s']:.2f};"
                f"p50_ms={st[k]['p50_ms']:.1f};"
                f"p95_ms={st[k]['p95_ms']:.1f};"
                f"ttft_p50_ms={st[k]['ttft_p50_ms']:.1f};"
                f"ttft_p95_ms={st[k]['ttft_p95_ms']:.1f};"
                f"slots={LOAD_SLOTS};"
                f"reqs={st[k]['n']};"
                f"preemptions={st['preemptions']}"))
    int_p95_x = (results["fcfs"]["interactive"]["p95_ms"]
                 / results["edf"]["interactive"]["p95_ms"])
    batch_ratio = (results["edf"]["batch"]["tok_s"]
                   / results["fcfs"]["batch"]["tok_s"])
    ok = int_p95_x > 1.0 and batch_ratio >= 0.9
    print(f"  edf vs fcfs: interactive p95 x{int_p95_x:.2f} better, "
          f"batch throughput x{batch_ratio:.2f} "
          f"({results['edf']['preemptions']} preemptions) "
          f"({'OK' if ok else 'REGRESSION: QoS bar missed!'})")
    rows.append(("engine/sched_qos_gain/load", 0.0,
                 f"int_p95_x{int_p95_x:.3f};"
                 f"batch_tok_ratio={batch_ratio:.3f};"
                 f"preemptions={results['edf']['preemptions']}"))
    return rows


MEM_CACHE = 64
MEM_PAGE = 8                    # tile-aligned: NB = 8 pages per ring
MEM_BUDGET_SLOTS = 3            # contiguous rings the budget pays for
MEM_PAGES = MEM_BUDGET_SLOTS * (MEM_CACHE // MEM_PAGE)
MEM_OVERSUB_SLOTS = 8           # paged engine oversubscribes slots
MEM_REQ = 10


def _mem_requests(vocab: int) -> List[Request]:
    rng = np.random.default_rng(17)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab,
                                        size=(8 + (3 * i) % 10,))
                    .astype(np.int32),
                    max_new_tokens=6)
            for i in range(MEM_REQ)]


def _drive_tracking(eng, reqs):
    """(streams, max concurrent occupied slots, tok/s) for one pass."""
    for r in reqs:
        eng.submit(r)
    done, conc = [], 0
    t0 = time.perf_counter()
    while eng.has_work():
        done.extend(eng.step())
        conc = max(conc, sum(r is not None for r in eng.slot_req))
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    return {r.rid: list(r.out_tokens) for r in done}, conc, toks / dt


def bench_engine_memory() -> List:
    """Paged KV memory (DESIGN.md §13): capacity at a FIXED device KV
    budget — a contiguous engine affords budget/ring slots; the paged
    engine shares the same pages through block tables and oversubscribes
    slots (spilling cold pages to host RAM under pressure) — plus the
    spill→host / fault→device page-move latency. Acceptance: ≥1.5×
    concurrent slots at the same budget, streams bit-identical."""
    rows = []
    print("\n== paged KV memory: capacity at fixed device budget "
          f"({MEM_PAGES} pages × {MEM_PAGE} tokens) ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)

    contig = Engine(params0, cfg0, batch_slots=MEM_BUDGET_SLOTS,
                    cache_len=MEM_CACHE)
    contig.run(_mem_requests(cfg0.vocab_size))          # warm-up
    ref_streams, conc_c, tok_c = _drive_tracking(
        contig, _mem_requests(cfg0.vocab_size))

    paged = Engine(params0, cfg0, batch_slots=MEM_OVERSUB_SLOTS,
                   cache_len=MEM_CACHE, kv_pages=MEM_PAGES,
                   kv_page_len=MEM_PAGE, kv_host_pages=MEM_PAGES)
    paged.run(_mem_requests(cfg0.vocab_size))           # warm-up
    streams, conc_p, tok_p = _drive_tracking(
        paged, _mem_requests(cfg0.vocab_size))
    mem = paged.memory_stats()
    agree = int(streams == ref_streams)
    ratio = conc_p / conc_c
    ok = ratio >= 1.5 and agree
    print(f"  contiguous: {conc_c} concurrent slots, {tok_c:7.1f} tok/s"
          f"  |  paged: {conc_p} concurrent, {tok_p:7.1f} tok/s "
          f"(x{ratio:.2f} capacity, streams "
          f"{'==' if agree else '!='}, {mem.spills} spills) "
          f"({'OK' if ok else 'REGRESSION: capacity bar missed!'})")
    rows.append((f"engine/mem/contig/slots{MEM_BUDGET_SLOTS}",
                 1e6 / tok_c,
                 f"tok_s={tok_c:.2f};concurrent={conc_c};"
                 f"pages={MEM_PAGES}"))
    rows.append((f"engine/mem/paged/slots{MEM_OVERSUB_SLOTS}",
                 1e6 / tok_p,
                 f"tok_s={tok_p:.2f};concurrent={conc_p};"
                 f"pages={MEM_PAGES};page_len={MEM_PAGE};"
                 f"spills={mem.spills};faults={mem.faults};"
                 f"contig_agree={agree}"))
    rows.append(("engine/mem/capacity", 0.0,
                 f"x{ratio:.3f}_concurrent_slots_at_fixed_budget;"
                 f"agree={agree}"))

    # spill→fault latency: whole-ring page set through the host pool
    from repro.serve.memory import PagedKVPool
    pool = PagedKVPool(params0, cfg0, cache_len=MEM_CACHE,
                       device_pages=MEM_CACHE // MEM_PAGE,
                       page_len=MEM_PAGE,
                       host_pages=MEM_CACHE // MEM_PAGE)
    nb = pool.NB
    pool.admit(0, nb)
    pool.preempt(0)
    pool.admit(1, nb)                   # warm the move kernels
    pool.free(1)
    pool.resume(0)
    pool.preempt(0)
    t0 = time.perf_counter()
    pool.admit(1, nb)                   # forces nb spills to host
    spill_us = (time.perf_counter() - t0) / nb * 1e6
    pool.free(1)
    t0 = time.perf_counter()
    pool.resume(0)                      # faults nb pages back
    fault_us = (time.perf_counter() - t0) / nb * 1e6
    st = pool.stats()
    print(f"  spill {spill_us:7.1f} us/page -> host, fault "
          f"{fault_us:7.1f} us/page -> device "
          f"({st.spills} spills, {st.faults} faults total)")
    rows.append(("engine/mem/spill_latency", spill_us,
                 f"us_per_page={spill_us:.1f};page_len={MEM_PAGE};"
                 f"pages={nb}"))
    rows.append(("engine/mem/fault_latency", fault_us,
                 f"us_per_page={fault_us:.1f};page_len={MEM_PAGE};"
                 f"pages={nb}"))
    return rows


SHARE_SYS = 48                  # shared system prompt, 6 whole pages
SHARE_SUF = 8                   # distinct per-request tail
SHARE_REQ = 10


def _share_requests(vocab: int) -> List[Request]:
    """Shared-system-prompt workload: every request opens with the same
    48-token system prompt (6 whole pages at page_len 8) and diverges
    in an 8-token user suffix."""
    rng = np.random.default_rng(31)
    sys_prompt = rng.integers(0, vocab, size=(SHARE_SYS,))
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, vocab, size=(SHARE_SUF,))])
                    .astype(np.int32),
                    max_new_tokens=6)
            for i in range(SHARE_REQ)]


def bench_engine_share() -> List:
    """Prefix sharing (DESIGN.md §16) at the SAME fixed page budget as
    the mem bench: a shared-system-prompt workload served with
    ``kv_share`` off vs on. Sharing maps each later prompt's system
    pages onto the first admission's resident pages, so prefill skips
    those tokens entirely and admission gets cheaper at identical
    streams. Acceptance: >=50% of prefill tokens skipped, streams
    bit-identical to sharing off."""
    rows = []
    print("\n== prefix-sharing paged KV: shared system prompt at fixed "
          f"budget ({MEM_PAGES} pages x {MEM_PAGE} tokens) ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)

    def build(share):
        return Engine(params0, cfg0, batch_slots=MEM_OVERSUB_SLOTS,
                      cache_len=MEM_CACHE, kv_pages=MEM_PAGES,
                      kv_page_len=MEM_PAGE, kv_host_pages=MEM_PAGES,
                      kv_share=share)

    def drive(share):
        eng = build(share)
        eng.run(_share_requests(cfg0.vocab_size))       # warm-up
        eng = build(share)
        for r in _share_requests(cfg0.vocab_size):
            eng.submit(r)
        done, conc = [], 0
        t0 = time.perf_counter()
        while eng.has_work():
            done.extend(eng.step())
            conc = max(conc, sum(r is not None for r in eng.slot_req))
        dt = time.perf_counter() - t0
        adm = [r.t_first - r.t_submit for r in done
               if r.t_first is not None and r.t_submit is not None]
        adm_ms = 1e3 * sum(adm) / max(1, len(adm))
        toks = sum(len(r.out_tokens) for r in done)
        streams = {r.rid: list(r.out_tokens) for r in done}
        return streams, conc, toks / dt, adm_ms, eng

    ref_streams, conc_off, tok_off, adm_off, _ = drive(False)
    streams, conc_on, tok_on, adm_on, eng = drive(True)
    st, mem = eng.stats, eng.memory_stats()
    total = st["prefill_tokens"] + st["prefill_tokens_skipped"]
    skipped_pct = 100.0 * st["prefill_tokens_skipped"] / max(1, total)
    agree = int(streams == ref_streams)
    ok = skipped_pct >= 50.0 and agree
    print(f"  share off: {conc_off} concurrent, {tok_off:7.1f} tok/s, "
          f"adm {adm_off:6.1f} ms  |  on: {conc_on} concurrent, "
          f"{tok_on:7.1f} tok/s, adm {adm_on:6.1f} ms")
    print(f"  prefill skipped {st['prefill_tokens_skipped']}/{total} "
          f"tokens ({skipped_pct:.0f}%), {mem.prefix_hits} hits, "
          f"{mem.cow_copies} COWs, streams "
          f"{'==' if agree else '!='} "
          f"({'OK' if ok else 'REGRESSION: share bar missed!'})")
    rows.append(("engine/mem/share/off", 1e6 / tok_off,
                 f"tok_s={tok_off:.2f};concurrent={conc_off};"
                 f"admission_ms={adm_off:.2f};pages={MEM_PAGES}"))
    rows.append(("engine/mem/share/on", 1e6 / tok_on,
                 f"tok_s={tok_on:.2f};concurrent={conc_on};"
                 f"admission_ms={adm_on:.2f};pages={MEM_PAGES};"
                 f"prefix_hits={mem.prefix_hits};"
                 f"cow_copies={mem.cow_copies};agree={agree}"))
    rows.append(("engine/mem/share/skip", 0.0,
                 f"skipped_pct={skipped_pct:.1f};"
                 f"skipped={st['prefill_tokens_skipped']};"
                 f"prefilled={st['prefill_tokens']};agree={agree}"))
    return rows


SPEC_DS = (0.5, 0.625, 0.75)    # drafter sparsities on the ladder
SPEC_K = 12                     # draft tokens per verify pass
SPEC_EPS = 0.05                 # crafted prunable-tile magnitude
SPEC_MAX_NEW = 40
SPEC_REPS = 4


def _spec_requests(vocab: int) -> List[Request]:
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=(8 + 5 * i,))
                    .astype(np.int32),
                    max_new_tokens=SPEC_MAX_NEW)
            for i in range(SLOTS)]


def _spec_crafted_params(params, cfg, block: int = 8):
    """Weights whose drafter/target agreement mirrors a TRAINED
    pruned model's. Random-init weights give the self-speculation
    ladder nothing to agree on (drafter and target argmaxes are
    uncorrelated noise), so acceptance — the one workload-dependent
    input to speculative throughput — would be meaningless. A model
    actually trained under SASP concentrates magnitude in the
    surviving tiles; we reproduce that structure directly: tiles
    OUTSIDE the max-draft-sparsity survivor set are scaled to
    SPEC_EPS of their init value, so a drafter re-pruned at up to
    max(SPEC_DS) computes nearly the same function as the target and
    the bench measures the machinery at trained-model-like acceptance
    (reported per row, alongside a natural-weights reference row)."""
    from repro.configs.base import SASPConfig
    from repro.core.pruning import prune_params
    sasp = SASPConfig(enabled=True, block_k=block, block_n=block,
                      sparsity=max(SPEC_DS), scope="ffn")
    pruned, _ = prune_params(params, sasp)
    return jax.tree.map(lambda d, p: p + SPEC_EPS * (d - p),
                        params, pruned)


def bench_engine_spec() -> List:
    """Self-speculative decoding on the sparsity ladder (DESIGN.md
    §17): the packed target drafts k tokens through a higher-sparsity
    repack of its OWN weights, then verifies them in one batched
    target pass — greedy streams stay bit-identical to sequential
    decode (checked). Decode throughput wins come from amortizing the
    per-step dispatch + engine overhead across k+1 tokens per verify
    (and, on real tile-skip hardware, from the drafter's pruned-tile
    FLOP discount that interpret-mode CPU kernels do not reproduce).
    Acceptance bar: >1.5x decode tok/s over the spec-off engine at
    some draft sparsity in [0.5, 0.75], streams identical."""
    rows = []
    print("\n== self-speculative decoding: drafter on the sparsity "
          f"ladder, k={SPEC_K}, target packed@0.50 ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    crafted = _spec_crafted_params(params0, cfg0)
    tparams, tcfg = build_serving_params(
        crafted, cfg0, path="packed", sparsity=0.5, block_k=8,
        block_n=8, verbose=False)
    nparams, ncfg = build_serving_params(
        params0, cfg0, path="packed", sparsity=0.5, block_k=8,
        block_n=8, verbose=False)

    def drive(params, cfg, ds):
        kw = dict(batch_slots=SLOTS, cache_len=MEM_CACHE,
                  kv_pages=2 * SLOTS * (MEM_CACHE // MEM_PAGE),
                  kv_page_len=MEM_PAGE)
        if ds is not None:
            kw.update(draft_sparsity=ds, draft_k=SPEC_K)
        eng = Engine(params, cfg, **kw)
        eng.run(_spec_requests(cfg.vocab_size))     # jit warm-up
        best = 0.0
        for _ in range(SPEC_REPS):                  # dispatch-bound:
            reqs = _spec_requests(cfg.vocab_size)   # best-of filters
            t0 = time.perf_counter()                # scheduler noise
            done = eng.run(reqs)
            dt = time.perf_counter() - t0
            best = max(best, sum(len(r.out_tokens) for r in done) / dt)
        streams = {r.rid: list(r.out_tokens) for r in done}
        st = eng.stats
        acc = st.get("spec_accepted_tokens", 0)
        drafted = st.get("spec_draft_tokens", 0)
        return best, streams, (acc, drafted, st.get("spec_rounds", 0))

    base, ref, _ = drive(tparams, tcfg, None)
    print(f"  spec off          : {base:7.1f} tok/s")
    rows.append(("engine/spec/off", 1e6 / base,
                 f"tok_s={base:.2f};target=packed@0.50;k={SPEC_K}"))
    best_x = 0.0
    for ds in SPEC_DS:
        tok, streams, (acc, drafted, rounds) = drive(tparams, tcfg, ds)
        agree = int(streams == ref)
        x = tok / base
        best_x = max(best_x, x)
        acc_pct = 100.0 * acc / max(1, drafted)
        print(f"  draft sp={ds:5.3f}   : {tok:7.1f} tok/s  x{x:.2f}  "
              f"accepted {acc}/{drafted} ({acc_pct:.0f}%), "
              f"{rounds} rounds, streams "
              f"{'==' if agree else '!='}")
        rows.append((f"engine/spec/ds{ds:.3f}", 1e6 / tok,
                     f"tok_s={tok:.2f};speedup_x={x:.3f};"
                     f"accept_pct={acc_pct:.1f};accepted={acc};"
                     f"drafted={drafted};rounds={rounds};k={SPEC_K};"
                     f"agree={agree}"))
    # reference: natural (uncrafted) random-init weights. NOTE tiny
    # random models emit degenerate (repetitive) streams, so even this
    # drafter tracks the target — the row records the measured
    # acceptance rather than assuming it; the adversarial LOW-
    # acceptance regime is covered by the stubbed-drafter tests in
    # tests/test_spec_decode.py, where acceptance is controlled exactly
    nbase, nref, _ = drive(nparams, ncfg, None)
    ntok, nstreams, (acc, drafted, rounds) = drive(nparams, ncfg, 0.75)
    nagree = int(nstreams == nref)
    acc_pct = 100.0 * acc / max(1, drafted)
    print(f"  natural sp=0.750  : {ntok:7.1f} tok/s  x{ntok/nbase:.2f}"
          f"  accepted {acc}/{drafted} ({acc_pct:.0f}%), streams "
          f"{'==' if nagree else '!='}")
    rows.append(("engine/spec/natural0.750", 1e6 / ntok,
                 f"tok_s={ntok:.2f};speedup_x={ntok/nbase:.3f};"
                 f"accept_pct={acc_pct:.1f};accepted={acc};"
                 f"drafted={drafted};rounds={rounds};k={SPEC_K};"
                 f"agree={nagree}"))
    ok = best_x > 1.5
    print(f"  best speedup x{best_x:.2f} "
          f"({'OK' if ok else 'REGRESSION: spec bar missed!'})")
    rows.append(("engine/spec/best", 0.0,
                 f"best_speedup_x={best_x:.3f};bar=1.5;"
                 f"ok={int(ok)}"))
    return rows


OBS_REPS = 4


def bench_engine_obs() -> List:
    """Telemetry overhead (DESIGN.md §18): the same packed paged engine
    with the span tracer + metrics registry ARMED vs the telemetry-off
    default, best-of-N decode throughput. The tracer is host-side only
    (monotonic clock reads + deque appends, no device sync), so the
    acceptance bar is streams bit-identical and decode tok/s overhead
    < 3%."""
    rows = []
    print("\n== telemetry overhead: tracer+metrics armed vs off ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    pparams, pcfg = build_serving_params(
        params0, cfg0, path="packed", sparsity=0.5, block_k=8,
        block_n=8, verbose=False)

    def build(trace: bool):
        tel = Telemetry(trace=trace)
        eng = Engine(pparams, pcfg, batch_slots=SLOTS,
                     cache_len=MEM_CACHE,
                     kv_pages=2 * SLOTS * (MEM_CACHE // MEM_PAGE),
                     kv_page_len=MEM_PAGE, telemetry=tel)
        eng.run(_spec_requests(pcfg.vocab_size))    # jit warm-up
        return eng, tel

    def timed(eng):
        reqs = _spec_requests(pcfg.vocab_size)
        t0 = time.perf_counter()
        done = eng.run(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        return toks / dt, {r.rid: list(r.out_tokens) for r in done}

    # interleave the reps so clock drift (thermal / background load)
    # cancels instead of billing whichever engine runs second
    eng_off, _ = build(False)
    eng_on, tel = build(True)
    base = traced = 0.0
    for _ in range(OBS_REPS):
        r_off, ref = timed(eng_off)
        r_on, streams = timed(eng_on)
        base, traced = max(base, r_off), max(traced, r_on)
    agree = int(streams == ref)
    overhead_pct = 100.0 * (base - traced) / base
    ok = agree and overhead_pct < 3.0
    print(f"  off: {base:7.1f} tok/s  |  armed: {traced:7.1f} tok/s  "
          f"overhead {overhead_pct:+.2f}% "
          f"({len(tel.tracer)} events, streams "
          f"{'==' if agree else '!='}) "
          f"({'OK' if ok else 'REGRESSION: telemetry overhead bar!'})")
    rows.append(("engine/obs/overhead", 0.0,
                 f"overhead_pct={overhead_pct:.2f};"
                 f"base_tok_s={base:.2f};traced_tok_s={traced:.2f};"
                 f"events={len(tel.tracer)};agree={agree};bar=3.0"))
    return rows


FE_REQ = 12
FE_MAX_NEW = (2, 12, 4, 16, 6, 2, 10, 4)
FE_KILL_STEP = 6                # host 0 dies this many ticks in


def _fe_requests(vocab: int, n: int = FE_REQ,
                 rid_base: int = 100) -> List[Request]:
    rng = np.random.default_rng(23)
    return [Request(rid=rid_base + i,
                    prompt=rng.integers(0, vocab,
                                        size=(LOAD_PROMPT_LEN,))
                    .astype(np.int32),
                    max_new_tokens=FE_MAX_NEW[i % len(FE_MAX_NEW)])
            for i in range(n)]


def bench_engine_recovery() -> List:
    """Fault-tolerant frontend (DESIGN.md §14) at a FIXED offered load:
    goodput (completed requests/s) and p50/p95 latency with 0 vs 1 of 2
    hosts chaos-killed mid-load, then time-to-recover after
    ``revive_host`` — rank rebuild + fresh jit + replayed backlog + a
    probe request served end to end. Acceptance: the killed run still
    completes EVERY request (bounded retry + exact-resume hand-off), so
    a host death costs latency, not answers."""
    from repro.serve.chaos import ChaosConfig, ChaosMonkey
    from repro.serve.frontend import ClusterFrontend, FrontendConfig, \
        make_local_hosts
    from repro.serve.scheduler import SchedulerConfig

    rows = []
    print("\n== frontend recovery: fixed Poisson load, "
          f"{FE_REQ} reqs over 2 hosts, 0 vs 1 killed ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    arrivals = list(np.random.default_rng(19).exponential(
        LOAD_MEAN_ARRIVAL_S, size=FE_REQ).cumsum())

    results = {}
    fe = None
    for mode in ("healthy", "kill1"):
        hosts = make_local_hosts(
            params0, cfg0, hosts=2,
            sched=SchedulerConfig(slots_per_rank=LOAD_SLOTS,
                                  cache_len=64))
        for h in hosts:                 # compile every admission shape
            _warm_scheduler(h.sched, cfg0.vocab_size)
        if mode == "kill1":
            hosts[0].chaos = ChaosMonkey(
                ChaosConfig(kill_at_step={0: FE_KILL_STEP}))
        fe = ClusterFrontend(hosts, FrontendConfig(
            retries=2, backoff_base=0.001, backoff_cap=0.01))
        reqs = _fe_requests(cfg0.vocab_size)
        t0 = time.perf_counter()
        done = fe.run(reqs, arrivals=arrivals)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        tok_s = toks / dt
        goodput = len(done) / dt
        p50, p95 = _pcts_ms(sorted(r.latency for r in done))
        st = fe.stats()
        results[mode] = dict(done=len(done), tok_s=tok_s, p95=p95)
        print(f"  {mode:8s}: {goodput:6.1f} req/s {tok_s:7.1f} tok/s  "
              f"p50={p50:6.0f}ms p95={p95:6.0f}ms "
              f"({len(done)}/{FE_REQ} done, {st['retries']} retries, "
              f"{st['dead']} dead host)")
        rows.append((f"engine/frontend/recovery/{mode}", 1e6 / tok_s,
                     f"tok_s={tok_s:.2f};goodput_rps={goodput:.2f};"
                     f"p50_ms={p50:.1f};p95_ms={p95:.1f};"
                     f"done={len(done)};failed={st['failed']};"
                     f"retries={st['retries']};hosts=2;"
                     f"dead={st['dead']};"
                     f"kill_step={FE_KILL_STEP if mode == 'kill1' else -1}"))
    # time-to-recover: the kill1 frontend still holds its dead host —
    # revive it, replay whatever the outage failed, and serve a probe
    # request end to end (includes rank rebuild + fresh jit compiles,
    # the honest cost of bringing capacity back)
    replayable = sum(1 for t in fe.trackers.values()
                     if t.outcome == "failed" and t.replayable)
    t0 = time.perf_counter()
    fe.revive_host(0)
    probe = _fe_requests(cfg0.vocab_size, n=1, rid_base=900)[0]
    fe.submit(probe)
    while fe.unresolved():
        fe.step()
    recover_s = time.perf_counter() - t0
    fe.close()
    ok = results["kill1"]["done"] == FE_REQ and probe.done
    print(f"  revive host 0: {recover_s * 1e3:6.0f} ms to "
          f"healthy-and-serving ({replayable} failures replayed) "
          f"({'OK' if ok else 'REGRESSION: kill run lost requests!'})")
    rows.append(("engine/frontend/recovery/revive", recover_s * 1e6,
                 f"recover_ms={recover_s * 1e3:.1f};"
                 f"replayed={replayable};probe_done={int(probe.done)};"
                 f"kill1_done={results['kill1']['done']}"))
    return rows


def bench_engine() -> List:
    rows = []
    print("\n== serving engine (CPU; interpret-mode kernels) ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)

    tok_s, _ = _run_engine(params0, cfg0)
    print(f"  dense           : {tok_s:7.1f} tok/s")
    rows.append(("engine/dense", 1e6 / tok_s, f"tok_s={tok_s:.1f}"))

    for sp in SPARSITIES:
        streams = {}
        rates = {}
        for path in PATHS:
            p, c = build_serving_params(
                params0, cfg0, path=path, sparsity=sp,
                block_k=8, block_n=8, verbose=False)
            rates[path], streams[path] = _run_engine(p, c)
        agree = int(streams["kernel"] == streams["packed"])
        speedup = rates["packed"] / rates["kernel"]
        print(f"  sp={sp:.2f}: masked={rates['masked']:7.1f} "
              f"kernel(repack)={rates['kernel']:7.1f} "
              f"packed={rates['packed']:7.1f} tok/s "
              f"(packed/kernel x{speedup:.2f}, "
              f"outputs {'==' if agree else '!='})")
        for path in PATHS:
            rows.append((f"engine/{path}/sp{sp:.2f}",
                         1e6 / rates[path],
                         f"tok_s={rates[path]:.2f};"
                         f"kernel_packed_agree={agree}"))
        rows.append((f"engine/packed_speedup/sp{sp:.2f}", 0.0,
                     f"x{speedup:.3f}_vs_percall_repack"))
    rows.extend(_mesh_rows_subprocess())
    rows.extend(bench_engine_load())
    rows.extend(bench_engine_qos())
    rows.extend(bench_engine_memory())
    rows.extend(bench_engine_share())
    rows.extend(bench_engine_spec())
    rows.extend(bench_engine_obs())
    rows.extend(bench_engine_recovery())
    return rows


def rows_to_json(rows, path: str):
    payload = [{"name": n, "us_per_call": round(us, 3), "derived": d}
               for n, us, d in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path} ({len(payload)} rows)")


def main():
    if "--mesh-only" in sys.argv:       # the 2-fake-device subprocess
        print("RESULT " + json.dumps(bench_engine_mesh()))
        return
    rows = bench_engine()
    rows_to_json(rows, "BENCH_engine.json")


if __name__ == "__main__":
    main()
