"""End-to-end serving-engine throughput: dense vs masked vs the seed
per-call-repacking kernel path (BSR -> padded flat list re-emitted every
call) vs the packed deployment fast path (compact sorted block lists +
fused epilogues + fused gated FFN, built once at load time).

All greedy; the kernel and packed paths must emit IDENTICAL token
streams (same pruned weights, same visit order) — the benchmark checks
this. Wall numbers are CPU/interpret-mode, so they compare *paths*, not
hardware; the acceptance bar is packed strictly faster than the
per-call-repacking path at 50% tile sparsity.

Standalone: PYTHONPATH=src python -m benchmarks.bench_engine
writes BENCH_engine.json next to the repo root.
"""
from __future__ import annotations

import json
import time
from typing import List

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.launch.serve import build_serving_params
from repro.models import lm
from repro.serve.engine import Engine, Request

ARCH = "qwen3-32b"
SPARSITIES = (0.0, 0.25, 0.5, 0.75)
PATHS = ("masked", "kernel", "packed")
N_REQ = 3
MAX_NEW = 10
SLOTS = 2
CACHE_LEN = 64


def _requests(vocab: int) -> List[Request]:
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=(8 + 7 * i,))
                    .astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i in range(N_REQ)]


def _run_engine(params, cfg):
    """(tokens/s, token streams) for one warmed engine pass."""
    eng = Engine(params, cfg, batch_slots=SLOTS, cache_len=CACHE_LEN)
    eng.run(_requests(cfg.vocab_size))          # warm-up: jit compiles
    reqs = _requests(cfg.vocab_size)
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    streams = {r.rid: list(r.out_tokens) for r in done}
    return toks / dt, streams


def bench_engine() -> List:
    rows = []
    print("\n== serving engine (CPU; interpret-mode kernels) ==")
    cfg0 = reduced(get_config(ARCH), layers=2, d_model=64, vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)

    tok_s, _ = _run_engine(params0, cfg0)
    print(f"  dense           : {tok_s:7.1f} tok/s")
    rows.append(("engine/dense", 1e6 / tok_s, f"tok_s={tok_s:.1f}"))

    for sp in SPARSITIES:
        streams = {}
        rates = {}
        for path in PATHS:
            p, c = build_serving_params(
                params0, cfg0, path=path, sparsity=sp,
                block_k=8, block_n=8, verbose=False)
            rates[path], streams[path] = _run_engine(p, c)
        agree = int(streams["kernel"] == streams["packed"])
        speedup = rates["packed"] / rates["kernel"]
        print(f"  sp={sp:.2f}: masked={rates['masked']:7.1f} "
              f"kernel(repack)={rates['kernel']:7.1f} "
              f"packed={rates['packed']:7.1f} tok/s "
              f"(packed/kernel x{speedup:.2f}, "
              f"outputs {'==' if agree else '!='})")
        for path in PATHS:
            rows.append((f"engine/{path}/sp{sp:.2f}",
                         1e6 / rates[path],
                         f"tok_s={rates[path]:.2f};"
                         f"kernel_packed_agree={agree}"))
        rows.append((f"engine/packed_speedup/sp{sp:.2f}", 0.0,
                     f"x{speedup:.3f}_vs_percall_repack"))
    return rows


def rows_to_json(rows, path: str):
    payload = [{"name": n, "us_per_call": round(us, 3), "derived": d}
               for n, us, d in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path} ({len(payload)} rows)")


def main():
    rows = bench_engine()
    rows_to_json(rows, "BENCH_engine.json")


if __name__ == "__main__":
    main()
