"""SASP ↔ model integration: overlay merge, path equivalence, PTQ, and
train-through-masks."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import compute_sasp_masks, prune_params
from repro.core.sasp import (
    bsr_overlay_from_masks,
    build_sasp_overlay,
    merge_overlay,
    quantize_params,
    sasp_summary,
)
from repro.models import lm

KEY = jax.random.PRNGKey(0)
SASP = SASPConfig(enabled=True, block_k=16, block_n=16, sparsity=0.4)


def _setup(arch="qwen3-32b"):
    cfg = dataclasses.replace(
        reduced(get_config(arch), layers=2, d_model=64, vocab=128),
        sasp=SASP)
    params = lm.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    return cfg, params, {"tokens": toks}


def test_masked_view_changes_loss_but_not_params():
    cfg, params, batch = _setup()
    overlay, sp = build_sasp_overlay(params, SASP)
    assert 0.35 < sp < 0.45
    l_dense = float(lm.loss_fn(params, cfg, batch)[0])
    l_masked = float(lm.loss_fn(merge_overlay(params, overlay), cfg,
                                batch)[0])
    assert l_dense != l_masked
    # original params untouched
    l_again = float(lm.loss_fn(params, cfg, batch)[0])
    assert l_again == l_dense


def test_bsr_path_matches_masked_path():
    cfg, params, batch = _setup()
    masks = compute_sasp_masks(params, SASP)
    pruned, _ = prune_params(params, SASP)
    bov = bsr_overlay_from_masks(params, masks, SASP)
    cfg_bsr = dataclasses.replace(
        cfg, sasp=dataclasses.replace(SASP, path="bsr"))
    l_masked = float(lm.loss_fn(pruned, cfg, batch)[0])
    l_bsr = float(lm.loss_fn(merge_overlay(params, bov), cfg_bsr,
                             batch)[0])
    assert abs(l_masked - l_bsr) < 1e-4


def test_kernel_path_matches_masked_path():
    cfg, params, batch = _setup()
    masks = compute_sasp_masks(params, SASP)
    pruned, _ = prune_params(params, SASP)
    bov = bsr_overlay_from_masks(params, masks, SASP)
    cfg_k = dataclasses.replace(
        cfg, sasp=dataclasses.replace(SASP, path="kernel"))
    l_masked = float(lm.loss_fn(pruned, cfg, batch)[0])
    l_kernel = float(lm.loss_fn(merge_overlay(params, bov), cfg_k,
                                batch)[0])
    assert abs(l_masked - l_kernel) < 2e-3


def test_ptq_int8_close_to_dense():
    cfg, params, batch = _setup()
    pq = quantize_params(params, SASP)
    l_dense = float(lm.loss_fn(params, cfg, batch)[0])
    l_q = float(lm.loss_fn(pq, cfg, batch)[0])
    assert abs(l_dense - l_q) < 0.05


def test_grad_flows_only_through_kept_tiles():
    cfg, params, batch = _setup()
    # scope pruning to w1 only: with untrained scaled-init weights,
    # global-L1 across all matrices can prune the (small-init) w2
    # entirely, zeroing the whole FFN path and every FFN grad — a
    # legitimate selection outcome that would vacuously pass/fail this
    # gradient-masking check.
    from repro.core.pruning import compute_sasp_masks
    from repro.core.sasp import masks_to_overlay

    def w1_only(path):
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        return keys.endswith("ffn/w1/w")

    masks = compute_sasp_masks(params, SASP, is_prunable=w1_only)
    overlay = masks_to_overlay(masks)

    def loss(p):
        return lm.loss_fn(merge_overlay(p, overlay), cfg, batch)[0]

    g = jax.grad(loss)(params)
    # find one masked ffn weight and its mask
    seg = g["segments"][0]
    gm = np.asarray(seg["slot0"]["ffn"]["w1"]["w"])[0]   # layer 0
    ov_seg = overlay["segments"]["0"]["slot0"]["ffn"]["sasp_masks"]["w1"]
    mask = np.asarray(ov_seg)[0]
    KB, NB = mask.shape
    bk, bn = gm.shape[0] // KB, gm.shape[1] // NB
    gb = np.abs(gm).reshape(KB, bk, NB, bn).sum((1, 3))
    assert (gb[~mask] == 0).all()
    assert (gb[mask] > 0).any()


def test_sasp_summary_counts():
    cfg, params, _ = _setup()
    overlay, sp = build_sasp_overlay(params, SASP)
    s = sasp_summary(overlay)
    assert s["n_masked_matrices"] >= 2      # stacked w1/w3/w2
    assert abs(s["sparsity"] - sp) < 1e-9


def test_moe_sasp_masked_loss_changes():
    cfg, params, batch = _setup("granite-moe-1b-a400m")
    overlay, sp = build_sasp_overlay(params, SASP)
    assert sp > 0.3
    l0 = float(lm.loss_fn(params, cfg, batch)[0])
    l1 = float(lm.loss_fn(merge_overlay(params, overlay), cfg, batch)[0])
    assert l0 != l1
