"""Flash-attention Pallas kernel vs oracles (interpret mode) — shape /
dtype / window sweep + cross-check against the model's chunked jnp
attention."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attn.kernel import flash_attention
from repro.kernels.flash_attn.ops import mha
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.models.attention import attend_chunked

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("H,Sq,Sk,D,win,bq,bk", [
    (2, 64, 64, 32, 10 ** 9, 32, 32),        # causal
    (4, 128, 128, 64, 32, 64, 64),           # sliding window
    (2, 64, 128, 32, 10 ** 9, 32, 32),       # decode-ish Sq < Sk
    (1, 32, 32, 16, 8, 16, 16),              # tiny window
])
def test_flash_vs_ref(H, Sq, Sk, D, win, bq, bk):
    q = jnp.asarray(RNG.normal(size=(H, Sq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(H, Sk, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(H, Sk, D)), jnp.float32)
    qp = jnp.arange(Sk - Sq, Sk)
    kp = jnp.arange(Sk)
    y = flash_attention(q, k, v, qp, kp, window=win, block_q=bq,
                        block_k=bk)
    ref = flash_attention_ref(q, k, v, qp, kp, window=win)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q = jnp.asarray(RNG.normal(size=(2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(2, 64, 32)), jnp.bfloat16)
    pos = jnp.arange(64)
    y = flash_attention(q, k, v, pos, pos, window=10 ** 9,
                        block_q=32, block_k=32).astype(jnp.float32)
    ref = flash_attention_ref(q.astype(jnp.float32),
                              k.astype(jnp.float32),
                              v.astype(jnp.float32), pos, pos,
                              window=10 ** 9)
    denom = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / denom < 3e-2


def test_mha_gqa_matches_model_chunked_attention():
    """The kernel (via the GQA wrapper) and the model's jnp chunked
    online-softmax must agree — two independent implementations."""
    B, S, H, KH, D = 2, 64, 8, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KH, D)), jnp.float32)
    pos = jnp.arange(S)
    y_kernel = mha(q, k, v, pos, pos, window=10 ** 9)
    qg = q.reshape(B, S, KH, H // KH, D)
    y_model = attend_chunked(qg, k, v, pos, pos, window=S + 1
                             ).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(y_kernel),
                               np.asarray(y_model), rtol=2e-5, atol=2e-5)
