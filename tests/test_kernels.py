"""Per-kernel shape/dtype/sparsity sweeps vs the pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.quantization import quantize_int8
from repro.core.sparse import bsr_from_mask
from repro.kernels.int8_gemm.ops import int8_matmul
from repro.kernels.int8_gemm.ref import int8_gemm_ref
from repro.kernels.sasp_gemm import ops as sasp_ops
from repro.kernels.sasp_gemm.ref import block_list_ref, masked_dense_ref

RNG = np.random.default_rng(0)


def _case(M, K, N, bk, bn, sparsity, dtype=np.float32):
    x = jnp.asarray(RNG.normal(size=(M, K)).astype(dtype))
    w = RNG.normal(size=(K, N)).astype(np.float32)
    mask = RNG.random((K // bk, N // bn)) > sparsity
    return x, w, mask


SWEEP = [
    (8, 16, 16, 8, 8, 0.0),
    (16, 32, 64, 8, 16, 0.3),
    (64, 128, 128, 32, 32, 0.5),
    (32, 64, 96, 16, 16, 0.9),
    (128, 256, 128, 64, 64, 0.6),
    (7, 16, 32, 8, 8, 0.4),          # ragged M
]


@pytest.mark.parametrize("M,K,N,bk,bn,sp", SWEEP)
def test_sasp_gemm_fp_vs_oracle(M, K, N, bk, bn, sp):
    x, w, mask = _case(M, K, N, bk, bn, sp)
    ref = masked_dense_ref(x, jnp.asarray(w), jnp.asarray(mask))
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
    y = sasp_ops.sasp_matmul_packed(x, wv, kn, n=N, block_m=min(M, 128))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # the kernel's own input view agrees with the independent oracle
    ref2 = block_list_ref(x, wv, kn, N)
    np.testing.assert_allclose(np.asarray(y), ref2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N,bk,bn,sp", SWEEP[:4])
def test_sasp_gemm_int8_vs_oracle(M, K, N, bk, bn, sp):
    x, w, mask = _case(M, K, N, bk, bn, sp)
    ref = masked_dense_ref(x, jnp.asarray(w), jnp.asarray(mask))
    wv, kn, sc = sasp_ops.build_kernel_weight(w, mask, bk, bn,
                                              quantize=True)
    y = sasp_ops.sasp_matmul_packed(x, wv, kn, sc, n=N)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / scale < 2e-2
    # against the oracle that consumes the SAME int8 inputs: tight
    ref2 = block_list_ref(x, wv, kn, N, scales=sc)
    np.testing.assert_allclose(np.asarray(y), ref2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N,bk,bn,sp", SWEEP[:4])
def test_sasp_gemm_masked_grid_variant(M, K, N, bk, bn, sp):
    x, w, mask = _case(M, K, N, bk, bn, sp)
    ref = masked_dense_ref(x, jnp.asarray(w), jnp.asarray(mask))
    y = sasp_ops.masked_matmul(x, jnp.asarray(w),
                               jnp.asarray(mask, jnp.int32),
                               block_m=min(M, 128), block_k=bk, block_n=bn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sasp_gemm_bf16():
    x, w, mask = _case(32, 64, 64, 16, 16, 0.5, dtype=np.float32)
    x16 = x.astype(jnp.bfloat16)
    ref = masked_dense_ref(x16, jnp.asarray(w, jnp.bfloat16),
                           jnp.asarray(mask)).astype(jnp.float32)
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, 16, 16)
    y = sasp_ops.sasp_matmul_packed(
        x16, wv.astype(jnp.bfloat16), kn, n=64).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / scale < 3e-2


def test_sasp_gemm_fully_pruned_column():
    # output columns with zero surviving blocks must be exactly zero
    x, w, _ = _case(16, 32, 32, 8, 8, 0.0)
    mask = np.zeros((4, 4), bool)
    mask[:, 0] = True                # only first column block survives
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, 8, 8)
    y = np.asarray(sasp_ops.sasp_matmul_packed(x, wv, kn, n=32))
    assert np.allclose(y[:, 8:], 0.0)
    ref = masked_dense_ref(x, jnp.asarray(w), jnp.asarray(mask))
    np.testing.assert_allclose(y, np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_sasp_gemm_via_bsr_container():
    x, w, mask = _case(16, 64, 96, 16, 16, 0.5)
    bsr = bsr_from_mask(w, mask, 16, 16)
    y = sasp_ops.sasp_matmul(x, bsr)
    ref = masked_dense_ref(x, jnp.asarray(w), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N,bk,bn", [
    (16, 32, 64, 8, 16), (64, 128, 128, 32, 32), (7, 16, 16, 8, 8),
    (32, 64, 64, 64, 64),
])
def test_int8_gemm_vs_oracle(M, K, N, bk, bn):
    x = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    qw = quantize_int8(w, bk, bn)
    y = int8_matmul(x, qw)
    ref = int8_gemm_ref(x, qw.q, qw.scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # and close to the unquantized product
    full = x @ w
    rel = float(jnp.max(jnp.abs(y - full)) / jnp.max(jnp.abs(full)))
    assert rel < 2e-2
