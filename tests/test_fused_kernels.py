"""Fused epilogue (bias + activation in the last-visit flush) and fused
gated-FFN kernel vs the pure-jnp oracles in kernels/sasp_gemm/ref.py and
the masked-dense path, across fp32/bf16/int8 — including the
all-pruned-column padding case."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.sasp_gemm import ops as sasp_ops
from repro.kernels.sasp_gemm.ref import (
    epilogue_ref,
    fused_ffn_ref,
    masked_dense_ref,
)

RNG = np.random.default_rng(0)


def _case(M, K, N, bk, bn, sparsity, dtype=np.float32):
    x = jnp.asarray(RNG.normal(size=(M, K)).astype(dtype))
    w = RNG.normal(size=(K, N)).astype(np.float32)
    mask = RNG.random((K // bk, N // bn)) > sparsity
    return x, w, mask


def _mask_dense(w, mask, bk, bn):
    KB, NB = mask.shape
    wb = w.reshape(KB, bk, NB, bn) * mask[:, None, :, None]
    return wb.reshape(w.shape).astype(np.float32)


SWEEP = [
    (8, 16, 16, 8, 8, 0.0),
    (16, 32, 64, 8, 16, 0.3),
    (64, 128, 128, 32, 32, 0.5),
    (32, 64, 96, 16, 16, 0.9),
    (7, 16, 32, 8, 8, 0.4),          # ragged M
]


# ---------------------------------------------------------------------------
# GEMM epilogue: bias + activation in the flush
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N,bk,bn,sp", SWEEP)
@pytest.mark.parametrize("act", [None, "silu", "relu"])
def test_epilogue_fp32_vs_masked_dense(M, K, N, bk, bn, sp, act):
    x, w, mask = _case(M, K, N, bk, bn, sp)
    bias = RNG.normal(size=(N,)).astype(np.float32)
    ref = epilogue_ref(masked_dense_ref(x, jnp.asarray(w),
                                        jnp.asarray(mask)), bias, act)
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
    y = sasp_ops.sasp_matmul_packed(x, wv, kn, n=N, block_m=min(M, 128),
                                    bias=jnp.asarray(bias), act=act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_epilogue_act_only(act):
    M, K, N, bk, bn, sp = 16, 32, 64, 8, 16, 0.5
    x, w, mask = _case(M, K, N, bk, bn, sp)
    ref = epilogue_ref(masked_dense_ref(x, jnp.asarray(w),
                                        jnp.asarray(mask)), None, act)
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
    y = sasp_ops.sasp_matmul_packed(x, wv, kn, n=N, act=act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_epilogue_int8_vs_oracle():
    M, K, N, bk, bn = 32, 64, 64, 16, 16
    x, w, mask = _case(M, K, N, bk, bn, 0.4)
    bias = RNG.normal(size=(N,)).astype(np.float32)
    wv, kn, sc = sasp_ops.build_kernel_weight(w, mask, bk, bn,
                                              quantize=True)
    y = sasp_ops.sasp_matmul_packed(x, wv, kn, sc, n=N,
                                    bias=jnp.asarray(bias), act="silu")
    ref = epilogue_ref(masked_dense_ref(x, jnp.asarray(w),
                                        jnp.asarray(mask)), bias, "silu")
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / scale < 2e-2
    # against the oracle consuming the SAME int8 inputs: tight
    from repro.kernels.sasp_gemm.ref import block_list_ref
    ref2 = epilogue_ref(jnp.asarray(block_list_ref(x, wv, kn, N,
                                                   scales=sc)),
                        bias, "silu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref2),
                               rtol=1e-4, atol=1e-4)


def test_epilogue_bf16():
    M, K, N, bk, bn = 32, 64, 64, 16, 16
    x, w, mask = _case(M, K, N, bk, bn, 0.5)
    bias = RNG.normal(size=(N,)).astype(np.float32)
    x16 = x.astype(jnp.bfloat16)
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
    y = sasp_ops.sasp_matmul_packed(
        x16, wv.astype(jnp.bfloat16), kn, n=N, bias=jnp.asarray(bias),
        act="relu").astype(jnp.float32)
    ref = epilogue_ref(masked_dense_ref(x16, jnp.asarray(w, jnp.bfloat16),
                                        jnp.asarray(mask)), bias,
                       "relu").astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / scale < 3e-2


def test_epilogue_fully_pruned_column_gets_act_bias():
    """Empty output columns must flush act(bias), matching the
    masked-dense semantics act(x @ (w ⊙ mask) + b)."""
    M, K, N, bk, bn = 16, 32, 32, 8, 8
    x, w, _ = _case(M, K, N, bk, bn, 0.0)
    mask = np.zeros((4, 4), bool)
    mask[:, 0] = True                # only first column block survives
    bias = RNG.normal(size=(N,)).astype(np.float32)
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
    y = np.asarray(sasp_ops.sasp_matmul_packed(
        x, wv, kn, n=N, bias=jnp.asarray(bias), act="silu"))
    ref = np.asarray(epilogue_ref(
        masked_dense_ref(x, jnp.asarray(w), jnp.asarray(mask)), bias,
        "silu"))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
    # pruned columns carry exactly act(bias), broadcast across rows
    want = np.asarray(epilogue_ref(jnp.zeros((1, N)), bias, "silu"))
    np.testing.assert_allclose(y[:, bn:], np.broadcast_to(
        want[:, bn:], (M, N - bn)), rtol=1e-5, atol=1e-5)


def test_padded_visit_list_matches_compact():
    """Dup-last-visit zero padding (layer-stack sharing of one static
    nnz) must not change the result."""
    M, K, N, bk, bn = 16, 32, 64, 8, 16
    x, w, mask = _case(M, K, N, bk, bn, 0.5)
    wv, kn, _ = sasp_ops.build_kernel_weight(w, mask, bk, bn)
    y0 = np.asarray(sasp_ops.sasp_matmul_packed(x, wv, kn, n=N))
    vp, kp, _ = sasp_ops.pad_block_list(np.asarray(wv), np.asarray(kn),
                                        None, np.asarray(wv).shape[0] + 3)
    y1 = np.asarray(sasp_ops.sasp_matmul_packed(
        x, jnp.asarray(vp), jnp.asarray(kp), n=N))
    np.testing.assert_allclose(y0, y1, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Fused gated FFN
# ---------------------------------------------------------------------------


def _ffn_case(M, d, F, bk, bf, sp1, sp2):
    x = jnp.asarray(RNG.normal(size=(M, d)), jnp.float32)
    w1 = RNG.normal(size=(d, F)).astype(np.float32)
    w3 = RNG.normal(size=(d, F)).astype(np.float32)
    w2 = RNG.normal(size=(F, d)).astype(np.float32) * 0.1
    m1 = RNG.random((d // bk, F // bf)) > sp1
    m3 = RNG.random((d // bk, F // bf)) > sp1
    m2 = RNG.random((F // bf, d // bk)) > sp2
    return (x, _mask_dense(w1, m1, bk, bf), _mask_dense(w3, m3, bk, bf),
            _mask_dense(w2, m2, bf, bk))


@pytest.mark.parametrize("M,d,F,bk,bf,sp1,sp2", [
    (16, 32, 64, 8, 16, 0.0, 0.0),
    (32, 64, 128, 16, 16, 0.4, 0.4),
    (8, 32, 96, 8, 16, 0.7, 0.3),
    (7, 16, 32, 8, 8, 0.5, 0.5),     # ragged M
])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_fused_ffn_fp32_vs_masked_dense(M, d, F, bk, bf, sp1, sp2, act):
    x, w1m, w3m, w2m = _ffn_case(M, d, F, bk, bf, sp1, sp2)
    ref = fused_ffn_ref(x, w1m, w3m, w2m, act=act)
    w1v, w3v, w2v, b1, b3, b2, _ = sasp_ops.build_fused_ffn(
        w1m, w3m, w2m, block_f=bf)
    y = sasp_ops.fused_ffn_matmul(x, w1v, w3v, w2v, b1, b3, b2, act=act,
                                  block_m=min(M, 128))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_ffn_with_biases():
    M, d, F, bk, bf = 16, 32, 64, 8, 16
    x, w1m, w3m, w2m = _ffn_case(M, d, F, bk, bf, 0.5, 0.5)
    b1 = RNG.normal(size=(F,)).astype(np.float32)
    b3 = RNG.normal(size=(F,)).astype(np.float32)
    b2 = RNG.normal(size=(d,)).astype(np.float32)
    ref = fused_ffn_ref(x, w1m, w3m, w2m, b1, b3, b2, act="silu")
    w1v, w3v, w2v, b1v, b3v, b2v, _ = sasp_ops.build_fused_ffn(
        w1m, w3m, w2m, block_f=bf, b1=b1, b3=b3, b2=b2)
    y = sasp_ops.fused_ffn_matmul(x, w1v, w3v, w2v, b1v, b3v, b2v,
                                  act="silu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_ffn_bf16():
    M, d, F, bk, bf = 16, 32, 64, 8, 16
    x, w1m, w3m, w2m = _ffn_case(M, d, F, bk, bf, 0.4, 0.4)
    ref = fused_ffn_ref(x, w1m, w3m, w2m, act="silu")
    w1v, w3v, w2v, b1, b3, b2, _ = sasp_ops.build_fused_ffn(
        w1m, w3m, w2m, block_f=bf)
    y = sasp_ops.fused_ffn_matmul(
        x.astype(jnp.bfloat16), w1v.astype(jnp.bfloat16),
        w3v.astype(jnp.bfloat16), w2v.astype(jnp.bfloat16), b1, b3, b2,
        act="silu").astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / scale < 3e-2


def test_fused_ffn_int8():
    M, d, F, bk, bf = 32, 64, 128, 16, 16
    x, w1m, w3m, w2m = _ffn_case(M, d, F, bk, bf, 0.4, 0.4)
    ref = fused_ffn_ref(x, w1m, w3m, w2m, act="silu")
    w1v, w3v, w2v, b1, b3, b2, scales = sasp_ops.build_fused_ffn(
        w1m, w3m, w2m, block_f=bf, quantize=True)
    assert scales is not None
    y = sasp_ops.fused_ffn_matmul(x, w1v, w3v, w2v, b1, b3, b2,
                                  scales=scales, act="silu")
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(y - ref))) / scale < 5e-2


def test_fused_ffn_skips_pruned_columns():
    """Fully pruned d_ff column-blocks must be absent from the visit
    list (FLOPs AND bytes drop) without changing the output."""
    M, d, F, bk, bf = 16, 32, 64, 8, 16
    x, w1m, w3m, w2m = _ffn_case(M, d, F, bk, bf, 0.0, 0.0)
    w1m[:, :2 * bf] = 0.0              # kill d_ff columns 0..1 in w1
    w2m[3 * bf:] = 0.0                 # kill d_ff row-block 3 in w2
    w1v, _, _, _, _, _, _ = sasp_ops.build_fused_ffn(
        w1m, w3m, w2m, block_f=bf)
    assert w1v.shape[0] == 1           # only column-block 2 survives
    ref = fused_ffn_ref(x, w1m, w3m, w2m, act="silu")
    w1v, w3v, w2v, b1, b3, b2, _ = sasp_ops.build_fused_ffn(
        w1m, w3m, w2m, block_f=bf)
    y = sasp_ops.fused_ffn_matmul(x, w1v, w3v, w2v, b1, b3, b2,
                                  act="silu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_ffn_all_pruned():
    """Everything pruned -> one zero padding visit -> output == b2."""
    M, d, F, bf = 8, 16, 32, 8
    x = jnp.asarray(RNG.normal(size=(M, d)), jnp.float32)
    z = np.zeros((d, F), np.float32)
    b2 = RNG.normal(size=(d,)).astype(np.float32)
    w1v, w3v, w2v, b1, b3, b2v, _ = sasp_ops.build_fused_ffn(
        z, z, z.T.copy(), block_f=bf, b2=b2)
    assert w1v.shape[0] == 1
    y = np.asarray(sasp_ops.fused_ffn_matmul(x, w1v, w3v, w2v, b1, b3,
                                             b2v, act="silu"))
    np.testing.assert_allclose(y, np.broadcast_to(b2, (M, d)),
                               rtol=1e-6, atol=1e-6)


def test_fused_ffn_visit_padding_matches():
    """Zero-w2v visit padding (layer-stack sharing) is a no-op."""
    M, d, F, bk, bf = 16, 32, 64, 8, 16
    x, w1m, w3m, w2m = _ffn_case(M, d, F, bk, bf, 0.5, 0.5)
    a = sasp_ops.build_fused_ffn(w1m, w3m, w2m, block_f=bf)
    b = sasp_ops.build_fused_ffn(w1m, w3m, w2m, block_f=bf,
                                 nv_pad=np.asarray(a[0]).shape[0] + 2)
    ya = sasp_ops.fused_ffn_matmul(x, *a[:6], act="silu")
    yb = sasp_ops.fused_ffn_matmul(x, *b[:6], act="silu")
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=1e-6, atol=1e-6)
