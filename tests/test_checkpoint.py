"""Checkpoint manager: atomicity, CRC, retention, async, resume."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.checkpoint import CheckpointManager


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 8)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16)},
        "step": jnp.asarray(3, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(10, s, extra={"data_step": 123})
    restored, extra = mgr.restore(jax.eval_shape(lambda: s))
    assert extra["data_step"] == 123
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(5, s)
    d = os.path.join(str(tmp_path), "step_0000000005")
    # corrupt the array file
    path = os.path.join(d, "arrays.0.npz")
    data = dict(np.load(path))
    k = sorted(data)[0]
    data[k] = data[k] + 1.0
    np.savez(path, **data)
    with pytest.raises(IOError, match="CRC"):
        mgr.restore(jax.eval_shape(lambda: s))


def test_atomic_no_partial_checkpoint(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "tmp.99.123"))
    assert mgr.latest_step() is None
    mgr.save(1, _state())
    assert mgr.latest_step() == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save_async(7, s)
    mgr.wait()
    restored, _ = mgr.restore(jax.eval_shape(lambda: s))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((8,))},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(jax.eval_shape(lambda: bad))


@pytest.mark.slow
def test_restore_resumes_training(tmp_path):
    """Full loop: train 2 steps, checkpoint, restore, continue — states
    must match a run without interruption (deterministic data)."""
    from repro.configs import get_config, reduced
    from repro.data.pipeline import DataConfig, DataState, Pipeline
    from repro.models import lm
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step

    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=32, vocab=64)
    dcfg = DataConfig(vocab_size=64, seq_len=16, global_batch=2)
    opt_cfg = AdamWConfig(lr=1e-3)
    step_fn = make_train_step(cfg, opt_cfg)

    def run(n_steps, start=None):
        if start is None:
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            opt = adamw_init(params, opt_cfg)
            pipe = Pipeline(dcfg)
        else:
            params, opt, pipe = start
        for _ in range(n_steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt, pipe

    # uninterrupted 4 steps
    p_ref, _, _ = run(4)

    # 2 steps -> checkpoint -> restore -> 2 more
    p2, o2, pipe2 = run(2)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"params": p2, "opt": o2}, extra=pipe2.state.to_dict())
    like = jax.eval_shape(lambda: {"params": p2, "opt": o2})
    restored, extra = mgr.restore(like)
    pipe3 = Pipeline(dcfg, state=DataState.from_dict(extra))
    p_resumed, _, _ = run(2, start=(restored["params"], restored["opt"],
                                    pipe3))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
