"""Multi-device distribution tests. Each test runs tests/dist_worker.py
in a subprocess with 8 fake CPU devices (the main test process must keep
seeing 1 device, so no XLA_FLAGS here). All tests here are marked
``slow``: the fast CI job skips them with ``-m "not slow"``."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")


def _failure_summary(mode, p) -> str:
    """Readable worker-failure report: the final traceback (trimmed) and
    the stdout tail, instead of one assert line burying both."""
    err = p.stderr.strip().splitlines()
    tb_start = max((i for i, ln in enumerate(err)
                    if ln.startswith("Traceback")), default=None)
    tb = err[tb_start:] if tb_start is not None else err[-20:]
    if len(tb) > 30:
        tb = tb[:5] + ["    …"] + tb[-24:]
    parts = [f"dist worker '{mode}' exited rc={p.returncode}"]
    out_tail = p.stdout.strip().splitlines()[-3:]
    if out_tail:
        parts += ["--- worker stdout (tail) ---"] + out_tail
    parts += ["--- worker traceback ---"] + (tb or ["<empty stderr>"])
    return "\n".join(parts)


def run_worker(mode, *args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    p = subprocess.run([sys.executable, WORKER, mode, *args],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    if p.returncode != 0:
        pytest.fail(_failure_summary(mode, p), pytrace=False)
    for line in p.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    pytest.fail(f"worker '{mode}' printed no RESULT line:\n{p.stdout}\n"
                f"{p.stderr}", pytrace=False)


def test_sharded_train_step_matches_single_device():
    r = run_worker("sharded_train")
    assert abs(r["loss_ref"] - r["loss_sh"]) < 1e-3
    assert r["max_param_diff"] < 1e-3


def test_moe_ep_close_to_local():
    r = run_worker("moe_ep")
    # drop-free: EP all_to_all dispatch must match local math exactly
    assert r["rel_nodrop"] < 1e-4
    # default capacity: per-shard caps drop different tokens; small mean
    assert r["mean_rel"] < 0.15
    assert abs(r["aux_local"] - r["aux_ep"]) < 0.05


def test_compressed_psum_within_int8_bound():
    r = run_worker("grad_compress")
    assert r["err"] <= r["bound"]
    assert r["residual_norm"] > 0         # error feedback carries state


def test_elastic_checkpoint_reshard(tmp_path):
    r = run_worker("elastic_reshard", str(tmp_path))
    assert r["ok_value"] and r["ok_shard"]


def test_decode_with_sharded_caches_matches_reference():
    r = run_worker("decode_sharded")
    assert r["max_diff"] < 2e-3


def test_collective_parser_ground_truth():
    """The trip-count-aware HLO parser must exactly recover L x bytes for
    an all-reduce inside a scan of known length."""
    r = run_worker("collective_parser_ground_truth")
    assert r["all_reduce"] == r["expected"]


def test_rs_ag_int8_ffn_close_to_exact():
    """TP FFN with reduce-scatter + int8 all-gather (EXPERIMENTS §Perf
    B iter 5) stays within int8 resolution of the exact FFN."""
    r = run_worker("rs_ag_int8_ffn")
    assert r["rel"] < 2e-2


def test_mesh_packed_serving_streams_bit_identical():
    """Mesh-native packed serving (DESIGN.md §10): greedy decode streams
    under a 2×2 (data, model) mesh — TP-sharded visit lists, sharded
    caches, shard_map packed drivers for the fused FFN and the attention
    projections — must be bit-identical to the single-device packed
    path. (Deterministic, not flaky: fixed weights/prompts and XLA CPU
    give reproducible reductions per JAX version. If a JAX upgrade ever
    reassociates the fused psum enough to flip an argmax, this SHOULD
    fail loudly — bit-identity is the ISSUE-2 acceptance contract.)"""
    r = run_worker("packed_serve_mesh", timeout=560)
    assert r["n"] == 3
    assert r["fused_signal"] > 0      # the FFN reduction carries signal
    assert r["equal"] == 1, (r["streams_ref"], r["streams_mesh"])


def test_paged_kv_mesh_packed_bit_identical():
    """Paged KV on the 1×2-mesh packed path (DESIGN.md §13): block-table
    gather + page pool must reproduce the contiguous mesh engine's
    greedy streams bit-for-bit — under oversubscription, and across a
    forced preempt → spill(host) → fault → resume cycle."""
    r = run_worker("paged_mesh", timeout=560)
    assert r["equal"] == 1, (r["streams_ref"], r["streams_paged"])
    assert r["drained"] == 1          # every page back on the free list
    assert r["cycle_equal"] == 1, r
    assert r["preemptions"] >= 1
    assert r["spills"] >= 1 and r["faults"] >= 1, r
    assert r["device_used"] == 0


def test_sched_mesh_continuous_batching_bit_identical():
    """Sharded scheduler on mesh packed paths (DESIGN.md §11): a slot
    freed by EOS is refilled from the queue mid-decode, and every
    request's greedy stream is bit-identical to running it alone
    through the single-batch engine — on the 1×2 TP mesh (one DP rank)
    and the 2×2 mesh (two DP-rank engine shards on submeshes)."""
    r = run_worker("sched_mesh", timeout=560)
    for name in ("1x2", "2x2"):
        assert r[f"equal_{name}"] == 1, (
            r[f"streams_ref_{name}"], r[f"streams_got_{name}"])
        assert r[f"eos_early_{name}"] == 1
        assert r[f"refills_{name}"] >= 1
    assert r["ranks_2x2"] == 2
    assert r["ranks_served_2x2"] == 2   # both DP ranks took traffic
    # streaming + bucketed EDF admission on the 1×2 mesh (DESIGN.md
    # §12): per-token iterator bit-identical to the solo mesh engine,
    # admission jit cache bounded by the bucket table
    assert r["stream_equal"] == 1, r
    assert r["stream_events"] > 0
    assert r["admit_shapes_ok"] == 1, r["admit_shapes"]
