"""Data pipeline determinism/resume + schedules + watchdog."""
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.pipeline import DataConfig, DataState, Pipeline, \
    asr_batch, lm_batch
from repro.train.schedule import StragglerWatchdog, warmup_cosine


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 5))
def test_lm_batch_pure_function_of_step(step, seed):
    cfg = DataConfig(vocab_size=97, seq_len=64, global_batch=4, seed=seed)
    a = lm_batch(cfg, step)["tokens"]
    b = lm_batch(cfg, step)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 64) and a.min() >= 0 and a.max() < 97


def test_different_steps_differ():
    cfg = DataConfig(vocab_size=97, seq_len=64, global_batch=4)
    assert not np.array_equal(lm_batch(cfg, 0)["tokens"],
                              lm_batch(cfg, 1)["tokens"])


def test_host_sharding_disjoint():
    a = lm_batch(DataConfig(vocab_size=97, seq_len=32, global_batch=8,
                            num_hosts=2, host_id=0), 5)["tokens"]
    b = lm_batch(DataConfig(vocab_size=97, seq_len=32, global_batch=8,
                            num_hosts=2, host_id=1), 5)["tokens"]
    assert a.shape == (4, 32)
    assert not np.array_equal(a, b)


def test_resume_continues_stream():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=2)
    p1 = Pipeline(cfg)
    seq1 = [p1.next()["tokens"] for _ in range(5)]
    # resume from step 3
    p2 = Pipeline(cfg, state=DataState(step=3))
    np.testing.assert_array_equal(p2.next()["tokens"], seq1[3])
    np.testing.assert_array_equal(p2.next()["tokens"], seq1[4])


def test_asr_batch_learnable_structure():
    cfg = DataConfig(vocab_size=32, seq_len=16, global_batch=4)
    b = asr_batch(cfg, 0, d_model=24, noise=0.0)
    assert b["embeds"].shape == (4, 16, 24)
    # noise-free features are a pure function of the token => same token,
    # same feature
    t = b["tokens"]
    f = b["embeds"]
    i0 = np.argwhere(t == t[0, 0])
    ref = f[0, 0]
    for bi, si in i0:
        np.testing.assert_allclose(f[bi, si], ref, rtol=1e-6)


def test_warmup_cosine_shape():
    fn = warmup_cosine(100, 1000)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(100)) - 1.0) < 1e-5
    assert float(fn(550)) < 1.0
    assert abs(float(fn(1000)) - 0.1) < 2e-2


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog()
    for _ in range(50):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)           # 5x slower step flagged
    assert wd.slow_steps == 1
    # cadence tightens as variance rises
    base = wd.checkpoint_every(1000)
    for _ in range(20):
        wd.observe(3.0)
        wd.observe(0.5)
    assert wd.checkpoint_every(1000) <= base
