"""Paged KV-cache memory subsystem (DESIGN.md §13): tile-aligned page
geometry, allocator bookkeeping (hypothesis state machine: no leaks, no
double-frees, watermark held after every step), and the engine-level
bit-identity contract — greedy streams with paging on must equal the
contiguous-cache engine exactly, including across forced spill→fault
cycles, preempt/resume (page unmap), drop-to-reprefill, bucketed
admission, int8 KV, and local-window stacks. The 1×2-mesh packed twin
lives in tests/test_distribution.py (``paged_mesh`` worker)."""
import dataclasses

import numpy as np
import jax
import pytest

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, invariant, \
        precondition, rule
    HAVE_HYPOTHESIS = True
except ImportError:                      # the fixed twin below still runs
    HAVE_HYPOTHESIS = False

from repro.configs import SASPConfig, get_config, reduced
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.memory import PageAllocator, PagedKVPool, \
    tile_aligned_page_len
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen3-32b", kv_quant=False, amplify=True):
    cfg = reduced(get_config(arch), layers=2, d_model=64, vocab=64)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = lm.init_params(KEY, cfg)
    if amplify:     # position-dependent streams (see test_scheduler.py)
        params = jax.tree.map(lambda a: a * 3.0, params)
    return cfg, params


def _solo(params, cfg, req: Request):
    r = Request(rid=req.rid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, eos_id=req.eos_id)
    return Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [r])[0].out_tokens


def _mk_requests(n, rng, max_new=6, eos=False):
    return [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(int(
                        rng.integers(4, 30)),)).astype(np.int32),
                    max_new_tokens=max_new,
                    eos_id=int(rng.integers(0, 64)) if eos else None)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Page geometry
# ---------------------------------------------------------------------------


def test_tile_aligned_page_len():
    cfg, _ = _setup()
    # no SASP: any divisor of cache_len is legal; default ~C/8
    assert tile_aligned_page_len(cfg, 64) == 8
    assert tile_aligned_page_len(cfg, 64, 16) == 16
    # SASP deployed: page must be a multiple of the pruning tile
    sasp = SASPConfig(enabled=True, block_k=8, block_n=8, sparsity=0.25)
    cfg8 = dataclasses.replace(cfg, sasp=sasp)
    assert tile_aligned_page_len(cfg8, 64) == 8       # one tile
    assert tile_aligned_page_len(cfg8, 64, 16) == 16  # 2 tiles
    with pytest.raises(ValueError, match="multiple of the SASP tile"):
        tile_aligned_page_len(cfg8, 64, 12)
    with pytest.raises(ValueError, match="multiple of kv page_len"):
        tile_aligned_page_len(cfg, 64, 24)            # 64 % 24 != 0
    with pytest.raises(ValueError):
        tile_aligned_page_len(cfg, 64, 128)           # > cache_len


def test_pool_rejects_hybrid_stacks():
    cfg, params = _setup("mamba2-780m", amplify=False)
    with pytest.raises(ValueError, match="attention-only"):
        Engine(params, cfg, batch_slots=2, cache_len=64, kv_pages=8,
               kv_page_len=8)


# ---------------------------------------------------------------------------
# Allocator bookkeeping (fixed twin + hypothesis state machine)
# ---------------------------------------------------------------------------


def test_allocator_basic_lifecycle():
    a = PageAllocator(range(2, 10), host_slots=4, watermark_cap=6,
                      slot_pages=4)
    assert a.admit(0, 3) == (True, [])          # no moves needed
    assert a.used_dev == 3
    assert a.ensure(0, 3) == (True, [])         # growth, room available
    assert a.used_dev == 4
    # watermark: cap 6, so a 3-page admit must fail (nothing to spill)
    assert a.admit(1, 3) == (False, [])
    assert a.admit(1, 2) == (True, [])
    a.preempt(0)
    # rid 0's cold pages spill to host to make room
    ok, moves = a.admit(2, 4)
    assert ok
    assert moves and all(m[0] == "spill" and m[1] == 0 for m in moves)
    assert a.spills == len(moves)
    a.free(2)
    ok, moves = a.resume(0)                     # faults them back
    assert ok
    assert moves and all(m[0] == "fault" and m[1] == 0 for m in moves)
    a.check()
    a.free(0)
    a.free(1)
    assert a.used_dev == 0 and a.used_host == 0
    with pytest.raises(AssertionError, match="double free"):
        a.free(0)
    a.check()


def test_allocator_drops_to_reprefill_when_host_full():
    a = PageAllocator(range(2, 8), host_slots=0, watermark_cap=6,
                      slot_pages=4)
    assert a.admit(0, 4) == (True, [])
    a.preempt(0)
    assert a.admit(1, 4) == (True, [])          # 0 dropped, not spilled
    assert a.drops == 1 and not a.has(0)
    a.check()
    a.free(1)
    assert a.used_dev == 0


def test_failed_admit_still_executes_partial_spills():
    """A failed allocation may have ALREADY spilled cold pages in the
    allocator's bookkeeping; those moves must still reach the host
    pool, or the victim's later resume would fault back never-written
    zeros (silent KV corruption — caught in review)."""
    import jax.numpy as jnp

    cfg, params = _setup()
    pool = PagedKVPool(params, cfg, cache_len=64, device_pages=4,
                       page_len=16, host_pages=4)     # NB = 4, cap = 4
    assert pool.admit(0, 2)                     # A resident, 2 pages
    assert pool.admit(1, 2)                     # B, 2 pages
    b_pages = jnp.asarray([p for p in pool.alloc.dev_pages(1)
                           if p is not None])
    # stamp B's pages with a recognizable marker on every leaf
    pool.data = jax.tree.map(
        lambda a: a.at[:, b_pages].set(jnp.asarray(7, a.dtype)),
        pool.data)
    pool.preempt(1)
    # C wants 3 pages: both of B's cold pages spill, room is still
    # only 2 — the admit FAILS but the spills must have executed
    assert not pool.admit(2, 3)
    assert pool.stats().spills == 2
    assert pool.stats().host_used == 2
    assert pool.resume(1)                       # faults B back
    got = pool._read(pool.data,
                     jnp.asarray([p for p in pool.alloc.dev_pages(1)
                                  if p is not None]))
    for leaf in jax.tree.leaves(got):
        assert (np.asarray(leaf) == 7).all(), "spilled data lost"
    pool.alloc.check()


if HAVE_HYPOTHESIS:

    class PoolMachine(RuleBasedStateMachine):
        """Random admission / growth / EOS / preemption / resume over
        the allocator: after EVERY step no page is leaked or
        double-owned and the device-page count stays ≤ the watermark
        (the ISSUE's acceptance invariants)."""

        def __init__(self):
            super().__init__()
            self.a = PageAllocator(range(2, 14), host_slots=5,
                                   watermark_cap=10, slot_pages=4)
            self.next_rid = 0

        @rule(n=st.integers(1, 4))
        def admit(self, n):
            rid = self.next_rid
            self.next_rid += 1
            ok, _ = self.a.admit(rid, n)
            if not ok:
                assert not self.a.has(rid)      # failed admit is clean

        @precondition(lambda self: self.a.resident)
        @rule(data=st.data())
        def grow(self, data):
            rid = data.draw(st.sampled_from(sorted(self.a.resident)))
            js = [j for j, e in enumerate(self.a.tables[rid])
                  if e is None]
            if js:
                self.a.ensure(rid, js[0])

        @precondition(lambda self: self.a.tables)
        @rule(data=st.data())
        def eos(self, data):
            rid = data.draw(st.sampled_from(sorted(self.a.tables)))
            self.a.free(rid)

        @precondition(lambda self: self.a.resident)
        @rule(data=st.data())
        def preempt(self, data):
            rid = data.draw(st.sampled_from(sorted(self.a.resident)))
            self.a.preempt(rid)

        @precondition(lambda self: self.a.preempted)
        @rule(data=st.data())
        def resume(self, data):
            rid = data.draw(st.sampled_from(list(self.a.preempted)))
            before = list(self.a.preempted)
            ok, _ = self.a.resume(rid)
            if not ok:
                # failed resume must leave the request preempted (its
                # pages may have been dropped by room-making for OTHERS
                # only — never by its own protected resume)
                assert self.a.has(rid) and rid in self.a.preempted
                assert self.a.preempted.index(rid) == \
                    before.index(rid) - sum(
                        1 for r in before[:before.index(rid)]
                        if r not in self.a.preempted)

        @invariant()
        def no_leaks_no_double_free_watermark_held(self):
            self.a.check()

    PoolMachine.TestCase.settings = settings(
        max_examples=60, stateful_step_count=40, deadline=None)
    TestPoolMachine = PoolMachine.TestCase


# ---------------------------------------------------------------------------
# Engine bit-identity: paging on == contiguous cache, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_pages,host", [(24, 0), (10, 8)])
def test_paged_streams_bit_identical_and_no_leak(kv_pages, host):
    """Ample pool AND oversubscribed pool (admission defers, slots
    refill as pages free): every greedy stream equals the contiguous
    engine; every page is back on the free list at the end."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    reqs = _mk_requests(7, rng, eos=True)
    ref = {r.rid: _solo(params, cfg, r) for r in reqs}
    eng = Engine(params, cfg, batch_slots=4, cache_len=64,
                 kv_pages=kv_pages, kv_page_len=8, kv_host_pages=host)
    rng = np.random.default_rng(0)
    done = eng.run(_mk_requests(7, rng, eos=True))
    assert {r.rid: r.out_tokens for r in done} == ref
    mem = eng.memory_stats()
    assert mem.device_used == 0 and mem.host_used == 0, mem.as_dict()
    eng.pool.alloc.check()


def test_paged_bucketed_admission_bit_identical_and_bounded():
    """Paging composes with prefill bucketing: fixed admission shapes
    (jit cache ≤ len(buckets)) and streams equal to the plain engine."""
    cfg, params = _setup()
    buckets = (8, 16, 32, 64)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 64, size=(int(rng.integers(2, 60)),))
               .astype(np.int32) for _ in range(20)]
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=2)
                  for i, p in enumerate(prompts)]
    plain = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(mk())}
    eng = Engine(params, cfg, batch_slots=2, cache_len=64,
                 buckets=buckets, kv_pages=16, kv_page_len=8)
    shapes = set()
    orig = eng._prefill

    def counting(params_, toks, poss, data, dests):
        shapes.add(tuple(toks.shape))
        return orig(params_, toks, poss, data, dests)

    eng._prefill = counting
    done = eng.run(mk())
    assert {r.rid: r.out_tokens for r in done} == plain
    assert len(shapes) <= len(buckets), shapes
    assert all(g == 2 and s in buckets for g, s in shapes), shapes


def test_paged_int8_kv_bit_identical_to_contiguous_int8():
    cfg, params = _setup(kv_quant=True)
    rng = np.random.default_rng(2)
    reqs = _mk_requests(4, rng)
    ref = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(
        [Request(rid=r.rid, prompt=r.prompt,
                 max_new_tokens=r.max_new_tokens) for r in reqs])}
    got = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64, kv_pages=16,
        kv_page_len=8).run(reqs)}
    assert got == ref


def test_paged_local_window_stack_bit_identical():
    """gemma3-style local:global interleave: the paged pool forces a
    UNIFORM ring capacity (local layers lose their min(window, C) cap);
    the window mask must keep streams identical anyway."""
    cfg, params = _setup("gemma3-4b")
    assert cfg.sliding_window, "arch no longer exercises local layers"
    rng = np.random.default_rng(3)
    reqs = _mk_requests(4, rng, max_new=8)
    ref = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(
        [Request(rid=r.rid, prompt=r.prompt,
                 max_new_tokens=r.max_new_tokens) for r in reqs])}
    got = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64, kv_pages=20,
        kv_page_len=8).run(reqs)}
    assert got == ref


def test_forced_spill_fault_and_preempt_resume_bit_identical():
    """The ISSUE's acceptance cycle: a batch request is preempted (page
    unmap), its pages SPILL to host RAM when the interactive working
    set needs the room, FAULT back on resume — both streams equal the
    solo contiguous engine bit-for-bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    batch = Request(rid=0, prompt=rng.integers(0, 64, size=(18,))
                    .astype(np.int32), max_new_tokens=14, slo="batch")
    inter = Request(rid=1, prompt=rng.integers(0, 64, size=(40,))
                    .astype(np.int32), max_new_tokens=3,
                    slo="interactive", deadline=0.01)
    ref = {r.rid: _solo(params, cfg, r) for r in (batch, inter)}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=8))
    assert sched.submit(batch)
    for _ in range(4):
        sched.step()
    assert sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    st = sched.stats()
    mem = st["per_rank"][0]["memory"]
    assert {r.rid: r.out_tokens for r in done} == ref
    assert st["preemptions"] >= 1
    assert mem["spills"] >= 1 and mem["faults"] >= 1, mem
    assert mem["device_used"] == 0 and mem["host_used"] == 0


def test_drop_to_reprefill_when_host_pool_full_still_exact():
    """No host pool: under pressure the preempted victim's pages are
    DROPPED and it resumes by re-prefill — still bit-exact, with the
    drop counted."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    batch = Request(rid=0, prompt=rng.integers(0, 64, size=(18,))
                    .astype(np.int32), max_new_tokens=14, slo="batch")
    inter = Request(rid=1, prompt=rng.integers(0, 64, size=(40,))
                    .astype(np.int32), max_new_tokens=3,
                    slo="interactive", deadline=0.01)
    ref = {r.rid: _solo(params, cfg, r) for r in (batch, inter)}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=0))
    assert sched.submit(batch)
    for _ in range(4):
        sched.step()
    assert sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    st = sched.stats()
    mem = st["per_rank"][0]["memory"]
    assert {r.rid: r.out_tokens for r in done} == ref
    assert mem["drops"] >= 1 and mem["spills"] == 0, mem
    assert mem["device_used"] == 0


def test_preempt_keep_kv_false_frees_pages_immediately():
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    req = Request(rid=0, prompt=rng.integers(0, 64, size=(12,))
                  .astype(np.int32), max_new_tokens=8)
    ref = _solo(params, cfg, req)
    eng = Engine(params, cfg, batch_slots=1, cache_len=64, kv_pages=8,
                 kv_page_len=8)
    eng.submit(req)
    for _ in range(3):
        eng.step()
    victim = eng.preempt_slot(0, keep_kv=False)
    assert eng.memory_stats().device_used == 0      # freed outright
    eng.submit(victim)
    done = []
    while eng.has_work():
        done.extend(eng.step())
    assert done[0].out_tokens == ref
    assert eng.stats["resumes"] == 1


# ---------------------------------------------------------------------------
# Admission consults pool headroom (scheduler co-op)
# ---------------------------------------------------------------------------


def test_admission_capacity_consults_pool_headroom():
    """A paged engine with free SLOTS but an exhausted POOL must report
    zero absorbable capacity, so the scheduler's max_queue check sheds
    instead of counting phantom free slots."""
    cfg, params = _setup()
    eng = Engine(params, cfg, batch_slots=4, cache_len=64, kv_pages=8,
                 kv_page_len=8)
    assert eng.admission_capacity() == 4            # empty pool: slots
    rng = np.random.default_rng(8)
    eng.submit(Request(rid=0, prompt=rng.integers(0, 64, size=(60,))
                       .astype(np.int32), max_new_tokens=4))
    eng.step()                                      # 8/8 pages resident
    assert eng.n_free() == 3
    assert eng.admission_capacity() == 0            # no pages left

    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=4, cache_len=64,
                              max_queue=1, kv_pages=8, kv_page_len=8))
    assert sched.submit(Request(
        rid=0, prompt=rng.integers(0, 64, size=(60,)).astype(np.int32),
        max_new_tokens=4))
    sched.step()
    # pool exhausted: only max_queue=1 waiter is absorbable despite 3
    # free slots; the third submission sheds
    assert sched.submit(Request(
        rid=1, prompt=rng.integers(0, 64, size=(10,)).astype(np.int32),
        max_new_tokens=2))
    assert not sched.submit(Request(
        rid=2, prompt=rng.integers(0, 64, size=(10,)).astype(np.int32),
        max_new_tokens=2))
    done = sched.run([])
    assert sorted(r.rid for r in done) == [0, 1]


# ---------------------------------------------------------------------------
# Memory stress (slow): sustained churn through a tiny pool
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_memory_stress_churn_no_leaks_bit_identical():
    """Sustained oversubscribed churn: 24 requests with random lengths,
    budgets and EOS through 4 slots backed by a 12-page pool + host
    spill, EDF + preemption on. Every stream must match the solo
    engine; the watermark must hold after every step; the pool must
    drain empty."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(int(
                        rng.integers(4, 50)),)).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 12)),
                    eos_id=int(rng.integers(0, 64)),
                    slo="interactive" if i % 3 == 0 else "batch",
                    deadline=0.02 if i % 3 == 0 else 30.0)
            for i in range(24)]
    ref = {r.rid: _solo(params, cfg, r) for r in reqs}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=4, cache_len=64,
                              policy="edf", aging=0.01, preempt=True,
                              kv_pages=12, kv_page_len=8,
                              kv_host_pages=12))
    for r in reqs:
        assert sched.submit(r)
    eng = sched.shards[0]
    done = []
    while sched.has_work():
        done.extend(sched.step())
        mem = eng.memory_stats()
        assert mem.device_used <= mem.watermark
        eng.pool.alloc.check()
    assert {r.rid: r.out_tokens for r in done} == ref
    mem = eng.memory_stats()
    assert mem.device_used == 0 and mem.host_used == 0
