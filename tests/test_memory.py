"""Paged KV-cache memory subsystem (DESIGN.md §13): tile-aligned page
geometry, allocator bookkeeping (hypothesis state machine: no leaks, no
double-frees, watermark held after every step), and the engine-level
bit-identity contract — greedy streams with paging on must equal the
contiguous-cache engine exactly, including across forced spill→fault
cycles, preempt/resume (page unmap), drop-to-reprefill, bucketed
admission, int8 KV, and local-window stacks. The 1×2-mesh packed twin
lives in tests/test_distribution.py (``paged_mesh`` worker)."""
import dataclasses

import numpy as np
import jax
import pytest

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, invariant, \
        precondition, rule
    HAVE_HYPOTHESIS = True
except ImportError:                      # the fixed twin below still runs
    HAVE_HYPOTHESIS = False

from repro.configs import SASPConfig, get_config, reduced
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.memory import PageAllocator, PagedKVPool, \
    tile_aligned_page_len
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen3-32b", kv_quant=False, amplify=True):
    cfg = reduced(get_config(arch), layers=2, d_model=64, vocab=64)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = lm.init_params(KEY, cfg)
    if amplify:     # position-dependent streams (see test_scheduler.py)
        params = jax.tree.map(lambda a: a * 3.0, params)
    return cfg, params


def _solo(params, cfg, req: Request):
    r = Request(rid=req.rid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, eos_id=req.eos_id)
    return Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [r])[0].out_tokens


def _mk_requests(n, rng, max_new=6, eos=False):
    return [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(int(
                        rng.integers(4, 30)),)).astype(np.int32),
                    max_new_tokens=max_new,
                    eos_id=int(rng.integers(0, 64)) if eos else None)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Page geometry
# ---------------------------------------------------------------------------


def test_tile_aligned_page_len():
    cfg, _ = _setup()
    # no SASP: any divisor of cache_len is legal; default ~C/8
    assert tile_aligned_page_len(cfg, 64) == 8
    assert tile_aligned_page_len(cfg, 64, 16) == 16
    # SASP deployed: page must be a multiple of the pruning tile
    sasp = SASPConfig(enabled=True, block_k=8, block_n=8, sparsity=0.25)
    cfg8 = dataclasses.replace(cfg, sasp=sasp)
    assert tile_aligned_page_len(cfg8, 64) == 8       # one tile
    assert tile_aligned_page_len(cfg8, 64, 16) == 16  # 2 tiles
    with pytest.raises(ValueError, match="multiple of the SASP tile"):
        tile_aligned_page_len(cfg8, 64, 12)
    with pytest.raises(ValueError, match="multiple of kv page_len"):
        tile_aligned_page_len(cfg, 64, 24)            # 64 % 24 != 0
    with pytest.raises(ValueError):
        tile_aligned_page_len(cfg, 64, 128)           # > cache_len


def test_pool_rejects_hybrid_stacks():
    cfg, params = _setup("mamba2-780m", amplify=False)
    with pytest.raises(ValueError, match="attention-only"):
        Engine(params, cfg, batch_slots=2, cache_len=64, kv_pages=8,
               kv_page_len=8)


# ---------------------------------------------------------------------------
# Allocator bookkeeping (fixed twin + hypothesis state machine)
# ---------------------------------------------------------------------------


def test_allocator_basic_lifecycle():
    a = PageAllocator(range(2, 10), host_slots=4, watermark_cap=6,
                      slot_pages=4)
    assert a.admit(0, 3) == (True, [])          # no moves needed
    assert a.used_dev == 3
    assert a.ensure(0, 3) == (True, [])         # growth, room available
    assert a.used_dev == 4
    # watermark: cap 6, so a 3-page admit must fail (nothing to spill)
    assert a.admit(1, 3) == (False, [])
    assert a.admit(1, 2) == (True, [])
    a.preempt(0)
    # rid 0's cold pages spill to host to make room
    ok, moves = a.admit(2, 4)
    assert ok
    assert moves and all(m[0] == "spill" and m[1] == 0 for m in moves)
    assert a.spills == len(moves)
    a.free(2)
    ok, moves = a.resume(0)                     # faults them back
    assert ok
    assert moves and all(m[0] == "fault" and m[1] == 0 for m in moves)
    a.check()
    a.free(0)
    a.free(1)
    assert a.used_dev == 0 and a.used_host == 0
    with pytest.raises(AssertionError, match="double free"):
        a.free(0)
    a.check()


def test_allocator_drops_to_reprefill_when_host_full():
    a = PageAllocator(range(2, 8), host_slots=0, watermark_cap=6,
                      slot_pages=4)
    assert a.admit(0, 4) == (True, [])
    a.preempt(0)
    assert a.admit(1, 4) == (True, [])          # 0 dropped, not spilled
    assert a.drops == 1 and not a.has(0)
    a.check()
    a.free(1)
    assert a.used_dev == 0


def test_failed_admit_still_executes_partial_spills():
    """A failed allocation may have ALREADY spilled cold pages in the
    allocator's bookkeeping; those moves must still reach the host
    pool, or the victim's later resume would fault back never-written
    zeros (silent KV corruption — caught in review)."""
    import jax.numpy as jnp

    cfg, params = _setup()
    pool = PagedKVPool(params, cfg, cache_len=64, device_pages=4,
                       page_len=16, host_pages=4)     # NB = 4, cap = 4
    assert pool.admit(0, 2)                     # A resident, 2 pages
    assert pool.admit(1, 2)                     # B, 2 pages
    b_pages = jnp.asarray([p for p in pool.alloc.dev_pages(1)
                           if p is not None])
    # stamp B's pages with a recognizable marker on every leaf
    pool.data = jax.tree.map(
        lambda a: a.at[:, b_pages].set(jnp.asarray(7, a.dtype)),
        pool.data)
    pool.preempt(1)
    # C wants 3 pages: both of B's cold pages spill, room is still
    # only 2 — the admit FAILS but the spills must have executed
    assert not pool.admit(2, 3)
    assert pool.stats().spills == 2
    assert pool.stats().host_used == 2
    assert pool.resume(1)                       # faults B back
    got = pool._read(pool.data,
                     jnp.asarray([p for p in pool.alloc.dev_pages(1)
                                  if p is not None]))
    for leaf in jax.tree.leaves(got):
        assert (np.asarray(leaf) == 7).all(), "spilled data lost"
    pool.alloc.check()


if HAVE_HYPOTHESIS:

    class PoolMachine(RuleBasedStateMachine):
        """Random admission / growth / EOS / preemption / resume over
        the allocator: after EVERY step no page is leaked or
        double-owned and the device-page count stays ≤ the watermark
        (the ISSUE's acceptance invariants)."""

        def __init__(self):
            super().__init__()
            self.a = PageAllocator(range(2, 14), host_slots=5,
                                   watermark_cap=10, slot_pages=4)
            self.next_rid = 0

        @rule(n=st.integers(1, 4))
        def admit(self, n):
            rid = self.next_rid
            self.next_rid += 1
            ok, _ = self.a.admit(rid, n)
            if not ok:
                assert not self.a.has(rid)      # failed admit is clean

        @precondition(lambda self: self.a.resident)
        @rule(data=st.data())
        def grow(self, data):
            rid = data.draw(st.sampled_from(sorted(self.a.resident)))
            js = [j for j, e in enumerate(self.a.tables[rid])
                  if e is None]
            if js:
                self.a.ensure(rid, js[0])

        @precondition(lambda self: self.a.tables)
        @rule(data=st.data())
        def eos(self, data):
            rid = data.draw(st.sampled_from(sorted(self.a.tables)))
            self.a.free(rid)

        @precondition(lambda self: self.a.resident)
        @rule(data=st.data())
        def preempt(self, data):
            rid = data.draw(st.sampled_from(sorted(self.a.resident)))
            self.a.preempt(rid)

        @precondition(lambda self: self.a.preempted)
        @rule(data=st.data())
        def resume(self, data):
            rid = data.draw(st.sampled_from(list(self.a.preempted)))
            before = list(self.a.preempted)
            ok, _ = self.a.resume(rid)
            if not ok:
                # failed resume must leave the request preempted (its
                # pages may have been dropped by room-making for OTHERS
                # only — never by its own protected resume)
                assert self.a.has(rid) and rid in self.a.preempted
                assert self.a.preempted.index(rid) == \
                    before.index(rid) - sum(
                        1 for r in before[:before.index(rid)]
                        if r not in self.a.preempted)

        @invariant()
        def no_leaks_no_double_free_watermark_held(self):
            self.a.check()

    PoolMachine.TestCase.settings = settings(
        max_examples=60, stateful_step_count=40, deadline=None)
    TestPoolMachine = PoolMachine.TestCase


# ---------------------------------------------------------------------------
# Engine bit-identity: paging on == contiguous cache, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_pages,host", [(24, 0), (10, 8)])
def test_paged_streams_bit_identical_and_no_leak(kv_pages, host):
    """Ample pool AND oversubscribed pool (admission defers, slots
    refill as pages free): every greedy stream equals the contiguous
    engine; every page is back on the free list at the end."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    reqs = _mk_requests(7, rng, eos=True)
    ref = {r.rid: _solo(params, cfg, r) for r in reqs}
    eng = Engine(params, cfg, batch_slots=4, cache_len=64,
                 kv_pages=kv_pages, kv_page_len=8, kv_host_pages=host)
    rng = np.random.default_rng(0)
    done = eng.run(_mk_requests(7, rng, eos=True))
    assert {r.rid: r.out_tokens for r in done} == ref
    mem = eng.memory_stats()
    # sharing on (REPRO_KV_SHARE leg): retired prompts may survive as
    # rc-0 cached prefix pages — reclaimable, not leaked
    assert mem.device_used == mem.cached_pages, mem.as_dict()
    assert mem.host_used == 0, mem.as_dict()
    eng.pool.alloc.check()


def test_paged_bucketed_admission_bit_identical_and_bounded():
    """Paging composes with prefill bucketing: fixed admission shapes
    (jit cache ≤ len(buckets)) and streams equal to the plain engine."""
    cfg, params = _setup()
    buckets = (8, 16, 32, 64)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 64, size=(int(rng.integers(2, 60)),))
               .astype(np.int32) for _ in range(20)]
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=2)
                  for i, p in enumerate(prompts)]
    plain = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(mk())}
    eng = Engine(params, cfg, batch_slots=2, cache_len=64,
                 buckets=buckets, kv_pages=16, kv_page_len=8)
    shapes = set()
    orig = eng._prefill

    def counting(params_, toks, poss, data, dests):
        shapes.add(tuple(toks.shape))
        return orig(params_, toks, poss, data, dests)

    eng._prefill = counting
    done = eng.run(mk())
    assert {r.rid: r.out_tokens for r in done} == plain
    assert len(shapes) <= len(buckets), shapes
    assert all(g == 2 and s in buckets for g, s in shapes), shapes


def test_paged_int8_kv_bit_identical_to_contiguous_int8():
    cfg, params = _setup(kv_quant=True)
    rng = np.random.default_rng(2)
    reqs = _mk_requests(4, rng)
    ref = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(
        [Request(rid=r.rid, prompt=r.prompt,
                 max_new_tokens=r.max_new_tokens) for r in reqs])}
    got = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64, kv_pages=16,
        kv_page_len=8).run(reqs)}
    assert got == ref


def test_paged_local_window_stack_bit_identical():
    """gemma3-style local:global interleave: the paged pool forces a
    UNIFORM ring capacity (local layers lose their min(window, C) cap);
    the window mask must keep streams identical anyway."""
    cfg, params = _setup("gemma3-4b")
    assert cfg.sliding_window, "arch no longer exercises local layers"
    rng = np.random.default_rng(3)
    reqs = _mk_requests(4, rng, max_new=8)
    ref = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(
        [Request(rid=r.rid, prompt=r.prompt,
                 max_new_tokens=r.max_new_tokens) for r in reqs])}
    got = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64, kv_pages=20,
        kv_page_len=8).run(reqs)}
    assert got == ref


def test_forced_spill_fault_and_preempt_resume_bit_identical():
    """The ISSUE's acceptance cycle: a batch request is preempted (page
    unmap), its pages SPILL to host RAM when the interactive working
    set needs the room, FAULT back on resume — both streams equal the
    solo contiguous engine bit-for-bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    batch = Request(rid=0, prompt=rng.integers(0, 64, size=(18,))
                    .astype(np.int32), max_new_tokens=14, slo="batch")
    inter = Request(rid=1, prompt=rng.integers(0, 64, size=(40,))
                    .astype(np.int32), max_new_tokens=3,
                    slo="interactive", deadline=0.01)
    ref = {r.rid: _solo(params, cfg, r) for r in (batch, inter)}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=8))
    assert sched.submit(batch)
    for _ in range(4):
        sched.step()
    assert sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    st = sched.stats()
    mem = st["per_rank"][0]["memory"]
    assert {r.rid: r.out_tokens for r in done} == ref
    assert st["preemptions"] >= 1
    assert mem["spills"] >= 1 and mem["faults"] >= 1, mem
    assert mem["device_used"] == 0 and mem["host_used"] == 0


def test_drop_to_reprefill_when_host_pool_full_still_exact():
    """No host pool: under pressure the preempted victim's pages are
    DROPPED and it resumes by re-prefill — still bit-exact, with the
    drop counted."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    batch = Request(rid=0, prompt=rng.integers(0, 64, size=(18,))
                    .astype(np.int32), max_new_tokens=14, slo="batch")
    inter = Request(rid=1, prompt=rng.integers(0, 64, size=(40,))
                    .astype(np.int32), max_new_tokens=3,
                    slo="interactive", deadline=0.01)
    ref = {r.rid: _solo(params, cfg, r) for r in (batch, inter)}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=0))
    assert sched.submit(batch)
    for _ in range(4):
        sched.step()
    assert sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    st = sched.stats()
    mem = st["per_rank"][0]["memory"]
    assert {r.rid: r.out_tokens for r in done} == ref
    assert mem["drops"] >= 1 and mem["spills"] == 0, mem
    assert mem["device_used"] == 0


def test_preempt_keep_kv_false_frees_pages_immediately():
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    req = Request(rid=0, prompt=rng.integers(0, 64, size=(12,))
                  .astype(np.int32), max_new_tokens=8)
    ref = _solo(params, cfg, req)
    eng = Engine(params, cfg, batch_slots=1, cache_len=64, kv_pages=8,
                 kv_page_len=8)
    eng.submit(req)
    for _ in range(3):
        eng.step()
    victim = eng.preempt_slot(0, keep_kv=False)
    mem = eng.memory_stats()
    # freed outright — under forced sharing (REPRO_KV_SHARE leg) the
    # registered prompt pages legitimately linger as rc-0 cached
    assert mem.device_used == mem.cached_pages, mem.as_dict()
    eng.submit(victim)
    done = []
    while eng.has_work():
        done.extend(eng.step())
    assert done[0].out_tokens == ref
    assert eng.stats["resumes"] == 1


# ---------------------------------------------------------------------------
# Admission consults pool headroom (scheduler co-op)
# ---------------------------------------------------------------------------


def test_admission_capacity_consults_pool_headroom():
    """A paged engine with free SLOTS but an exhausted POOL must report
    zero absorbable capacity, so the scheduler's max_queue check sheds
    instead of counting phantom free slots."""
    cfg, params = _setup()
    eng = Engine(params, cfg, batch_slots=4, cache_len=64, kv_pages=8,
                 kv_page_len=8)
    assert eng.admission_capacity() == 4            # empty pool: slots
    rng = np.random.default_rng(8)
    eng.submit(Request(rid=0, prompt=rng.integers(0, 64, size=(60,))
                       .astype(np.int32), max_new_tokens=4))
    eng.step()                                      # 8/8 pages resident
    assert eng.n_free() == 3
    assert eng.admission_capacity() == 0            # no pages left

    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=4, cache_len=64,
                              max_queue=1, kv_pages=8, kv_page_len=8))
    assert sched.submit(Request(
        rid=0, prompt=rng.integers(0, 64, size=(60,)).astype(np.int32),
        max_new_tokens=4))
    sched.step()
    # pool exhausted: only max_queue=1 waiter is absorbable despite 3
    # free slots; the third submission sheds
    assert sched.submit(Request(
        rid=1, prompt=rng.integers(0, 64, size=(10,)).astype(np.int32),
        max_new_tokens=2))
    assert not sched.submit(Request(
        rid=2, prompt=rng.integers(0, 64, size=(10,)).astype(np.int32),
        max_new_tokens=2))
    done = sched.run([])
    assert sorted(r.rid for r in done) == [0, 1]


# ---------------------------------------------------------------------------
# Memory stress (slow): sustained churn through a tiny pool
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_memory_stress_churn_no_leaks_bit_identical():
    """Sustained oversubscribed churn: 24 requests with random lengths,
    budgets and EOS through 4 slots backed by a 12-page pool + host
    spill, EDF + preemption on. Every stream must match the solo
    engine; the watermark must hold after every step; the pool must
    drain empty."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(int(
                        rng.integers(4, 50)),)).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 12)),
                    eos_id=int(rng.integers(0, 64)),
                    slo="interactive" if i % 3 == 0 else "batch",
                    deadline=0.02 if i % 3 == 0 else 30.0)
            for i in range(24)]
    ref = {r.rid: _solo(params, cfg, r) for r in reqs}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=4, cache_len=64,
                              policy="edf", aging=0.01, preempt=True,
                              kv_pages=12, kv_page_len=8,
                              kv_host_pages=12))
    for r in reqs:
        assert sched.submit(r)
    eng = sched.shards[0]
    done = []
    while sched.has_work():
        done.extend(sched.step())
        mem = eng.memory_stats()
        assert mem.device_used <= mem.watermark
        eng.pool.alloc.check()
    assert {r.rid: r.out_tokens for r in done} == ref
    mem = eng.memory_stats()
    assert mem.device_used == 0 and mem.host_used == 0


# ---------------------------------------------------------------------------
# Refcounted prefix sharing (DESIGN.md §16): allocator state machine,
# fixed twin, resume-under-host-pressure
# ---------------------------------------------------------------------------

# three prompt "chains" of four page keys each — prefixes collide across
# requests, so random admissions exercise fork / cached reuse / COW
_CHAINS = tuple(tuple(bytes([c, j]) for j in range(4)) for c in range(3))


if HAVE_HYPOTHESIS:

    class SharedPoolMachine(PoolMachine):
        """The PR-5 machine over the REFCOUNTED allocator: random
        interleavings of admit-with-shared-prefix / fork / register /
        COW / grow / preempt / resume / spill / free must preserve "no
        leaks, no double-frees, refcount == number of block-table
        references, watermark respected" after every step (the ISSUE's
        acceptance invariants; ``check()`` verifies all of them plus
        radix-index consistency)."""

        def __init__(self):
            RuleBasedStateMachine.__init__(self)
            self.a = PageAllocator(range(2, 14), host_slots=5,
                                   watermark_cap=10, slot_pages=4,
                                   share=True)
            self.next_rid = 0

        @rule(c=st.integers(0, 2), n=st.integers(1, 4),
              reg=st.integers(0, 4))
        def admit_shared(self, c, n, reg):
            """Admit along chain ``c`` (same chain = fork) and publish
            the first ``reg`` pages into the prefix index."""
            rid = self.next_rid
            self.next_rid += 1
            keys = _CHAINS[c][:n]
            ok, _, m = self.a.admit_prefix(rid, n, keys)
            if not ok:
                assert not self.a.has(rid)      # unwound, no leaked refs
                return
            assert 0 <= m <= n
            self.a.register_prefix(rid, keys[:reg])

        @precondition(lambda self: self.a.resident)
        @rule(data=st.data())
        def cow(self, data):
            """Write rule: make a random resident page writable —
            shared pages must COW, registered ones unregister."""
            rid = data.draw(st.sampled_from(sorted(self.a.resident)))
            js = [j for j, e in enumerate(self.a.tables[rid])
                  if e is not None and e[0] == "dev"]
            if not js:
                return
            j = data.draw(st.sampled_from(js))
            p = self.a.tables[rid][j][1]
            was_shared = self.a.rc[p] > 1
            ok, _, copy = self.a.make_writable(rid, j)
            if ok:
                q = self.a.tables[rid][j][1]
                assert self.a.rc[q] == 1 and q not in self.a._node_of
                assert (copy is not None) == was_shared

    SharedPoolMachine.TestCase.settings = settings(
        max_examples=60, stateful_step_count=40, deadline=None)
    TestSharedPoolMachine = SharedPoolMachine.TestCase


def test_refcounted_allocator_fixed_twin():
    """Deterministic twin of SharedPoolMachine (runs without
    hypothesis): admit→register→fork→COW→grow→free-to-cached→cached
    reuse→LRU eviction→pinned-shared spill ordering→drop→unwound
    admit, with the full invariant check after every step."""
    a = PageAllocator(range(2, 12), host_slots=4, watermark_cap=8,
                      slot_pages=4, share=True)
    K = tuple(bytes([9, j]) for j in range(4))
    K2 = tuple(bytes([8, j]) for j in range(4))

    # admit a 3-page prompt, register its 2 full pages
    ok, _, m = a.admit_prefix(0, 3, K[:2])
    assert ok and m == 0                        # cold index: no match
    a.register_prefix(0, K[:2])
    a.check()
    p0, p1 = a.tables[0][0][1], a.tables[0][1][1]

    # fork: identical prompt maps both registered pages
    ok, _, m = a.admit_prefix(1, 3, K[:2])
    assert ok and m == 2 and a.prefix_hits == 1
    assert a.tables[1][0][1] == p0 and a.rc[p0] == 2
    assert a.used_dev == 4                      # 3 + 1, not 6
    a.check()

    # COW: the writer forks the shared page, the reader keeps p1
    ok, _, copy = a.make_writable(1, 1)
    assert ok and copy is not None and copy[0] == p1
    assert a.rc[p1] == 1 and a.tables[1][1][1] == copy[1]
    assert a.tables[0][1][1] == p1 and a.cow == 1
    a.check()

    # private registered page: writable = just unregister (no copy)
    ok, _, copy = a.make_writable(0, 1)
    assert ok and copy is None and p1 not in a._node_of
    a.check()

    # decode growth on a shared table
    assert a.ensure(0, 3)[0]
    a.check()

    # free: the registered page turns cached once BOTH owners drop it
    a.free(1)
    a.check()
    assert a.rc[p0] == 1                        # rid 0 still owns it
    a.free(0)
    a.check()
    assert a.cached == [p0] and p0 not in a.rc  # rc 0, matchable
    assert a.used_dev == 1                      # cached pages stay dev

    # cached reuse: a new prompt revives p0 from the cache
    ok, _, m = a.admit_prefix(2, 2, K[:2])
    assert ok and m == 1 and a.rc[p0] == 1 and not a.cached
    a.check()
    a.free(2)
    assert a.cached == [p0]

    # LRU eviction: room-making reclaims the cached page last-resort
    assert a.admit(3, 4)[0]
    assert a.admit(4, 4)[0]                     # needs the cached page
    assert a.evictions == 1 and not a.cached and p0 not in a._node_of
    a.check()
    a.free(3)
    a.free(4)

    # spill ordering: shared pages are PINNED on device; only the
    # victim's private page spills, then the shared-only holder drops
    ok, _, m = a.admit_prefix(5, 3, K2[:2])
    assert ok
    a.register_prefix(5, K2[:2])
    ok, _, m = a.admit_prefix(6, 2, K2[:2])
    assert ok and m == 2                        # rid 6 fully shared
    a.preempt(5)
    assert a.admit(7, 4)[0]                     # used 3 + 4 = 7
    ok, moves = a.admit(8, 4)                   # would need 4 more
    assert not ok                               # shared pages can't spill
    assert a.spills == 1                        # rid 5's private page
    assert [m_[0] for m_ in moves] == ["spill"]
    assert not a.has(5) and a.drops == 1        # shared-only holder drops
    assert a.has(6)                             # co-owner keeps the pages
    assert all(a.rc[e[1]] == 1 for e in a.tables[6] if e)
    a.check()
    assert a.admit(8, 2)[0]                     # the freed room admits
    a.check()

    # failed admit_prefix unwinds its matched refs exactly
    a.free(7)
    assert a.admit(10, 4)[0]                    # pool back at cap 8
    shared = [e[1] for e in a.tables[6] if e]
    ok, _, m = a.admit_prefix(12, 4, K2[:2])
    assert not ok and m == 0 and not a.has(12)
    assert all(a.rc[p] == 1 for p in shared)    # refs unwound
    a.check()

    for rid in (6, 8, 10):
        a.free(rid)
    a.check()
    assert a.used_dev == len(a.cached)          # only cached pages remain


def test_resume_when_host_pool_full_fails_clean_then_succeeds():
    """Satellite: ``resume`` under host-pool pressure. Room-making for
    the resume can neither spill (host full) nor drop (only the
    protected rid is cold): the resume must fail CLEANLY — request
    intact in its preempted position, zero faults executed, no
    partially-gathered pages leaked — and succeed once room frees."""
    a = PageAllocator(range(2, 8), host_slots=2, watermark_cap=6,
                      slot_pages=4)
    assert a.admit(0, 4)[0]
    a.preempt(0)
    assert a.admit(1, 4)[0]                     # spills 2 of rid 0's pages
    assert a.used_host == 2 and a.spills == 2   # host pool now full
    before = list(a.tables[0])
    ok, moves = a.resume(0)
    assert not ok and moves == []               # all-or-nothing: no faults
    assert a.has(0) and a.preempted == [0]
    assert a.tables[0] == before                # nothing leaked or moved
    assert a.used_host == 2 and a.faults == 0
    a.check()
    a.free(1)
    ok, moves = a.resume(0)                     # retry with room
    assert ok and sum(1 for m in moves if m[0] == "fault") == 2
    assert a.used_host == 0
    a.check()
    a.free(0)
    assert a.used_dev == 0


def test_pool_resume_under_host_pressure_keeps_spilled_data():
    """Pool-level twin with real arrays: the failed resume must leave
    the spilled pages' DATA intact in the host pool, so the retry
    faults back exactly what was written."""
    import jax.numpy as jnp

    cfg, params = _setup()
    pool = PagedKVPool(params, cfg, cache_len=64, device_pages=4,
                       page_len=16, host_pages=2)   # NB = 4, cap = 4
    assert pool.admit(0, 4)                     # rid 0 fills the pool
    pages = jnp.asarray([p for p in pool.alloc.dev_pages(0)
                         if p is not None])
    pool.data = jax.tree.map(
        lambda a: a.at[:, pages].set(jnp.asarray(7, a.dtype)),
        pool.data)
    pool.preempt(0)
    assert pool.admit(1, 2)                     # spills 2 pages, host full
    assert pool.stats().host_used == 2
    assert not pool.resume(0)                   # no room: clean failure
    assert pool.stats().host_used == 2 and pool.stats().faults == 0
    pool.alloc.check()
    pool.free(1)
    assert pool.resume(0)                       # retry: faults back
    got = pool._read(pool.data,
                     jnp.asarray([p for p in pool.alloc.dev_pages(0)
                                  if p is not None]))
    for leaf in jax.tree.leaves(got):
        assert (np.asarray(leaf) == 7).all(), "spilled data lost"
    pool.alloc.check()


# ---------------------------------------------------------------------------
# Prefix sharing: engine bit-identity oracle (DESIGN.md §16)
# ---------------------------------------------------------------------------


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = []
    while eng.has_work():
        done.extend(eng.step())
    return {r.rid: list(r.out_tokens) for r in done}


def _share_engine(params, cfg, share, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("kv_pages", 14)
    kw.setdefault("kv_page_len", 8)
    kw.setdefault("kv_host_pages", 8)
    return Engine(params, cfg, kv_share=share, **kw)


def test_share_fanout_bit_identical_and_leak_free():
    """Best-of-N fan-out: one prompt, N greedy samplers, admissions
    staggered through 2 slots so later arrivals map the first
    admission's resident pages. Streams must equal sharing-off AND the
    solo contiguous engine; afterwards every surviving device page is
    a cached (rc-0) prefix page — nothing leaked."""
    cfg, params = _setup()
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, 64, size=(25,)).astype(np.int32)
    mk = lambda: [Request(rid=i, prompt=prompt.copy(), max_new_tokens=7)
                  for i in range(6)]
    solo = _solo(params, cfg, mk()[0])
    off = _drive(_share_engine(params, cfg, False), mk())
    eng = _share_engine(params, cfg, True)
    on = _drive(eng, mk())
    assert on == off == {i: solo for i in range(6)}
    mem = eng.memory_stats()
    assert mem.prefix_hits > 0 and mem.prefix_pages_reused > 0
    assert eng.stats["prefill_tokens_skipped"] > 0
    assert mem.device_used == mem.cached_pages  # only the cache remains
    assert not eng.pool.alloc.rc               # no owned page survives
    eng.pool.alloc.check()


@pytest.mark.parametrize("d", [0, 1, 7, 8])
def test_share_divergence_at_page_boundaries_bit_identical(d):
    """Divergence pinned at offset {0, 1, L-1, L} past a 16-token
    (2-page) common prefix: the divergent page must never be mapped
    shared (offsets 0/1/7 land inside page 2; offset 8 shares all of
    it), and streams must equal sharing-off exactly."""
    cfg, params = _setup()
    rng = np.random.default_rng(22)
    base = rng.integers(0, 64, size=(16 + 9,)).astype(np.int32)
    var = base.copy()
    var[16 + d] = (var[16 + d] + 1) % 64
    mk = lambda: [Request(rid=0, prompt=base.copy(), max_new_tokens=6),
                  Request(rid=1, prompt=var.copy(), max_new_tokens=6)]
    ref = {r.rid: _solo(params, cfg, r) for r in mk()}
    # one slot forces strictly staggered admission: rid 1 sees rid 0's
    # registered pages and shares exactly the still-common prefix
    off = _drive(_share_engine(params, cfg, False, batch_slots=1), mk())
    eng = _share_engine(params, cfg, True, batch_slots=1)
    on = _drive(eng, mk())
    assert on == off == ref
    mem = eng.memory_stats()
    shared_pages = (16 + d) // 8
    assert mem.prefix_pages_reused == shared_pages, mem.as_dict()
    eng.pool.alloc.check()


def test_share_multi_turn_chat_replay_bit_identical():
    """Multi-turn replay: each turn's prompt is the full conversation
    so far (previous prompt + model reply + a new user turn). Sharing
    must skip the whole resident prefix and still match sharing-off
    and the solo engine on every turn."""
    cfg, params = _setup()
    rng = np.random.default_rng(23)
    sys_prompt = rng.integers(0, 64, size=(9,)).astype(np.int32)
    turns = [rng.integers(0, 64, size=(5,)).astype(np.int32)
             for _ in range(3)]

    def replay(build):
        history, streams = sys_prompt, []
        for t, turn in enumerate(turns):
            prompt = np.concatenate([history, turn]).astype(np.int32)
            out = build(t, prompt)
            streams.append(list(out))
            history = np.concatenate(
                [prompt, np.asarray(out, np.int32)])
        return streams

    ref = replay(lambda t, p: _solo(
        params, cfg, Request(rid=t, prompt=p, max_new_tokens=5)))
    e_off = _share_engine(params, cfg, False)
    off = replay(lambda t, p: e_off.run(
        [Request(rid=t, prompt=p, max_new_tokens=5)])[0].out_tokens)
    e_on = _share_engine(params, cfg, True)
    on = replay(lambda t, p: e_on.run(
        [Request(rid=t, prompt=p, max_new_tokens=5)])[0].out_tokens)
    assert on == off == ref
    assert e_on.stats["prefill_tokens_skipped"] > 0
    assert e_on.memory_stats().prefix_hits >= 2  # turns 2 and 3 share
    e_on.pool.alloc.check()


def test_share_ring_wrap_cow_bit_identical():
    """Decode past the ring capacity wraps into the SHARED prompt
    pages: the write rule must copy-on-write each one before the
    scatter, keeping co-owners' streams bit-identical."""
    cfg, params = _setup()
    rng = np.random.default_rng(24)
    sys_prompt = rng.integers(0, 64, size=(24,)).astype(np.int32)
    mk = lambda: [Request(rid=i, prompt=np.concatenate(
        [sys_prompt, np.asarray([i + 1], np.int32)]),
        max_new_tokens=45) for i in range(4)]
    off = _drive(_share_engine(params, cfg, False), mk())
    eng = _share_engine(params, cfg, True)
    on = _drive(eng, mk())
    assert on == off
    mem = eng.memory_stats()
    assert mem.cow_copies >= 1, mem.as_dict()   # wrap hit a shared page
    eng.pool.alloc.check()


def test_share_preempt_spill_resume_bit_identical():
    """The PR-5 acceptance cycle WITH sharing: two batch requests fork
    a shared prompt, an interactive deadline preempts them, their
    private pages spill to host and fault back on resume — streams
    still equal the solo contiguous engine bit-for-bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(25)
    shared = rng.integers(0, 64, size=(17,)).astype(np.int32)
    inter = rng.integers(0, 64, size=(40,)).astype(np.int32)
    mk = lambda: [
        Request(rid=0, prompt=shared.copy(), max_new_tokens=12,
                slo="batch"),
        Request(rid=1, prompt=np.concatenate(
            [shared, np.asarray([3], np.int32)]), max_new_tokens=12,
            slo="batch"),
        Request(rid=2, prompt=inter.copy(), max_new_tokens=3,
                slo="interactive", deadline=0.01)]
    ref = {r.rid: _solo(params, cfg, r) for r in mk()}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=10,
                              kv_share=True))
    reqs = mk()
    assert sched.submit(reqs[0])
    for _ in range(4):
        sched.step()
    assert sched.submit(reqs[1])
    for _ in range(2):
        sched.step()
    assert sched.submit(reqs[2])
    done = []
    while sched.has_work():
        done.extend(sched.step())
    st = sched.stats()
    mem = st["per_rank"][0]["memory"]
    assert {r.rid: r.out_tokens for r in done} == ref
    assert st["preemptions"] >= 1
    assert mem["spills"] >= 1 and mem["faults"] >= 1, mem
    sched.shards[0].pool.alloc.check()


@pytest.mark.slow
def test_share_radix_churn_stress_bit_identical_no_leaks():
    """Radix-churn stress: 20 requests drawn from 3 prompt families
    (shared system prefixes of different lengths) churn through 3
    slots over a tiny shared pool with EDF preemption — every stream
    must match the solo engine, the refcount invariants must hold
    after every step, and the pool must drain to cached-only."""
    cfg, params = _setup()
    rng = np.random.default_rng(26)
    families = [rng.integers(0, 64, size=(s,)).astype(np.int32)
                for s in (9, 17, 25)]

    def mk():
        rng2 = np.random.default_rng(27)
        out = []
        for i in range(20):
            fam = families[i % 3]
            tail = rng2.integers(0, 64, size=(int(
                rng2.integers(1, 8)),)).astype(np.int32)
            out.append(Request(
                rid=i, prompt=np.concatenate([fam, tail]),
                max_new_tokens=int(rng2.integers(2, 10)),
                slo="interactive" if i % 4 == 0 else "batch",
                deadline=0.02 if i % 4 == 0 else 30.0))
        return out

    ref = {r.rid: _solo(params, cfg, r) for r in mk()}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=3, cache_len=64,
                              policy="edf", aging=0.01, preempt=True,
                              kv_pages=16, kv_page_len=8,
                              kv_host_pages=12, kv_share=True))
    for r in mk():
        assert sched.submit(r)
    eng = sched.shards[0]
    done = []
    while sched.has_work():
        done.extend(sched.step())
        eng.pool.alloc.check()
    assert {r.rid: r.out_tokens for r in done} == ref
    mem = eng.memory_stats()
    assert mem.prefix_hits > 0
    assert mem.device_used == mem.cached_pages and mem.host_used == 0


# ---------------------------------------------------------------------------
# Cross-request dedup sweep (ROADMAP item 1 leftover)
# ---------------------------------------------------------------------------


def test_dedup_sweep_relinks_simultaneous_duplicates():
    """Two same-prompt requests admitted before either registered (the
    one-bucket-group race): both hold private copies of identical
    pages. The sweep must re-link the later request onto the canonical
    pages (refcount merge) and free its duplicates — and a COW write
    must stale the content key so the sweep never re-links a page that
    has diverged."""
    keys = [b"k0", b"k1"]
    a = PageAllocator(range(8), host_slots=0, watermark_cap=8,
                      slot_pages=4, share=True)
    assert a.admit_prefix(0, 3, keys)[0]
    assert a.admit_prefix(1, 3, keys)[2] == 0   # race: nothing matched
    a.register_prefix(0, keys)                  # canonical (first wins)
    a.register_prefix(1, keys)                  # nodes taken: no-op
    dup = [e[1] for e in a.tables[1][:2]]
    canon = [e[1] for e in a.tables[0][:2]]
    free_before = len(a.free_dev)
    assert a.dedup_sweep() == 2
    assert a.dedup_merges == 2
    assert [e[1] for e in a.tables[1][:2]] == canon
    assert all(a.rc[p] == 2 for p in canon)
    assert len(a.free_dev) == free_before + 2   # duplicates freed
    assert all(p in a.free_dev for p in dup)
    a.check()
    assert a.dedup_sweep() == 0                 # idempotent
    # COW on rid 1's shared page 0: the fresh copy's content will
    # diverge, so its key is staled and the sweep must leave it alone
    ok, _, copy = a.make_writable(1, 0)
    assert ok and copy is not None
    assert a.dedup_sweep() == 0
    a.check()
    a.free(0)
    a.free(1)
    a.check()


def test_dedup_sweep_promotes_cached_canonical_and_repairs_holes():
    """Sweep vs the page cache: (1) when the canonical twin went
    cached (owner freed), re-linking must promote it back to owned;
    (2) when eviction left a hole in the radix, a resident duplicate
    repairs it so later admissions share again."""
    keys = [b"k0", b"k1"]
    a = PageAllocator(range(8), host_slots=0, watermark_cap=8,
                      slot_pages=4, share=True)
    assert a.admit_prefix(0, 3, keys)[0]
    assert a.admit_prefix(1, 3, keys)[2] == 0
    a.register_prefix(0, keys)
    a.register_prefix(1, keys)
    canon = [e[1] for e in a.tables[0][:2]]
    a.free(0)                                   # canonical turns cached
    assert sorted(a.cached) == sorted(canon)
    assert a.dedup_sweep() == 2
    assert [e[1] for e in a.tables[1][:2]] == canon
    assert not a.cached and all(a.rc[p] == 1 for p in canon)
    a.check()
    # hole repair: strip the index, then sweep republishes rid 1's
    # (still byte-identical) pages so a newcomer matches them
    while a.cached:
        a._evict_cached_lru()
    for p in list(a._node_of):
        a._unregister(p)
    assert a.dedup_sweep() == 0                 # no merges, just repair
    assert all(p in a._node_of for p in canon)
    assert a.admit_prefix(2, 3, keys)[2] == 2   # newcomer shares again
    a.check()


def test_engine_dedup_sweep_bit_identical_and_frees_duplicates():
    """Engine-level dedup (kv_dedup_every=1): two identical prompts
    admitted in the SAME bucket group miss admission-time sharing; the
    sweep merges their prompt pages mid-decode and the streams still
    equal both the sharing-off engine and the solo reference."""
    cfg, params = _setup()
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, 64, size=(20,)).astype(np.int32)
    mk = lambda: [Request(rid=i, prompt=prompt.copy(),
                          max_new_tokens=6) for i in range(2)]
    solo = _solo(params, cfg, mk()[0])
    off = _drive(_share_engine(params, cfg, False), mk())
    eng = _share_engine(params, cfg, True, kv_dedup_every=1)
    on = _drive(eng, mk())
    assert on == off == {0: solo, 1: solo}
    mem = eng.memory_stats()
    # 20-token prompt = 2 full pages at page_len 8, re-linked for the
    # second admission of the pair
    assert mem.dedup_merges == 2, mem.as_dict()
    eng.pool.alloc.check()
