"""End-to-end behaviour tests for the paper's system: the full
train → prune (schedule) → deploy (BSR/int8) → QoS-check loop on a tiny
model, plus the headline qualitative claims on live (not cached) runs."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SASPConfig, get_config, reduced
from repro.core.pruning import compute_sasp_masks, \
    cubic_sparsity_schedule, prune_params
from repro.core.sasp import (
    bsr_overlay_from_masks,
    build_sasp_overlay,
    merge_overlay,
    quantize_params,
)
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import lm
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

VOCAB, SEQ, BATCH, NOISE = 32, 32, 8, 2.0


def _shift(b):
    """lm.loss_fn is next-token CE (logits[t] -> token[t+1]); shifting
    the acoustic features left by one aligns it with the per-position
    transcription task (feature of token[t+1] arrives at position t)."""
    e = np.roll(b["embeds"], -1, axis=1)
    return {"tokens": jnp.asarray(b["tokens"]), "embeds": jnp.asarray(e)}


def _cfg():
    c = reduced(get_config("paper-espnet2-mt"), layers=2, d_model=64,
                vocab=VOCAB)
    return dataclasses.replace(
        c, sasp=SASPConfig(enabled=True, block_k=8, block_n=8,
                           sparsity=0.3))


def _train(cfg, steps=120, sasp_from=None):
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=SEQ, global_batch=BATCH)
    pipe = Pipeline(dcfg, kind="asr", d_model=cfg.d_model, noise=NOISE)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=2e-3)
    opt = adamw_init(params, opt_cfg)
    overlay = None
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    for i in range(steps):
        if sasp_from is not None and i >= sasp_from and overlay is None:
            sasp = dataclasses.replace(cfg.sasp, sparsity=0.3)
            overlay, _ = build_sasp_overlay(params, sasp)
            step = jax.jit(make_train_step(cfg, opt_cfg,
                                           overlay=overlay))
        b = _shift(pipe.next())
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    return params, losses, overlay


def _ter(params, cfg, overlay=None, n=3):
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=SEQ, global_batch=BATCH,
                      seed=77)
    pipe = Pipeline(dcfg, kind="asr", d_model=cfg.d_model, noise=NOISE)
    pv = merge_overlay(params, overlay) if overlay is not None else params
    errs = tot = 0
    for _ in range(n):
        b = _shift(pipe.next())
        logits = lm.forward(pv, cfg, b["tokens"], embeds=b["embeds"])
        pred = np.asarray(jnp.argmax(logits, -1))[:, :-1]
        tgt = np.asarray(b["tokens"])[:, 1:]
        errs += int((pred != tgt).sum())
        tot += tgt.size
    return errs / tot


@pytest.mark.slow
def test_full_sasp_lifecycle():
    """Train dense -> prune mid-training (straight-through) -> deploy to
    BSR + INT8 -> QoS within budget and deployment paths agree."""
    cfg = _cfg()
    params, losses, overlay = _train(cfg, steps=140, sasp_from=70)
    assert losses[-1] < losses[0] * 0.5, "did not learn"
    assert overlay is not None

    ter_pruned = _ter(params, cfg, overlay)
    assert ter_pruned < 0.30, f"pruned TER too high: {ter_pruned}"

    sasp = dataclasses.replace(cfg.sasp, sparsity=0.3)
    masks = compute_sasp_masks(params, sasp)
    baked, _ = prune_params(params, sasp)
    bsr = bsr_overlay_from_masks(params, masks, sasp)
    cfg_bsr = dataclasses.replace(
        cfg, sasp=dataclasses.replace(sasp, path="bsr"))
    dcfg = DataConfig(vocab_size=VOCAB, seq_len=SEQ, global_batch=BATCH,
                      seed=77)
    b = Pipeline(dcfg, kind="asr", d_model=cfg.d_model,
                 noise=NOISE).next()
    b = _shift(b)
    l_masked = lm.forward(baked, cfg, b["tokens"], embeds=b["embeds"])
    l_bsr = lm.forward(merge_overlay(params, bsr), cfg_bsr,
                       b["tokens"], embeds=b["embeds"])
    np.testing.assert_allclose(np.asarray(l_masked), np.asarray(l_bsr),
                               rtol=2e-3, atol=2e-3)

    pq = quantize_params(baked, sasp)
    l_q = lm.forward(pq, cfg, b["tokens"], embeds=b["embeds"])
    denom = float(jnp.abs(l_masked).max())
    assert float(jnp.abs(l_q - l_masked).max()) / denom < 0.05


@pytest.mark.slow
def test_large_tile_brittleness_live():
    """Live (uncached) check of paper §4.4 on a freshly trained model:
    at a fixed rate, bigger tiles hurt at least as much."""
    cfg = _cfg()
    params, losses, _ = _train(cfg, steps=120)
    ters = {}
    for tile in (4, 16):
        sasp = SASPConfig(enabled=True, block_k=tile, block_n=tile,
                          sparsity=0.5)
        overlay, _ = build_sasp_overlay(params, sasp)
        ters[tile] = _ter(params, cfg, overlay)
    base = _ter(params, cfg)
    assert ters[4] >= base - 1e-9
    assert ters[16] >= ters[4] - 0.02, (base, ters)


def test_cubic_schedule_reaches_target():
    xs = [cubic_sparsity_schedule(i, start_step=10, end_step=50,
                                  final_sparsity=0.4) for i in range(60)]
    assert xs[9] == 0.0 and abs(xs[-1] - 0.4) < 1e-9
    assert all(b >= a - 1e-12 for a, b in zip(xs, xs[1:]))
