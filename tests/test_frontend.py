"""Fault-tolerant cluster frontend (DESIGN.md §14): heartbeat health
ladder, idempotent retry with backoff after in-process and kill -9 host
deaths (greedy streams bit-identical to an undisturbed single-host
run, zero duplicate-streamed tokens), watchdog timeouts, graceful
drain, revive + replay, and a seeded/property chaos harness over
random kill/revive schedules."""
import json
import os
import sys

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve.chaos import ChaosConfig, ChaosMonkey, parse_chaos_spec
from repro.serve.engine import Engine, Request
from repro.serve.frontend import ClusterFrontend, FrontendConfig, \
    SubprocessHost, make_local_hosts
from repro.serve.scheduler import SchedulerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)
from tools.analyze import check_page_refcounts  # noqa: E402

KEY = jax.random.PRNGKey(0)
SCHED = SchedulerConfig(slots_per_rank=2, cache_len=64)
# paged + prefix-sharing variant: host death with shared (refcounted)
# pages in flight must never strand a refcount (DESIGN.md §16)
SCHED_SHARE = SchedulerConfig(slots_per_rank=2, cache_len=64,
                              kv_pages=12, kv_page_len=8,
                              kv_host_pages=8, kv_share=True)
WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def setup():
    """Shared model + per-request solo reference streams (the
    bit-identity oracle: one request alone on one undisturbed
    single-batch engine)."""
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    params = jax.tree.map(lambda a: a * 3.0, params)   # see test_scheduler
    rng = np.random.default_rng(0)
    specs = [(rng.integers(0, 64, size=(5 + 3 * i,)).astype(np.int32),
              4 + (3 * i) % 5) for i in range(8)]
    solo_eng = Engine(params, cfg, batch_slots=1, cache_len=64)
    solo = {i: solo_eng.run([Request(rid=i, prompt=p, max_new_tokens=m)]
                            )[0].out_tokens
            for i, (p, m) in enumerate(specs)}
    return cfg, params, specs, solo


def _mk(specs, idx=None, rid_base=0):
    idx = range(len(specs)) if idx is None else idx
    return [Request(rid=rid_base + i, prompt=specs[i][0],
                    max_new_tokens=specs[i][1]) for i in idx]


def _collector(delivered):
    return lambda req, tok: delivered.setdefault(req.rid, []).append(tok)


# ----------------------------------------------------------------------
# chaos harness itself
# ----------------------------------------------------------------------
def test_parse_chaos_spec_grammar():
    cfg = parse_chaos_spec("kill:0@12, raise:1@3,drop-hb:0@5x3,"
                           "slow:1@0.02,seed:7")
    assert cfg.kill_at_step == {0: 12}
    assert cfg.raise_in_decode == {1: 3}
    assert cfg.drop_heartbeat == {0: (5, 3)}
    assert cfg.slow_host == {1: 0.02}
    assert cfg.seed == 7
    assert parse_chaos_spec("drop-hb:2@4").drop_heartbeat == {2: (4, -1)}
    assert parse_chaos_spec("").kill_at_step == {}
    with pytest.raises(ValueError, match="grammar"):
        parse_chaos_spec("explode:0@1")
    with pytest.raises(ValueError, match="grammar"):
        parse_chaos_spec("kill:0@soon")


def test_chaos_monkey_hooks_fire_deterministically():
    m = ChaosMonkey(ChaosConfig(seed=3, kill_at_step={0: 5},
                                raise_in_decode={1: 2},
                                drop_heartbeat={0: (3, 2)},
                                slow_host={1: 0.5}))
    assert not m.kill_due(0, 4) and not m.kill_due(1, 99)
    assert m.kill_due(0, 5)
    assert not m.kill_due(0, 6)                 # one-shot
    assert m.decode_raise_due(1, 7)             # late host still raises
    assert not m.decode_raise_due(1, 8)
    assert [m.heartbeat_dropped(0, s) for s in range(1, 7)] == \
        [False, False, True, True, False, False]
    assert m.delay_s(1) == 0.5 and m.delay_s(0) == 0.0
    # the seeded RNG is reproducible schedule-wide
    assert ChaosMonkey(ChaosConfig(seed=3)).rng.random() == \
        ChaosMonkey(ChaosConfig(seed=3)).rng.random()


# ----------------------------------------------------------------------
# host death -> retry -> exact resume
# ----------------------------------------------------------------------
def test_kill_host_mid_load_bit_identical(setup):
    """The tentpole acceptance (in-process half): a host hard-dies
    mid-load; every request resolves, no token streams twice, and every
    greedy stream — including the ones resumed on the surviving host —
    is bit-identical to the undisturbed solo run."""
    cfg, params, specs, solo = setup
    chaos = ChaosMonkey(ChaosConfig(kill_at_step={0: 3}))
    hosts = make_local_hosts(params, cfg, hosts=2, sched=SCHED,
                             chaos=chaos)
    delivered = {}
    fe = ClusterFrontend(
        hosts, FrontendConfig(retries=2, backoff_base=0.001, rng_seed=1),
        on_token=_collector(delivered))
    reqs = _mk(specs)
    completed = fe.run(reqs)
    assert hosts[0].killed and fe._state(0) == "dead"
    assert not fe.failed and not fe.rejected
    assert {r.rid: r.out_tokens for r in completed} == solo
    assert delivered == solo            # exactly once, in order
    assert fe.n_retries >= 1
    st = fe.stats()
    assert st["dead"] == 1 and st["done"] == len(reqs)
    assert st["unresolved"] == 0


def test_step_failure_escalates_and_retries_elsewhere(setup):
    """A decode raise that kills a single-rank host's only shard is a
    HOST-level failure (no sibling rank to requeue to): the scheduler's
    terminal failures surface through the host's step, and the frontend
    re-submits them to the other host with the stream resuming
    exactly."""
    cfg, params, specs, solo = setup
    chaos = ChaosMonkey(ChaosConfig(raise_in_decode={0: 2}))
    hosts = make_local_hosts(params, cfg, hosts=2, sched=SCHED,
                             chaos=chaos)
    delivered = {}
    fe = ClusterFrontend(
        hosts, FrontendConfig(retries=2, backoff_base=0.001),
        on_token=_collector(delivered))
    completed = fe.run(_mk(specs, range(6)))
    assert {r.rid: r.out_tokens for r in completed} == \
        {i: solo[i] for i in range(6)}
    assert delivered == {i: solo[i] for i in range(6)}
    assert not fe.failed and fe.n_retries >= 1
    assert hosts[0].sched.shards[0].dead      # the rank really died
    assert fe._state(0) == "dead"


def test_suspect_host_recovers_without_losing_its_work(setup):
    """Dropped heartbeats below ``dead_after`` make a host suspect (no
    new routing) but never evacuate it: it keeps serving what it holds,
    answers again, and returns to healthy — zero retries burned."""
    cfg, params, specs, solo = setup
    chaos = ChaosMonkey(ChaosConfig(drop_heartbeat={0: (2, 2)}))
    hosts = make_local_hosts(params, cfg, hosts=2, sched=SCHED,
                             chaos=chaos)
    fe = ClusterFrontend(hosts, FrontendConfig(suspect_after=1,
                                               dead_after=3))
    states = []
    completed = fe.run(_mk(specs, range(6)),
                       on_tick=lambda t: states.append(fe._state(0)))
    assert {r.rid: r.out_tokens for r in completed} == \
        {i: solo[i] for i in range(6)}
    assert "suspect" in states and "dead" not in states
    assert fe._state(0) == "healthy"
    assert fe.n_retries == 0
    assert hosts[0].sched.stats()["accepted"] > 0   # it did real work


def test_watchdog_fails_hung_request_without_stalling_others(setup):
    """A request that cannot finish inside its wall-clock budget (its
    host is a chaos straggler) is cancelled out of its slot and failed;
    requests on the other host complete bit-identically and the loop
    never wedges."""
    cfg, params, specs, solo = setup
    # a (mild) straggler host exercises the slow-host chaos hook; the
    # hang itself comes from a decode budget no wall clock can cover
    chaos = ChaosMonkey(ChaosConfig(slow_host={0: 0.002}))
    hosts = make_local_hosts(params, cfg, hosts=2, sched=SCHED,
                             chaos=chaos)
    rng = np.random.default_rng(9)
    hung = Request(rid=100,
                   prompt=rng.integers(0, 64, size=(8,)).astype(np.int32),
                   max_new_tokens=10_000)
    # the timeout must outlast jit warm-up (which counts against every
    # request's clock) but cut the hung request long before its budget
    fe = ClusterFrontend(hosts, FrontendConfig(request_timeout=8.0,
                                               retries=1,
                                               backoff_base=0.001))
    # hung first: it routes to (empty) host 0, whose huge outstanding
    # cost then steers everything else to host 1
    completed = fe.run([hung] + _mk(specs, range(4)))
    assert {r.rid: r.out_tokens for r in completed} == \
        {i: solo[i] for i in range(4)}
    assert fe.failed == [hung]
    assert "watchdog" in hung.error and hung.status == "failed"
    assert not fe.trackers[100].replayable    # a revive must not redo it
    assert 0 < len(hung.out_tokens) < 10_000  # genuinely cut mid-decode
    assert hosts[1].sched.stats()["accepted"] == 4
    assert not hosts[0].sched.has_work()      # cancel freed the slot


def test_graceful_drain_under_load_and_expiry(setup):
    cfg, params, specs, solo = setup
    hosts = make_local_hosts(params, cfg, hosts=2, sched=SCHED)
    fe = ClusterFrontend(hosts, FrontendConfig(drain_timeout=120.0))
    reqs = _mk(specs)
    for r in reqs:
        assert fe.submit(r)
    fe.step()
    fe.step()                           # work genuinely in flight
    completed, clean = fe.drain()
    assert clean and not fe.unresolved()
    assert {r.rid: r.out_tokens for r in fe.done} == solo
    late = Request(rid=99, prompt=specs[0][0], max_new_tokens=4)
    assert not fe.submit(late)          # admission is closed
    assert late.status == "rejected" and late in fe.rejected

    # expiry: a deadline of 0 cuts everything still unresolved — each
    # request still resolves exactly once, cancelled out of its host
    fe2 = ClusterFrontend(hosts, FrontendConfig())
    reqs2 = _mk(specs, range(4), rid_base=200)
    for r in reqs2:
        assert fe2.submit(r)
    fe2.step()
    completed2, clean2 = fe2.drain(timeout=0.0)
    assert not clean2 and not fe2.unresolved()
    assert len(fe2.done) + len(fe2.failed) == 4
    assert all("drain timeout" in r.error for r in fe2.failed)
    assert not hosts[0].sched.has_work() and not hosts[1].sched.has_work()


def test_revive_host_replays_retryable_failures(setup):
    """Total outage: the only host's only rank dies, every request
    fails retryably; ``revive_host`` rebuilds the rank (stats
    continuous across the outage) and replays the failures — streams
    complete bit-identically to the undisturbed run."""
    cfg, params, specs, solo = setup
    chaos = ChaosMonkey(ChaosConfig(raise_in_decode={0: 2}))
    hosts = make_local_hosts(params, cfg, hosts=1, sched=SCHED,
                             chaos=chaos)
    delivered = {}
    fe = ClusterFrontend(
        hosts, FrontendConfig(retries=1, backoff_base=0.001),
        on_token=_collector(delivered))
    completed = fe.run(_mk(specs, range(4)))
    assert not completed
    assert len(fe.failed) == 4
    assert all(fe.trackers[r.rid].replayable for r in fe.failed)
    assert fe._state(0) == "dead"

    fe.revive_host(0)
    assert fe._state(0) == "healthy" and not fe.failed
    eng = hosts[0].sched.shards[0]
    assert not eng.dead and eng.stats["deaths"] == 1    # carried over
    completed = fe.run([])              # serve the replayed backlog
    assert {r.rid: r.out_tokens for r in completed} == \
        {i: solo[i] for i in range(4)}
    assert delivered == {i: solo[i] for i in range(4)}  # no index twice
    assert eng.stats["admitted"] >= 4
    assert fe.stats()["done"] == 4 and fe.stats()["failed"] == 0


# ----------------------------------------------------------------------
# property harness: random kill/revive schedules
# ----------------------------------------------------------------------
def _assert_pool_refcounts(fe):
    """tools.analyze.check_page_refcounts over every live paged shard:
    no leaked page, no double-free, refcount == table references,
    watermark held — checked after every kill/revive cycle so a host
    death with shared pages in flight cannot strand refcounts."""
    for h in fe.hosts:
        sched = getattr(h, "sched", None)
        if sched is None:
            continue
        for eng in sched.shards:
            if eng.dead or getattr(eng, "pool", None) is None:
                continue
            errs = check_page_refcounts(eng.pool)
            assert not errs, (h.host_id, eng.rank, errs)


def _run_schedule(setup, schedule, n_reqs=5, sched=SCHED):
    """Drive a frontend under a {tick: [(op, host), ...]} schedule and
    assert the two global invariants: every request resolves exactly
    once, and no token index is ever streamed twice (delivered streams
    are exact prefixes of the solo oracle). Paged configs additionally
    get the refcount invariant check after every kill/revive cycle."""
    cfg, params, specs, solo = setup
    hosts = make_local_hosts(params, cfg, hosts=2, sched=sched)
    delivered = {}
    fe = ClusterFrontend(
        hosts, FrontendConfig(retries=3, backoff_base=0.001, rng_seed=7),
        on_token=_collector(delivered))

    def on_tick(t):
        cycled = False
        for op, h in schedule.get(t, []):
            if op == "kill":
                fe.hosts[h].killed = True
                cycled = True
            elif op == "revive" and fe._state(h) == "dead":
                fe.revive_host(h)
                cycled = True
        if cycled:
            _assert_pool_refcounts(fe)

    fe.run(_mk(specs, range(n_reqs)), on_tick=on_tick)
    _assert_pool_refcounts(fe)
    # exactly-once resolution
    resolved = fe.done + fe.failed + fe.rejected
    assert len(resolved) == n_reqs
    assert {r.rid for r in resolved} == set(range(n_reqs))
    assert all(t.outcome in ("done", "failed", "rejected")
               for t in fe.trackers.values())
    # exactly-once delivery, bit-exact against the solo oracle
    for rid, toks in delivered.items():
        assert toks == solo[rid][:len(toks)]
    for r in fe.done:
        assert r.out_tokens == solo[r.rid]
        assert delivered[r.rid] == solo[r.rid]
    for r in fe.failed:
        assert r.error
    return fe


def test_chaos_schedules_fixed_twin(setup):
    """Always-on twin of the hypothesis sweep: one plain kill, and a
    kill/revive/kill sequence that ends with only the revived host."""
    fe = _run_schedule(setup, {2: [("kill", 0)]})
    assert fe.n_retries >= 1 and not fe.failed
    fe = _run_schedule(setup, {1: [("kill", 1)], 4: [("revive", 1)],
                               6: [("kill", 0)]})
    assert fe.n_retries >= 1 and not fe.failed


def test_chaos_schedules_paged_share_fixed_twin(setup):
    """The same kill/revive schedules over paged engines with prefix
    sharing on: streams stay bit-exact and ``check_page_refcounts``
    holds after every cycle (no refcount stranded by a host death)."""
    fe = _run_schedule(setup, {2: [("kill", 0)]}, sched=SCHED_SHARE)
    assert fe.n_retries >= 1 and not fe.failed
    fe = _run_schedule(setup, {1: [("kill", 1)], 4: [("revive", 1)],
                               6: [("kill", 0)]}, sched=SCHED_SHARE)
    assert fe.n_retries >= 1 and not fe.failed


def test_chaos_kill_with_shared_fanout_in_flight(setup):
    """Fan-out of one prompt with sharing on, host 0 killed while the
    forked (refcounted, possibly copy-on-written) pages are in flight:
    every request resolves with the solo-oracle stream, the survivor's
    pool passes the refcount check, and nothing leaks on drain."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, 64, size=(19,)).astype(np.int32)
    solo_eng = Engine(params, cfg, batch_slots=1, cache_len=64)
    solo = solo_eng.run([Request(rid=0, prompt=prompt.copy(),
                                 max_new_tokens=8)])[0].out_tokens
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new_tokens=8)
            for i in range(6)]
    hosts = make_local_hosts(params, cfg, hosts=2, sched=SCHED_SHARE)
    delivered = {}
    fe = ClusterFrontend(
        hosts, FrontendConfig(retries=3, backoff_base=0.001, rng_seed=7),
        on_token=_collector(delivered))

    def on_tick(t):
        if t == 3 and not fe.hosts[0].killed:
            fe.hosts[0].killed = True
            _assert_pool_refcounts(fe)

    done = fe.run(reqs, on_tick=on_tick)
    _assert_pool_refcounts(fe)
    assert not fe.failed and not fe.rejected
    assert {r.rid: r.out_tokens for r in done} == {i: solo
                                                   for i in range(6)}
    mem = hosts[1].sched.shards[0].memory_stats()
    assert mem.device_used == mem.cached_pages  # drained to cache only


@pytest.mark.slow
def test_chaos_schedules_property(setup):
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=5, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(st.lists(st.tuples(st.integers(0, 10),
                              st.sampled_from(["kill", "revive"]),
                              st.integers(0, 1)), max_size=4))
    def inner(ops):
        schedule = {}
        for tick, op, host in ops:
            schedule.setdefault(tick, []).append((op, host))
        _run_schedule(setup, schedule, n_reqs=4)

    inner()


# ----------------------------------------------------------------------
# subprocess hosts: real kill -9
# ----------------------------------------------------------------------
def _worker_cmd(seed):
    return [sys.executable, WORKER, "frontend_host",
            json.dumps({"seed": seed})]


@pytest.mark.slow
def test_kill9_subprocess_host_mid_load(setup):
    """The tentpole acceptance (OS half): SIGKILL a real worker process
    mid-load. Reference = the same worker stack, one undisturbed host.
    Every request resolves, streams and per-token delivery are
    bit-identical, nothing double-streams."""
    cfg, params, specs, solo = setup
    ref_fe = ClusterFrontend([SubprocessHost(0, _worker_cmd(0))],
                             FrontendConfig())
    ref_done = ref_fe.run(_mk(specs, range(6)))
    ref = {r.rid: r.out_tokens for r in ref_done}
    ref_fe.close()
    assert len(ref) == 6

    hosts = [SubprocessHost(0, _worker_cmd(0)),
             SubprocessHost(1, _worker_cmd(1))]
    delivered = {}
    fe = ClusterFrontend(
        hosts, FrontendConfig(retries=2, backoff_base=0.001),
        on_token=_collector(delivered))
    killed = []

    def on_tick(t):
        if t == 3 and not killed:
            # the victim must actually hold in-flight work (mid-load)
            assert any(tr.host_id == 0 for tr in fe.unresolved())
            hosts[0].kill()
            killed.append(t)

    try:
        completed = fe.run(_mk(specs, range(6)), on_tick=on_tick)
    finally:
        fe.close()
    got = {r.rid: r.out_tokens for r in completed}
    assert killed and not hosts[0].alive and fe._state(0) == "dead"
    assert got == ref
    assert delivered == ref             # zero duplicate-streamed tokens
    assert not fe.failed and not fe.rejected
    assert fe.n_retries >= 1
    # the in-process oracle and the worker stack agree bit-for-bit
    assert got == {i: solo[i] for i in range(6)}
