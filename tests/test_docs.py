"""Docs freshness runs inside tier-1 too, so a stale DESIGN.md section
list or a dangling README link fails locally before CI."""
import os
import sys

TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, os.path.abspath(TOOLS))

import check_docs  # noqa: E402


def test_design_sections_match_manifest():
    import json
    with open(check_docs.MANIFEST, encoding="utf-8") as f:
        manifest = json.load(f)
    assert check_docs.check_sections(manifest) == []


def test_readme_and_design_links_resolve():
    import json
    with open(check_docs.MANIFEST, encoding="utf-8") as f:
        manifest = json.load(f)
    assert check_docs.check_links(manifest) == []


def test_checker_detects_drift(tmp_path):
    """The checker itself must actually fire on a stale manifest (guards
    against a regex rotting into match-nothing)."""
    manifest = {"DESIGN.md": {"sections": ["§1 Overview"]}}
    errs = check_docs.check_sections(manifest)
    assert errs and "docs_manifest" in errs[0]
