"""The shard_map compat shim (distribution/context.py) must resolve AND
execute on every supported JAX. This runs in-process over a 1-device
mesh — no subprocess, no ``slow`` marker — so the min-JAX CI job
(``-m "not slow"`` on 0.4.x) exercises the check_rep ↔ check_vma kwarg
mapping at call time, not just at import. The multi-device semantics are
covered by tests/test_distribution.py (slow)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distribution import context as dctx


def test_shim_resolves_known_kwarg():
    assert dctx._CHECK_KW in ("check_rep", "check_vma")
    assert callable(dctx._SHARD_MAP_IMPL)


def test_shim_executes_on_current_jax():
    """Calling through the shim must construct the underlying shard_map
    with the right replication-check kwarg — a wrong kwarg raises at
    this call, which is exactly the drift the min-JAX job watches."""
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.arange(8.0, dtype=jnp.float32).reshape(2, 4)

    def body(xx):
        return jax.lax.psum(xx, "model")

    fn = dctx.shard_map(body, mesh=mesh, in_specs=P(None, None),
                        out_specs=P(None, None))
    np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(x)),
                                  np.asarray(x))
