"""Clean twin of lock_bad.py: every shared access guarded, foreign
state reached through an owner method, hierarchy respected — zero
findings under the same fixture spec."""
import threading


class Peer:
    def __init__(self):
        self._lock = threading.Lock()
        self.inbox = []

    def push(self, item):
        with self._lock:
            self.inbox.append(item)


class Worker:
    def __init__(self, peer):
        self._lock = threading.Lock()
        self.peer = peer
        self.count = 0

    def increment(self):
        with self._lock:
            self.count += 1

    def forward(self, item):
        self.peer.push(item)            # owner method takes Peer._lock
