"""Clean twin of recompile_bad.py: closures bound through partial,
static arguments hashable — zero findings."""
from functools import partial

import jax


def _step(x, scale):
    return x * scale


class Runner:
    def __init__(self, scale):
        self.scale = scale

    def make_step(self):
        # scale is pinned as an explicit partial argument at build
        # time — the cache key is honest about it
        return jax.jit(partial(_step, scale=self.scale))


def good_static_call(f, x):
    g = jax.jit(f, static_argnums=(1,))
    return g(x, (1, 2, 3))              # tuple: hashable static
