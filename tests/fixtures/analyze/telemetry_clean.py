"""Clean twin for the telemetry pass: every constant stats key written
here is declared in repro.serve.telemetry.DECLARED_STATS."""


class FakeEngine:
    def __init__(self, stats):
        self.stats = stats

    def step(self):
        self.stats["admitted"] += 1
        self.stats["generated_tokens"] += 4
        self.stats["memory"] = {"pages": 0}
