"""Seeded trace-safety violations: each jit-reachable function below
carries exactly one deliberate host/trace confusion.  Scanned only by
tests/test_analyze.py (EXCLUDE_PARTS keeps it out of repo runs)."""
import time

import jax


def branches_on_traced(x, n):
    if x > 0:                           # TRACE-BRANCH: traced test
        return x + n
    return x - n


def coerces_traced(x):
    return float(x) * 2.0               # TRACE-COERCE: host coercion


def host_callback(x):
    t = time.time()                     # TRACE-HOSTCALL: wall clock
    return x + t


branches_j = jax.jit(branches_on_traced)
coerces_j = jax.jit(coerces_traced)
hostcall_j = jax.jit(host_callback)
