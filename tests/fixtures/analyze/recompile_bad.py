"""Seeded jit-cache-key hazards (recompile pass AST rules)."""
import jax


class Runner:
    def __init__(self, scale):
        self.scale = scale

    def make_step(self):
        # JIT-CLOSURE: the jitted lambda closes over mutable instance
        # state — rebinding self.scale silently recompiles (or worse,
        # does NOT retrace and serves the stale constant).
        return jax.jit(lambda x: x * self.scale)


def bad_static_call(f, x):
    # JIT-STATIC-UNHASHABLE: a list literal at a static position is
    # unhashable — every call raises (or defeats the cache if it were
    # hashed by identity).
    g = jax.jit(f, static_argnums=(1,))
    return g(x, [1, 2, 3])
