"""Clean twin of trace_bad.py: the same shapes of logic written
trace-safely — static-attribute branching, jnp.where selects, static
arguments — must produce ZERO findings."""
import jax
import jax.numpy as jnp


def branches_on_shape(x, n):
    if x.shape[0] > 1:                  # shape is static: fine
        return x + n
    return x - n


def selects_traced(x):
    return jnp.where(x > 0, x * 2.0, x)     # traced select: fine


def static_branch(x, flag):
    if flag:                            # flag is a static argument
        return x * 2
    return x


branches_j = jax.jit(branches_on_shape)
selects_j = jax.jit(selects_traced)
static_j = jax.jit(static_branch, static_argnames=("flag",))
