"""Seeded shim violation: direct shard_map use outside
distribution/context.py (SHIM-IMPORT)."""
from jax.experimental import shard_map


def run_sharded(f, mesh, in_specs, out_specs):
    return shard_map.shard_map(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs)
