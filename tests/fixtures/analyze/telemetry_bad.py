"""Seeded TELEMETRY-DECLARED violations: stats keys written but never
declared in repro.serve.telemetry.DECLARED_STATS."""


class FakeEngine:
    def __init__(self, stats):
        self.stats = stats

    def step(self):
        # undeclared key via augmented assignment
        self.stats["bogus_counter"] += 1
        # undeclared key via plain assignment
        self.stats["mystery_gauge"] = 42
        # declared key — must NOT be flagged
        self.stats["admitted"] += 1
        # dynamic key — out of scope for the lint
        k = "computed"
        self.stats[k] = 1
