"""Seeded concurrency violations, checked against the fixture lock
spec in tests/test_analyze.py: LOCK-UNHELD (off-lock counter) and
LOCK-ORDER (acquisition against the declared hierarchy)."""
import threading


class Peer:
    def __init__(self):
        self._lock = threading.Lock()
        self.inbox = []

    def push(self, item):
        with self._lock:
            self.inbox.append(item)


class Worker:
    def __init__(self, peer):
        self._lock = threading.Lock()
        self.peer = peer
        self.count = 0

    def increment(self):
        self.count += 1                 # LOCK-UNHELD: off-lock write

    def forward(self, item):
        # LOCK-ORDER: declared hierarchy is Peer then Worker, but this
        # acquires Peer._lock while already holding Worker._lock
        with self._lock:
            with self.peer._lock:
                self.peer.inbox.append(item)
