"""Clean twin of shim_bad.py: shard_map reached through the
distribution.context shim — zero findings."""
from repro.distribution import context as dctx


def run_sharded(f, mesh, in_specs, out_specs):
    return dctx.shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
