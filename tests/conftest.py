"""Shared pytest configuration: per-test wall-clock enforcement.

CI installs ``pytest-timeout``, which owns the ``timeout`` ini key in
pytest.ini (a hung drain or wedged chaos worker must never stall a
whole job). Environments without the plugin get the same cap from the
SIGALRM fallback below — main-thread alarm, POSIX only — so the
guarantee does not silently depend on an optional dependency."""
import signal

import pytest


def _has_timeout_plugin(config) -> bool:
    pm = config.pluginmanager
    return pm.hasplugin("timeout") or pm.hasplugin("pytest_timeout")


def pytest_addoption(parser):
    # claim the ini key only when pytest-timeout has not already done
    # so (double registration raises)
    try:
        parser.addini("timeout", "per-test timeout in seconds "
                      "(SIGALRM fallback when pytest-timeout is absent)")
    except ValueError:
        pass


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if _has_timeout_plugin(item.config) \
            or not hasattr(signal, "SIGALRM"):
        yield
        return
    try:
        limit = float(item.config.getini("timeout") or 0)
    except (TypeError, ValueError):
        limit = 0.0
    mark = item.get_closest_marker("timeout")
    if mark is not None and mark.args:
        limit = float(mark.args[0])
    if limit <= 0:
        yield
        return

    def _alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded {limit:.0f}s "
            "(tests/conftest.py SIGALRM fallback)")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
