"""Shared pytest configuration: per-test wall-clock enforcement.

CI installs ``pytest-timeout``, which owns the ``timeout`` ini key in
pytest.ini (a hung drain or wedged chaos worker must never stall a
whole job). Environments without the plugin get the same cap from the
SIGALRM fallback below — main-thread alarm, POSIX only — so the
guarantee does not silently depend on an optional dependency.

``REPRO_KV_SHARE=1`` (CI's ``share`` matrix leg) force-enables prefix
sharing on every paged engine the suite builds: an autouse fixture
wraps ``Engine.__init__`` so any construction with ``kv_pages`` (and
without int8 KV, which sharing rejects) defaults ``kv_share=True``.
The whole paged test surface then doubles as a sharing bit-identity
oracle — any stream difference is a sharing bug.

``REPRO_SPEC=1`` (CI's ``spec`` matrix leg) does the same for
self-speculative decoding: every paged engine defaults a 75%-sparsity
drafter (interactive requests included), so the paged surface doubles
as a speculation bit-identity oracle — greedy speculative streams
must match sequential decode exactly (DESIGN.md §17).

``REPRO_TRACE=1`` (CI's ``trace`` matrix leg) arms the span tracer on
every engine the suite builds: the serving tests then double as a
telemetry bit-identity oracle — tracing consumes no RNG keys and
forces no device syncs, so any stream difference is a telemetry bug
(DESIGN.md §18)."""
import os
import signal

import pytest


def _has_timeout_plugin(config) -> bool:
    pm = config.pluginmanager
    return pm.hasplugin("timeout") or pm.hasplugin("pytest_timeout")


def pytest_addoption(parser):
    # claim the ini key only when pytest-timeout has not already done
    # so (double registration raises)
    try:
        parser.addini("timeout", "per-test timeout in seconds "
                      "(SIGALRM fallback when pytest-timeout is absent)")
    except ValueError:
        pass


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if _has_timeout_plugin(item.config) \
            or not hasattr(signal, "SIGALRM"):
        yield
        return
    try:
        limit = float(item.config.getini("timeout") or 0)
    except (TypeError, ValueError):
        limit = 0.0
    mark = item.get_closest_marker("timeout")
    if mark is not None and mark.args:
        limit = float(mark.args[0])
    if limit <= 0:
        yield
        return

    def _alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded {limit:.0f}s "
            "(tests/conftest.py SIGALRM fallback)")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _force_kv_share(monkeypatch):
    """CI share leg (REPRO_KV_SHARE=1): default kv_share=True on every
    paged Engine so the existing paged tests re-run as sharing
    oracles. Explicit kv_share arguments and contiguous / int8-KV
    engines are left alone."""
    if os.environ.get("REPRO_KV_SHARE") != "1":
        yield
        return
    from repro.serve.engine import Engine
    orig = Engine.__init__

    def patched(self, params, cfg, *args, **kw):
        if kw.get("kv_pages") and not getattr(cfg, "kv_quant", False):
            kw.setdefault("kv_share", True)
        return orig(self, params, cfg, *args, **kw)

    monkeypatch.setattr(Engine, "__init__", patched)
    yield


@pytest.fixture(autouse=True)
def _force_trace(monkeypatch):
    """CI trace leg (REPRO_TRACE=1): arm the span tracer + metrics on
    every Engine so the serving tests re-run as telemetry bit-identity
    oracles. Tracing is strictly host-side, so streams must be
    unchanged (DESIGN.md §18)."""
    if os.environ.get("REPRO_TRACE") != "1":
        yield
        return
    from repro.serve.engine import Engine
    orig = Engine.__init__

    def patched(self, *args, **kw):
        orig(self, *args, **kw)
        self.telemetry.tracer.enabled = True

    monkeypatch.setattr(Engine, "__init__", patched)
    yield


@pytest.fixture(autouse=True)
def _force_spec_decode(monkeypatch):
    """CI spec leg (REPRO_SPEC=1): default a drafter on every paged
    Engine so the existing paged tests re-run as speculative
    bit-identity oracles. Explicit draft arguments, contiguous
    engines, and int8-KV engines (speculation rejects kv_quant) are
    left alone. draft_interactive defaults on so interactive-SLO
    test requests exercise the draft path too."""
    if os.environ.get("REPRO_SPEC") != "1":
        yield
        return
    from repro.serve.engine import Engine
    orig = Engine.__init__

    def patched(self, params, cfg, *args, **kw):
        if kw.get("kv_pages") and not getattr(cfg, "kv_quant", False):
            kw.setdefault("draft_sparsity", 0.75)
            kw.setdefault("draft_interactive", True)
        return orig(self, params, cfg, *args, **kw)

    monkeypatch.setattr(Engine, "__init__", patched)
    yield
