"""Analytic counter validation vs XLA cost_analysis (on 1-layer configs,
where while-once counting is exact) + HLO collective-parser tests."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo as H
from repro.analysis.counters import step_costs
from repro.configs import ShapeConfig, get_config, reduced
from repro.models import lm


def _one_layer_cfg(arch):
    return dataclasses.replace(
        reduced(get_config(arch), layers=1, d_model=64, vocab=128),
        remat="none")


@pytest.mark.parametrize("arch", ["qwen3-32b", "mamba2-780m",
                                  "musicgen-medium"])
def test_forward_flops_match_xla_on_one_layer(arch):
    """1-layer scan bodies are counted once = exactly once by XLA CPU;
    the analytic forward count must land within 35% (XLA also counts
    softmax/norm elementwise flops that we fold into the GEMM terms)."""
    cfg = _one_layer_cfg(arch)
    B, S = 2, 64
    shape = ShapeConfig("t", "prefill", seq_len=S, global_batch=B)
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    kw = {}
    if cfg.frontend != "none":
        kw["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                            jnp.float32)

    def fwd(t, **kwargs):
        return lm.forward(None if False else _P, cfg, t, **kwargs)

    _P = lm.init_params(jax.random.PRNGKey(0), cfg)
    compiled = jax.jit(lambda t, **k: lm.forward(_P, cfg, t, **k)) \
        .lower(toks, **kw).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0))
    ours = step_costs(cfg, shape).flops_fwd
    assert xla_flops > 0
    ratio = ours / xla_flops
    assert 0.65 < ratio < 1.55, (arch, ours, xla_flops, ratio)


def test_train_multiplier():
    cfg = _one_layer_cfg("qwen3-32b")
    shape_t = ShapeConfig("t", "train", 64, 2)
    shape_p = ShapeConfig("p", "prefill", 64, 2)
    ct = step_costs(cfg, shape_t)
    cp = step_costs(cfg, shape_p)
    assert abs(ct.flops / cp.flops - 3.0) < 1e-6      # remat=none => 3x
    cfg_r = dataclasses.replace(cfg, remat="full")
    assert abs(step_costs(cfg_r, shape_t).flops / cp.flops - 4.0) < 1e-6


def test_decode_kv_bytes_dominate_large_context():
    cfg = dataclasses.replace(get_config("qwen2.5-32b"),
                              compute_dtype="bfloat16")
    shape = ShapeConfig("d", "decode", seq_len=32768, global_batch=128)
    c = step_costs(cfg, shape)
    assert c.kv_bytes / c.bytes_hbm > 0.8              # KV-bound regime
    cfg8 = dataclasses.replace(cfg, kv_quant=True)
    c8 = step_costs(cfg8, shape)
    assert 0.4 < c8.kv_bytes / c.kv_bytes < 0.6        # int8 halves it


def test_sasp_sparsity_scales_ffn_flops():
    cfg = _one_layer_cfg("qwen3-32b")
    shape = ShapeConfig("p", "prefill", 64, 2)
    c0 = step_costs(cfg, shape)
    c5 = step_costs(cfg, shape, sparsity=0.5)
    assert abs((c0.detail["ffn"] - c5.detail["ffn"]) /
               c0.detail["ffn"] - 0.5) < 1e-6


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
HloModule jit_step

%wide.cond (a: s32[]) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(s32[] %p, s32[] %c), direction=LT
}

%loop_body (x: f32[4,8]) -> f32[4,8] {
  %ar = f32[4,8]{1,0} all-reduce(f32[4,8] %x), replica_groups={}
  ROOT %r = f32[4,8]{1,0} add(%ar, %ar)
}

ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %ag = bf16[16,32]{1,0} all-gather(bf16[2,32] %q), dimensions={0}
  %w = f32[4,8]{1,0} while(f32[4,8] %p0), condition=%wide.cond, body=%loop_body
  ROOT %out = f32[4,8]{1,0} copy(%w)
}
"""


def test_collective_bytes_trip_counts():
    out = H.collective_bytes(SAMPLE_HLO)
    # all-gather at top level: 16*32*2 = 1024 B
    assert out.get("all-gather") == 16 * 32 * 2
    # all-reduce inside while body x7 trips: 4*8*4*7
    assert out.get("all-reduce") == 4 * 8 * 4 * 7


def test_cpu_f32_upcast_detector():
    text = ("%a = f32[48,16,4096,1536]{3,2,1,0} convert(...)\n"
            "%b = bf16[48,16,4096,1536]{3,2,1,0} parameter(0)\n"
            "%c = f32[10,10]{1,0} add(...)\n")
    assert H.cpu_f32_upcast_bytes(text) == 48 * 16 * 4096 * 1536 * 4


def test_split_computations():
    comps = H.split_computations(SAMPLE_HLO)
    assert "loop_body" in comps and "wide.cond" in comps
    assert "all-reduce" in comps["loop_body"]
