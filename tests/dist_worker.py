"""Multi-device worker invoked by tests/test_distribution.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
Each mode prints one JSON line of results."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import json
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def out(**kw):
    print("RESULT " + json.dumps(kw))


def mode_sharded_train():
    from repro.configs import get_config, reduced
    from repro.distribution import context as dctx
    from repro.distribution import sharding as shd
    from repro.models import lm
    from repro.train.optimizer import AdamWConfig, adamw_init, \
        opt_state_shardings
    from repro.train.train_step import make_train_step

    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                  vocab=128)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
    step = make_train_step(cfg, opt_cfg)

    # single-device reference
    p_ref, _, m_ref = step(params, opt, {"tokens": toks})

    with mesh, dctx.use_mesh(mesh):
        psh = shd.param_shardings(cfg, jax.eval_shape(lambda: params),
                                  mesh)
        osh = opt_state_shardings(cfg, jax.eval_shape(lambda: params),
                                  mesh, opt_cfg, psh)
        bsh = {"tokens": NamedSharding(mesh, P("data", None))}
        jstep = jax.jit(step, in_shardings=(psh, osh, bsh),
                        out_shardings=(psh, osh, None))
        p_sh, _, m_sh = jstep(params, opt, {"tokens": toks})
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p_ref),
                               jax.tree.leaves(p_sh)))
    out(loss_ref=float(m_ref["loss"]), loss_sh=float(m_sh["loss"]),
        max_param_diff=diff)


def mode_moe_ep():
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.distribution.moe_ep import can_use_ep, moe_ffn_ep
    from repro.models import lm, moe as moe_mod

    cfg = reduced(get_config("granite-moe-1b-a400m"), layers=2,
                  d_model=64, vocab=128)
    # drop-free capacity: EP (per-shard caps) and local (global cap) then
    # dispatch identical token sets and must agree numerically
    cfg_nodrop = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    slot = jax.tree.map(lambda a: a[0],
                        params["segments"][0]["slot0"])["ffn"]
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 64))

    assert can_use_ep(cfg, x.shape, mesh)
    y_l0, aux_l0 = moe_mod.moe_ffn_local(slot, cfg_nodrop, x)
    with mesh:
        y_e0, aux_e0 = jax.jit(lambda s, xx: moe_ffn_ep(
            s, cfg_nodrop, xx, mesh))(slot, x)
    denom = float(jnp.max(jnp.abs(y_l0))) + 1e-9
    rel_nodrop = float(jnp.max(jnp.abs(y_l0 - y_e0))) / denom

    # default capacity: outputs may differ on dropped tokens; mean gap
    # must stay small
    y_l1, _ = moe_mod.moe_ffn_local(slot, cfg, x)
    with mesh:
        y_e1, _ = jax.jit(lambda s, xx: moe_ffn_ep(
            s, cfg, xx, mesh))(slot, x)
    mean_rel = float(jnp.mean(jnp.abs(y_l1 - y_e1))
                     / (jnp.mean(jnp.abs(y_l1)) + 1e-9))
    out(rel_nodrop=rel_nodrop, mean_rel=mean_rel,
        aux_local=float(aux_l0), aux_ep=float(aux_e0))


def mode_grad_compress():
    from repro.train.grad_compress import compressed_psum

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 1024)) \
        * jnp.array([[1.0], [3.0]])      # different per pod

    def body(x_loc):
        y, res = compressed_psum(x_loc[0], "pod", None)
        return y[None], res[None]

    from repro.distribution.context import shard_map
    with mesh:
        y, res = jax.jit(shard_map(
            body, mesh=mesh, in_specs=P("pod", None),
            out_specs=(P("pod", None), P("pod", None))))(x)
    exact = jnp.mean(x, axis=0)
    err = float(jnp.max(jnp.abs(y[0] - exact)))
    amax = float(jnp.max(jnp.abs(x)))
    # one-step error bounded by shared-scale int8 resolution
    out(err=err, bound=amax / 127.0 * 1.01,
        residual_norm=float(jnp.abs(res).max()))


def mode_elastic_reshard():
    from repro.train.checkpoint import CheckpointManager

    state = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}
    mesh1 = jax.make_mesh((4, 2), ("data", "model"))
    sh1 = {"w": NamedSharding(mesh1, P("data", "model"))}
    st1 = jax.device_put(state, sh1)
    mgr = CheckpointManager(sys.argv[2])
    mgr.save(1, st1)

    # "elastic": restore on a DIFFERENT mesh shape
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
    restored, _ = mgr.restore(jax.eval_shape(lambda: state),
                              shardings=sh2)
    ok_value = bool(jnp.allclose(restored["w"], state["w"]))
    ok_shard = restored["w"].sharding.is_equivalent_to(sh2["w"], 2)
    out(ok_value=ok_value, ok_shard=bool(ok_shard))


def mode_decode_sharded():
    from repro.configs import get_config, reduced
    from repro.distribution import context as dctx
    from repro.distribution import sharding as shd
    from repro.models import lm

    cfg = reduced(get_config("gemma3-4b"), layers=4, d_model=64,
                  vocab=128)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S0 = 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0 + 4), 0, 128)

    # unsharded reference
    logits_ref, caches = lm.prefill(params, cfg, toks[:, :S0],
                                    cache_len=S0 + 4)
    pos = jnp.full((B,), S0, jnp.int32)
    ref_step, _ = lm.decode_step(params, cfg, toks[:, S0:S0 + 1], pos,
                                 caches)

    with mesh, dctx.use_mesh(mesh):
        csh = shd.cache_shardings(cfg, mesh, B,
                                  jax.eval_shape(lambda: caches))
        caches_s = jax.device_put(caches, csh)
        step = jax.jit(lambda p, t, po, c: lm.decode_step(p, cfg, t, po,
                                                          c))
        got, _ = step(params, toks[:, S0:S0 + 1], pos, caches_s)
    out(max_diff=float(jnp.max(jnp.abs(got - ref_step))))


def mode_collective_parser_ground_truth():
    from repro.analysis.hlo import collective_bytes

    mesh = jax.make_mesh((8,), ("model",))
    L, M, N = 5, 64, 128

    def step(ws, x):
        def body(x, w):
            h = x @ w[0]
            y = h @ w[1].T        # contracts the sharded dim -> psum
            return y * 1e-3 + x, None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jax.ShapeDtypeStruct((L, 2, M, N), jnp.float32)
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    wsh = NamedSharding(mesh, P(None, None, None, "model"))
    with mesh:
        compiled = jax.jit(step, in_shardings=(
            wsh, NamedSharding(mesh, P()))).lower(ws, x).compile()
    got = collective_bytes(compiled.as_text())
    out(all_reduce=got.get("all-reduce", 0), expected=L * M * M * 4)


def mode_rs_ag_int8_ffn():
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.distribution import context as dctx
    from repro.models.ffn import ffn_apply, ffn_init

    cfg = dataclasses.replace(
        reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=128),
        d_ff=128)
    cfg8 = dataclasses.replace(cfg, tp_comm="rs_ag_int8")
    p = ffn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 64))
    y0 = ffn_apply(p, cfg, x)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh, dctx.use_mesh(mesh):
        y1 = jax.jit(lambda pp, xx: ffn_apply(pp, cfg8, xx))(p, x)
    rel = float(jnp.max(jnp.abs(y0 - y1))
                / (jnp.max(jnp.abs(y0)) + 1e-9))
    out(rel=rel)


def mode_packed_serve_mesh():
    from repro.configs import get_config, reduced
    from repro.launch.serve import build_serving_params
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg0 = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                   vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(rid=i,
                        prompt=rng.integers(0, 128, size=(8 + 7 * i,))
                        .astype(np.int32), max_new_tokens=6)
                for i in range(3)]

    def streams(params, cfg, mesh=None):
        eng = Engine(params, cfg, batch_slots=2, cache_len=64, mesh=mesh)
        return {r.rid: r.out_tokens for r in eng.run(reqs())}

    # scope="all" exercises BOTH sharded drivers: the fused gated-FFN
    # kernel (d_ff visit shards + reduction) and the per-matrix packed
    # attention projections (col-sharded wq/wk/wv, row-sharded wo).
    # sparsity=0.25 (NOT 0.5): at 0.5 this reduced config prunes the
    # whole d_ff grid, the fused FFN output is identically zero, and the
    # bit-identity check has no discriminative power over the reduction.
    deploy = dict(path="packed", sparsity=0.25, block_k=8, block_n=8,
                  scope="all", verbose=False)
    p1, c1 = build_serving_params(params0, cfg0, **deploy)
    s_ref = streams(p1, c1)

    # the fused path must actually contribute signal (guards the check
    # above against config drift re-zeroing the FFN)
    from repro.core.deploy import packed_ffn_apply
    f0 = jax.tree.map(lambda a: a[0],
                      p1["segments"][0]["slot0"]["ffn"]["sasp_fused"])
    probe = packed_ffn_apply(jnp.ones((2, cfg0.d_model), jnp.float32), f0)
    fused_signal = float(jnp.abs(probe).max())

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    p2, c2 = build_serving_params(params0, cfg0, mesh=mesh, **deploy)
    s_mesh = streams(p2, c2, mesh=mesh)
    out(equal=int(s_ref == s_mesh), n=len(s_ref),
        fused_signal=fused_signal,
        streams_ref={str(k): v for k, v in s_ref.items()},
        streams_mesh={str(k): v for k, v in s_mesh.items()})


def mode_sched_mesh():
    """Sharded-scheduler continuous batching on mesh packed paths
    (DESIGN.md §11 bit-identity contract): a slot freed by EOS is
    refilled from the queue, and every greedy stream equals the solo
    single-batch engine run on the same deployment. 1×2 mesh = one DP
    rank with TP-sharded visit lists; 2×2 mesh = two DP-rank engine
    shards on dp_submeshes, each with its own cache-slot slice."""
    from repro.configs import get_config, reduced
    from repro.launch.serve import build_serving_params
    from repro.models import lm
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

    cfg0 = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                   vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    # amplified weights: unit-scale random init greedy-decodes into a
    # constant stream, which would make the mid-decode EOS unreachable
    params0 = jax.tree.map(lambda a: a * 3.0, params0)
    deploy = dict(path="packed", sparsity=0.25, block_k=8, block_n=8,
                  scope="all", verbose=False)
    rng = np.random.default_rng(0)
    # 6 requests > 2 ranks × 2 slots, so BOTH mesh shapes build a queue
    # and exercise the mid-decode refill
    prompts = [rng.integers(0, 128, size=(6 + 4 * i,)).astype(np.int32)
               for i in range(6)]
    budgets = [8, 8, 4, 5, 6, 3]

    def solo(params, cfg, mesh, i, eos_id=None):
        eng = Engine(params, cfg, batch_slots=1, cache_len=64,
                     mesh=mesh)
        return eng.run([Request(rid=i, prompt=prompts[i],
                                max_new_tokens=budgets[i],
                                eos_id=eos_id)])[0].out_tokens

    results = {}
    stash = {}
    for name, shape in (("1x2", (1, 2)), ("2x2", (2, 2))):
        mesh = jax.make_mesh(shape, ("data", "model"))
        p, c = build_serving_params(params0, cfg0, mesh=mesh, **deploy)
        # EOS for request 1: first token in its stream with no earlier
        # occurrence, so the slot frees MID-DECODE and is refilled
        stream1 = solo(p, c, mesh, 1)
        eos_at = next(i for i in range(1, len(stream1) - 1)
                      if stream1[i] not in stream1[:i])
        eos_id = int(stream1[eos_at])
        ref = {i: solo(p, c, mesh, i, eos_id=eos_id if i == 1 else None)
               for i in range(len(prompts))}
        sched = ShardedScheduler(
            p, c, mesh=mesh,
            sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
        done = sched.run(
            [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                     eos_id=eos_id if i == 1 else None)
             for i in range(len(prompts))])
        got = {r.rid: r.out_tokens for r in done}
        st = sched.stats()
        results[name] = dict(
            equal=int(got == ref),
            eos_early=int(len(ref[1]) == eos_at + 1),
            refills=sum(r["continuous_refills"] for r in st["per_rank"]),
            ranks=st["ranks"],
            ranks_served=len({r.rank for r in done}),
            streams_ref={str(k): v for k, v in ref.items()},
            streams_got={str(k): v for k, v in got.items()})
        stash[name] = (mesh, p, c, ref, eos_id)

    # streaming + prefill bucketing + EDF on the mesh path
    # (DESIGN.md §12): the per-token iterator over the 1×2 TP-sharded
    # deployment must yield every request's greedy stream bit-identical
    # to the solo mesh engine, with bucketed admission bounding the jit
    # cache (every admission shape (B, bucket))
    mesh, p, c, ref, eos_id = stash["1x2"]
    buckets = (16, 32, 64)
    sched = ShardedScheduler(
        p, c, mesh=mesh,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64,
                              policy="edf", buckets=buckets))
    shapes = set()
    eng = sched.shards[0]
    orig_prefill = eng._prefill

    def counting(params, toks, poss, caches, slots, valid):
        shapes.add(tuple(toks.shape))
        return orig_prefill(params, toks, poss, caches, slots, valid)

    eng._prefill = counting
    per = {}
    for rid, tok in sched.stream(
            [Request(rid=i, prompt=prompts[i],
                     max_new_tokens=budgets[i],
                     eos_id=eos_id if i == 1 else None,
                     slo="interactive" if i % 2 else "batch")
             for i in range(len(prompts))]):
        per.setdefault(rid, []).append(tok)
    out(stream_equal=int(per == ref),
        stream_events=sum(len(v) for v in per.values()),
        admit_shapes=sorted(shapes),
        admit_shapes_ok=int(len(shapes) <= len(buckets) and all(
            g == 2 and s in buckets for g, s in shapes)),
        **{f"{k}_{n}": v for n, res in results.items()
           for k, v in res.items()})


def mode_paged_mesh():
    """Paged KV on the 1×2-mesh packed path (DESIGN.md §13 acceptance):
    greedy streams with the page pool + block tables must equal the
    contiguous-cache mesh engine bit-for-bit, including across a forced
    preempt (page unmap) → spill (device→host) → fault → resume cycle
    driven by the QoS scheduler on the TP-sharded packed deployment."""
    from repro.configs import get_config, reduced
    from repro.launch.serve import build_serving_params
    from repro.models import lm
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

    cfg0 = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                   vocab=128)
    params0 = lm.init_params(jax.random.PRNGKey(0), cfg0)
    params0 = jax.tree.map(lambda a: a * 3.0, params0)
    deploy = dict(path="packed", sparsity=0.25, block_k=8, block_n=8,
                  scope="all", verbose=False)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    p, c = build_serving_params(params0, cfg0, mesh=mesh, **deploy)

    def streams(**kv):
        eng = Engine(p, c, batch_slots=2, cache_len=64, mesh=mesh, **kv)
        rngs = np.random.default_rng(0)
        done = eng.run([Request(
            rid=i, prompt=rngs.integers(0, 128, size=(8 + 7 * i,))
            .astype(np.int32), max_new_tokens=6) for i in range(4)])
        return {r.rid: r.out_tokens for r in done}, eng

    s_ref, _ = streams()
    # tile-aligned pages (block 8): oversubscribed pool + host spill
    s_paged, eng = streams(kv_pages=12, kv_page_len=8, kv_host_pages=8)
    mem = eng.memory_stats()
    equal = int(s_paged == s_ref)
    drained = int(mem.device_used == 0 and mem.host_used == 0)

    # forced preempt → spill → fault → resume on the mesh deployment
    def solo(req):
        e = Engine(p, c, batch_slots=1, cache_len=64, mesh=mesh)
        return e.run([Request(rid=req.rid, prompt=req.prompt,
                              max_new_tokens=req.max_new_tokens)]
                     )[0].out_tokens

    rngq = np.random.default_rng(4)
    batch = Request(rid=0, prompt=rngq.integers(0, 128, size=(18,))
                    .astype(np.int32), max_new_tokens=12, slo="batch")
    inter = Request(rid=1, prompt=rngq.integers(0, 128, size=(40,))
                    .astype(np.int32), max_new_tokens=3,
                    slo="interactive", deadline=0.01)
    ref_q = {r.rid: solo(r) for r in (batch, inter)}
    sched = ShardedScheduler(
        p, c, mesh=mesh,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=8))
    assert sched.submit(batch)
    for _ in range(4):
        sched.step()
    assert sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    st = sched.stats()
    memq = st["per_rank"][0]["memory"]
    out(equal=equal, drained=drained, spills_run1=mem.spills,
        cycle_equal=int({r.rid: r.out_tokens for r in done} == ref_q),
        preemptions=st["preemptions"], spills=memq["spills"],
        faults=memq["faults"], device_used=memq["device_used"],
        streams_ref={str(k): v for k, v in s_ref.items()},
        streams_paged={str(k): v for k, v in s_paged.items()})


def mode_frontend_host():
    """Cluster-frontend subprocess host (DESIGN.md §14): one
    single-process ShardedScheduler driven over a newline-JSON protocol
    — commands on stdin (``ping``/``submit``/``step``/``cancel``/
    ``exit``), ``EV {json}`` events on stdout (``ready``/``pong``/
    ``submitted``/``tok``/``done``/``failed``/``stepped``/
    ``cancelled``). ``serve.frontend.SubprocessHost`` is the parent
    side; tests ``kill -9`` this process mid-load to prove the
    frontend's retry/resume guarantees against a real OS-level death.
    ``sys.argv[2]`` (optional) is a JSON dict of model/scheduler knobs.
    Token events carry the GLOBAL output index (resume prefixes
    included), so the parent can dedup replays exactly."""
    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.serve.engine import Request
    from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

    spec = json.loads(sys.argv[2]) if len(sys.argv) > 2 else {}
    cfg = reduced(get_config("qwen3-32b"),
                  layers=spec.get("layers", 2),
                  d_model=spec.get("d_model", 64),
                  vocab=spec.get("vocab", 64))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # same 3x amplification as the serving tests: unit-scale random
    # init greedy-decodes into a constant stream
    params = jax.tree.map(lambda a: a * 3.0, params)
    sched = ShardedScheduler(
        params, cfg, ranks=spec.get("ranks", 1),
        sched=SchedulerConfig(slots_per_rank=spec.get("slots", 2),
                              cache_len=spec.get("cache_len", 64),
                              rng_seed=spec.get("seed", 0)))

    def ev(**kw):
        print("EV " + json.dumps(kw), flush=True)

    sched.set_on_token(lambda req, tok: ev(
        ev="tok", rid=req.rid, i=len(req.out_tokens) - 1, tok=int(tok)))
    ev(ev="ready")
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        msg = json.loads(line)
        cmd = msg["cmd"]
        if cmd == "ping":
            ev(ev="pong")
        elif cmd == "submit":
            req = Request(
                rid=msg["rid"],
                prompt=np.asarray(msg["prompt"], np.int32),
                max_new_tokens=msg["max_new"],
                temperature=msg.get("temperature", 0.0),
                eos_id=msg.get("eos"), slo=msg.get("slo", "batch"),
                out_tokens=list(msg.get("resume") or []))
            if req.out_tokens:
                req.mark_resumable()   # exact re-prefill continuation
            ok = sched.submit(req)
            ev(ev="submitted", rid=req.rid, ok=bool(ok),
               status=req.status)
        elif cmd == "step":
            for r in sched.step():
                ev(ev="done", rid=r.rid)
            for r in sched.failed:
                ev(ev="failed", rid=r.rid,
                   error=r.error or "rank failure")
            sched.failed[:] = []
            ev(ev="stepped")
        elif cmd == "cancel":
            sched.cancel(msg["rid"])
            ev(ev="cancelled", rid=msg["rid"])
        elif cmd == "exit":
            break


if __name__ == "__main__":
    globals()[f"mode_{sys.argv[1]}"]()
