"""Packed deployment pipeline: deploy_packed parity vs the masked-dense
reference across forward/prefill/decode, engine fast-path semantics
(batched left-padded prefill, on-device sampling, EOS masking), and
hypothesis property tests over random (tp, sparsity, block size,
int8/fp32) packing configs — visit-count conservation and
reshard↔from-scratch bit-identity as PROPERTIES, with fixed-grid twins
that run even where hypothesis is unavailable."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # twins below still run
    HAVE_HYPOTHESIS = False

from repro.configs import SASPConfig, get_config, reduced
from repro.core.deploy import deploy_packed, packed_summary
from repro.core.pruning import prune_params
from repro.models import lm
from repro.serve.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


def _pruned(scope="all", sparsity=0.5, layers=2, d_model=64, vocab=64):
    sasp = SASPConfig(enabled=True, block_k=16, block_n=16,
                      sparsity=sparsity, scope=scope)
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-32b"), layers=layers, d_model=d_model,
                vocab=vocab),
        sasp=sasp)
    params = lm.init_params(KEY, cfg)
    pruned, _ = prune_params(params, sasp)
    return pruned, cfg


@pytest.mark.parametrize("fuse_ffn", [True, False])
@pytest.mark.parametrize("scope", ["ffn", "all"])
def test_deploy_packed_forward_parity(scope, fuse_ffn):
    pruned, cfg = _pruned(scope=scope)
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    ref = lm.forward(pruned, cfg, toks)
    pp, pcfg = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn)
    got = lm.forward(pp, pcfg, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_deploy_packed_prefill_decode_parity():
    pruned, cfg = _pruned()
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    lg0, c0 = lm.prefill(pruned, cfg, toks, cache_len=32)
    pp, pcfg = deploy_packed(pruned, cfg)
    lg1, c1 = lm.prefill(pp, pcfg, toks, cache_len=32)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg0),
                               rtol=1e-4, atol=1e-4)
    t = jnp.asarray([[int(jnp.argmax(lg0[0, 0]))]], jnp.int32)
    pos = jnp.asarray([8], jnp.int32)
    d0, _ = lm.decode_step(pruned, cfg, t, pos, c0)
    d1, _ = lm.decode_step(pp, pcfg, t, pos, c1)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d0),
                               rtol=1e-4, atol=1e-4)


def test_deploy_packed_int8_close():
    pruned, cfg = _pruned(scope="ffn")
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    ref = np.asarray(lm.forward(pruned, cfg, toks))
    pp, pcfg = deploy_packed(pruned, cfg, quantize=True)
    got = np.asarray(lm.forward(pp, pcfg, toks))
    scale = np.abs(ref).max() + 1e-9
    assert np.abs(got - ref).max() / scale < 5e-2


def test_packed_summary_reports_compression():
    pruned, cfg = _pruned(scope="all", sparsity=0.5)
    pp, _ = deploy_packed(pruned, cfg)
    s = packed_summary(pp)
    assert s["n_fused_ffns"] == 1          # one stacked FFN container
    assert s["n_packed_matrices"] == 4     # wq/wk/wv/wo stacked
    assert 0 < s["compression"] < 1.0      # strictly smaller than dense


def _live_visits(vals) -> int:
    """Visits whose block carries any nonzero value (padding visits are
    zero-valued by construction)."""
    v = np.asarray(vals)
    return int(np.count_nonzero(np.any(v != 0, axis=(-2, -1))))


@pytest.mark.parametrize("kind", ["col", "row"])
def test_tp_shard_visit_counts_sum_to_unsharded(kind):
    """TP-sharded packing (DESIGN.md §10) must conserve work: the
    per-shard live visit counts sum to the unsharded nnz — no block is
    dropped and none is double-visited."""
    from repro.core.deploy import pack_weight

    rng = np.random.default_rng(0)
    K, N, bk, bn = 32, 64, 8, 8
    w = rng.normal(size=(2, K, N)).astype(np.float32)      # L-stacked
    mask = rng.random((2, K // bk, N // bn)) > 0.5
    wz = (w.reshape(2, K // bk, bk, N // bn, bn)
          * mask[:, :, None, :, None]).reshape(2, K, N)

    pw0 = pack_weight(wz, block_k=bk, block_n=bn)
    pw2 = pack_weight(wz, block_k=bk, block_n=bn, tp=2, shard_kind=kind)
    assert pw2.shards == 2 and pw2.shard_kind == kind
    assert pw2.vals.shape[:2] == (2, 2)        # (L, tp, nnz, bk, bn)
    for layer in range(2):
        ref = _live_visits(pw0.vals[layer])
        got = sum(_live_visits(pw2.vals[layer, s]) for s in range(2))
        assert got == ref == int(mask[layer].sum()), (layer, got, ref)


def test_tp_sharded_deploy_single_device_parity():
    """A mesh-deployed (TP-sharded) param tree must stay loadable and
    exact on a single device: the shard-loop fallback drivers reproduce
    the unsharded packed forward bit-for-bit (col shards) / within fp32
    summation-order noise (row/fused reductions). sparsity=0.25 so the
    FFN path carries nonzero signal (at 0.5 this reduced config prunes
    the whole d_ff grid and the comparison proves nothing about the
    shard reduction)."""
    pruned, cfg = _pruned(scope="all", sparsity=0.25)
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    for fuse_ffn in (True, False):
        pp0, c0 = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn)
        pp2, c2 = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn, tp=2)
        slot = pp2["segments"][0]["slot0"]
        cont = slot["ffn"]["sasp_fused"] if fuse_ffn \
            else slot["ffn"]["sasp_packed"]["w1"]
        assert cont.shards == 2            # sharding actually engaged
        assert slot["mixer"]["sasp_packed"]["wo"].shards == 2
        ref = lm.forward(pp0, c0, toks)
        got = lm.forward(pp2, c2, toks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def _assert_trees_equal(a, b):
    """Exact (bitwise) equality of two packed param trees."""
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert len(la) == len(lb), (len(la), len(lb))
    for (pa, xa), (pb, xb) in zip(la, lb):
        xa, xb = np.asarray(xa), np.asarray(xb)
        assert xa.shape == xb.shape and np.array_equal(xa, xb), \
            jax.tree_util.keystr(pa)


@pytest.mark.parametrize("fuse_ffn", [True, False])
def test_reshard_packed_matches_from_scratch(fuse_ffn):
    """Elastic re-deploy fast path (ROADMAP): re-partitioning an
    existing unsharded pack by slicing + padding its visit lists must be
    BIT-IDENTICAL to packing from scratch at the new tp — same visit
    sets, same empty-column flush entries, same shared-nnz padding."""
    from repro.core.deploy import reshard_packed

    pruned, cfg = _pruned(scope="all", sparsity=0.25)
    pp1, _ = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn)
    pp2, _ = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn, tp=2)
    rs = reshard_packed(pp1, cfg, tp=2)
    _assert_trees_equal(pp2["segments"], rs["segments"])


def test_reshard_packed_quantized_and_roundtrip():
    """int8 containers reshard exactly too (per-visit scales travel with
    their visits; epsilon scales of flush entries match), and resharding
    back to tp=1 reproduces the original pack — so mesh-shape changes
    can go sharded→sharded without keeping the unsharded pack around."""
    from repro.core.deploy import reshard_packed

    pruned, cfg = _pruned(scope="all", sparsity=0.25)
    for fuse_ffn in (True, False):
        pp1, _ = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn,
                               quantize=True)
        pp2, _ = deploy_packed(pruned, cfg, fuse_ffn=fuse_ffn,
                               quantize=True, tp=2)
        rs = reshard_packed(pp1, cfg, tp=2)
        _assert_trees_equal(pp2["segments"], rs["segments"])
        back = reshard_packed(rs, cfg, tp=1)
        _assert_trees_equal(pp1["segments"], back["segments"])


def test_reshard_packed_forward_parity():
    """The resharded tree must also SERVE identically: single-device
    shard-loop forward of reshard(tp=2) matches the unsharded packed
    forward (same contract as the from-scratch sharded deploy)."""
    from repro.core.deploy import reshard_packed

    pruned, cfg = _pruned(scope="all", sparsity=0.25)
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    pp1, c1 = deploy_packed(pruned, cfg)
    rs = reshard_packed(pp1, cfg, tp=2)
    ref = lm.forward(pp1, c1, toks)
    got = lm.forward(rs, c1, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Property tests: random packing configs (hypothesis + fixed twins)
# ---------------------------------------------------------------------------


def _random_blockmasked(seed, K, N, bk, bn, sparsity, layers=2):
    """(L, K, N) weights with a random block mask applied — the input
    contract of pack_weight (pruned tiles already zeroed)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(layers, K, N)).astype(np.float32)
    mask = rng.random((layers, K // bk, N // bn)) >= sparsity
    wz = (w.reshape(layers, K // bk, bk, N // bn, bn)
          * mask[:, :, None, :, None]).reshape(layers, K, N)
    return wz, mask


def _assert_packed_equal(a, b, ctx):
    for name in ("vals", "kn", "scale", "bias"):
        xa, xb = getattr(a, name), getattr(b, name)
        assert (xa is None) == (xb is None), (name, ctx)
        if xa is not None:
            assert np.array_equal(np.asarray(xa), np.asarray(xb)), \
                (name, ctx)
    assert a.shards == b.shards and a.shard_kind == b.shard_kind, ctx
    assert a.shape == b.shape and a.block == b.block, ctx


def _check_pack_properties(tp, sparsity, block, quantize, kind, seed):
    """The two properties, on one random config:

    1. **Visit conservation** — per layer, the shards' live (nonzero-
       valued) visits sum to the mask's surviving block count: no block
       dropped, none double-visited, at ANY shard count/sparsity
       (including entirely-empty shards, which carry only zero-valued
       flush/padding visits).
    2. **Reshard ↔ from-scratch bit-identity** — slicing + re-padding
       an existing pack to ``tp`` equals packing the dense weight from
       scratch at ``tp`` bit-for-bit (values, coords, int8 scales), and
       resharding back to 1 reproduces the original pack.
    """
    from repro.core.deploy import _reshard_weight, pack_weight

    K = N = 32
    wz, mask = _random_blockmasked(seed, K, N, block, block, sparsity)
    base = pack_weight(wz, block_k=block, block_n=block,
                       quantize=quantize)
    scratch = pack_weight(wz, block_k=block, block_n=block, tp=tp,
                          shard_kind=kind, quantize=quantize)
    ctx = dict(tp=tp, sparsity=sparsity, block=block,
               quantize=quantize, kind=kind, seed=seed)
    for layer in range(wz.shape[0]):
        ref = int(mask[layer].sum())
        v = np.asarray(scratch.vals)[layer]
        got = _live_visits(v) if tp == 1 else sum(
            _live_visits(v[s]) for s in range(tp))
        assert got == ref, (layer, got, ref, ctx)
    rs = _reshard_weight(base, tp, kind)
    _assert_packed_equal(rs, scratch, ctx)
    back = _reshard_weight(rs, 1, kind)
    _assert_packed_equal(back, base, ctx)


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(tp=st.sampled_from([1, 2, 4]),
           sparsity=st.floats(0.0, 0.97),
           block=st.sampled_from([4, 8]),
           quantize=st.booleans(),
           kind=st.sampled_from(["col", "row"]),
           seed=st.integers(0, 2**16))
    def test_pack_weight_properties_random_configs(
            tp, sparsity, block, quantize, kind, seed):
        _check_pack_properties(tp, sparsity, block, quantize, kind, seed)


@pytest.mark.parametrize("tp,kind", [(2, "col"), (4, "row")])
@pytest.mark.parametrize("quantize", [False, True])
def test_pack_weight_properties_fixed_grid(tp, kind, quantize):
    """Hypothesis-free twin of the property test (runs everywhere),
    including a high-sparsity case that forces empty shards."""
    for sparsity, seed in ((0.3, 0), (0.9, 1)):
        _check_pack_properties(tp, sparsity, 8, quantize, kind, seed)


def _check_deploy_reshard_property(tp, sparsity, quantize):
    """Deploy-level property: for a whole deployed tree (fused FFN +
    attention containers), reshard_packed to ``tp`` is bit-identical to
    deploy_packed from scratch at ``tp``, and round-trips back."""
    from repro.core.deploy import reshard_packed

    pruned, cfg = _pruned(scope="all", sparsity=sparsity)
    pp1, _ = deploy_packed(pruned, cfg, quantize=quantize)
    pp2, _ = deploy_packed(pruned, cfg, quantize=quantize, tp=tp)
    rs = reshard_packed(pp1, cfg, tp=tp)
    _assert_trees_equal(pp2["segments"], rs["segments"])
    back = reshard_packed(rs, cfg, tp=1)
    _assert_trees_equal(pp1["segments"], back["segments"])


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=5, deadline=None)
    @given(tp=st.sampled_from([1, 2]),
           sparsity=st.sampled_from([0.25, 0.5]),
           quantize=st.booleans())
    def test_deploy_reshard_property_random_configs(
            tp, sparsity, quantize):
        _check_deploy_reshard_property(tp, sparsity, quantize)


def test_deploy_reshard_property_fixed():
    """Hypothesis-free twin of the deploy-level reshard property."""
    _check_deploy_reshard_property(2, 0.25, quantize=True)


def test_engine_packed_matches_masked_engine_tokens():
    pruned, cfg = _pruned(scope="ffn", sparsity=0.5)
    pp, pcfg = deploy_packed(pruned, cfg)
    prompt = np.arange(1, 11, dtype=np.int32)
    a = Engine(pruned, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=6)])[0].out_tokens
    b = Engine(pp, pcfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=6)])[0].out_tokens
    assert a == b


def test_batched_prefill_slot_isolation():
    """Multi-slot batched (left-padded) prefill must be bit-equivalent
    to solo serving for every sequence, across unequal prompt lengths."""
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.arange(30, 40, dtype=np.int32),
               np.arange(5, 13, dtype=np.int32)]
    solo = [Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=p, max_new_tokens=5)])[0].out_tokens
        for p in prompts]
    eng = Engine(params, cfg, batch_slots=3, cache_len=64)
    together = eng.run([Request(rid=i, prompt=p, max_new_tokens=5)
                        for i, p in enumerate(prompts)])
    got = {r.rid: r.out_tokens for r in together}
    for i in range(len(prompts)):
        assert got[i] == solo[i], i


def test_engine_eos_stops_early():
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    prompt = np.arange(1, 9, dtype=np.int32)
    ref = Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=8)])[0].out_tokens
    assert len(ref) == 8
    eos = ref[2]                      # appears in the greedy stream
    out = Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=8,
                 eos_id=int(eos))])[0].out_tokens
    stop = ref.index(eos) + 1         # first emission, EOS included
    assert out == ref[:stop]


def test_engine_temperature_sampling_on_device():
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    prompt = np.arange(1, 9, dtype=np.int32)
    outs = set()
    for seed in range(3):
        eng = Engine(params, cfg, batch_slots=1, cache_len=64,
                     rng_seed=seed)
        r = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=8,
                             temperature=1.5)])[0]
        assert len(r.out_tokens) == 8
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
        outs.add(tuple(r.out_tokens))
    assert len(outs) > 1              # different seeds, different streams
