"""Histogram-fitted prefill bucket tables (tools/suggest_buckets.py +
the scheduler's prompt-length capture): the DP must be exactly optimal
on small cases, beat the geometric default on skewed traffic, and
round-trip through the scheduler's observed histogram."""
import itertools
import os
import sys

import numpy as np
import jax

TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
sys.path.insert(0, os.path.abspath(TOOLS))

from suggest_buckets import pad_waste, suggest_buckets  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.distribution.sharding import prefill_bucket_table  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve.engine import Request  # noqa: E402
from repro.serve.scheduler import SchedulerConfig, \
    ShardedScheduler  # noqa: E402


def test_fitted_table_beats_geometric_on_skewed_histogram():
    """Chat-like skew: 80% of prompts at 9–12 tokens, a 100–120 tail.
    The geometric table (64,128,256,512) pads the head to 64 every
    time; the fitted table puts boundaries on the mass."""
    hist = {9: 400, 10: 250, 11: 100, 12: 50,
            100: 60, 110: 25, 120: 15}
    cache_len, k = 512, 4
    fitted = suggest_buckets(hist, k, cache_len)
    geo = prefill_bucket_table(cache_len, k)
    assert len(fitted) <= k
    assert fitted[-1] == cache_len          # always covers the cache
    assert fitted == tuple(sorted(fitted))
    w_fit = pad_waste(hist, fitted, cache_len)
    w_geo = pad_waste(hist, geo, cache_len)
    assert w_fit < w_geo / 5, (fitted, w_fit, w_geo)


def test_dp_is_exactly_optimal_vs_brute_force():
    rng = np.random.default_rng(0)
    for _ in range(10):
        lengths = sorted(rng.choice(np.arange(1, 30), size=5,
                                    replace=False))
        hist = {int(l): int(rng.integers(1, 50)) for l in lengths}
        cache_len, k = 32, 3
        got = suggest_buckets(hist, k, cache_len)
        best = min(
            pad_waste(hist, combo + (cache_len,), cache_len)
            for n in range(0, k)
            for combo in itertools.combinations(lengths, n))
        assert pad_waste(hist, got, cache_len) == best, (hist, got)


def test_degenerate_histograms():
    assert suggest_buckets({}, 4, 128) == (128,)
    assert suggest_buckets({7: 10}, 4, 128) == (7, 128)
    # lengths beyond the cache clamp to it
    assert suggest_buckets({500: 3}, 2, 128) == (128,)


def test_scheduler_histogram_feeds_the_fit():
    """The serving loop's observed histogram (captured on EVERY submit,
    admitted or not) round-trips into a usable table."""
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                  vocab=64)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
    rng = np.random.default_rng(1)
    lens = [8] * 6 + [9] * 3 + [40]
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(n,))
                    .astype(np.int32), max_new_tokens=2)
            for i, n in enumerate(lens)]
    sched.run(reqs)
    hist = sched.prompt_length_histogram()
    assert hist == {8: 6, 9: 3, 40: 1}
    assert sched.stats()["prompt_lengths_seen"] == len(lens)
    table = suggest_buckets(hist, 3, 64)
    assert table[-1] == 64
    # the head of the mass gets its own tight bucket
    assert any(b in (8, 9) for b in table)
    assert pad_waste(hist, table, 64) <= pad_waste(
        hist, prefill_bucket_table(64, 3), 64)
