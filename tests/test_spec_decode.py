"""Self-speculative decoding on the sparsity ladder (DESIGN.md §17).

The contract under test: with a drafter enabled (the SAME weights
re-packed at higher tile sparsity), every greedy stream is
bit-identical to the non-speculative engine — acceptance rate moves
throughput, never outputs — and the scratch-page lifecycle never leaks
(promote/discard resolve every round, ``PageAllocator.check`` +
``tools.analyze.check_page_refcounts`` hold after every verify step).

Because a correct engine never depends on WHAT the drafter proposes,
the adversarial tests stub ``eng._draft_decode`` outright: a random-
token drafter drives acceptance toward zero, and an oracle drafter
that copies the reference stream for exactly m positions pins the
acceptance offset at m ∈ {0, k-1, k}. (Tiny random-init models decay
to near-constant streams, so a REAL high-sparsity drafter accepts
almost everything — useless for exercising the rejection paths.)"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler
from tools.analyze import check_page_refcounts

KEY = jax.random.PRNGKey(0)
VOCAB = 64


def _setup():
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                  vocab=VOCAB)
    params = lm.init_params(KEY, cfg)
    # position-dependent streams (same amplification as test_memory.py)
    return cfg, jax.tree.map(lambda a: a * 3.0, params)


def _solo(params, cfg, req: Request):
    r = Request(rid=req.rid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, eos_id=req.eos_id)
    return Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [r])[0].out_tokens


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = []
    while eng.has_work():
        done.extend(eng.step())
    return {r.rid: list(r.out_tokens) for r in done}


def _engine(params, cfg, draft=None, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("kv_pages", 20)
    kw.setdefault("kv_page_len", 8)
    if draft is not None:
        kw.setdefault("draft_sparsity", draft)
    return Engine(params, cfg, **kw)


def _spec_clean(eng):
    """Post-run invariants every spec test ends on: no scratch page
    survives a round, allocator bookkeeping intact."""
    assert not eng.pool.alloc.scratch, eng.pool.alloc.scratch
    assert eng.pool.stats().scratch_pages == 0
    errs = check_page_refcounts(eng.pool)
    assert not errs, errs
    eng.pool.alloc.check()


# ---------------------------------------------------------------------------
# Fixed twins: spec-on == spec-off == solo, across draft depths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_fixed_twins_bit_identical(k):
    """A mixed workload (greedy batch, greedy with EOS, interactive,
    temperature>0) through the real 75%-sparsity drafter at draft_k ∈
    {1, 2, 4}: every stream equals the non-speculative paged engine,
    greedy ones also equal the solo contiguous engine. The temp>0 row
    doubles as an RNG-parity oracle: speculation must consume exactly
    one key split per step, so the sampled stream cannot drift."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)

    def mk():
        return [
            Request(rid=0, prompt=rng.integers(0, VOCAB, size=(11,))
                    .astype(np.int32), max_new_tokens=9),
            Request(rid=1, prompt=rng.integers(0, VOCAB, size=(7,))
                    .astype(np.int32), max_new_tokens=12, eos_id=5),
            Request(rid=2, prompt=rng.integers(0, VOCAB, size=(9,))
                    .astype(np.int32), max_new_tokens=8,
                    slo="interactive"),
            Request(rid=3, prompt=rng.integers(0, VOCAB, size=(8,))
                    .astype(np.int32), max_new_tokens=7,
                    temperature=0.8),
        ]

    rng = np.random.default_rng(3)
    ref = {r.rid: _solo(params, cfg, r) for r in mk()
           if r.temperature == 0}
    rng = np.random.default_rng(3)
    off = _drive(_engine(params, cfg, batch_slots=4), mk())
    rng = np.random.default_rng(3)
    eng = _engine(params, cfg, draft=0.75, draft_k=k, batch_slots=4)
    on = _drive(eng, mk())
    assert on == off
    for rid, toks in ref.items():
        assert on[rid] == toks
    assert eng.stats["spec_rounds"] > 0
    assert eng.stats["spec_draft_tokens"] == \
        k * eng.stats["spec_rounds"]
    _spec_clean(eng)


# ---------------------------------------------------------------------------
# Controlled acceptance offsets via an oracle-drafter stub
# ---------------------------------------------------------------------------


def _offset_drafter(eng, ref, m, k):
    """Drafter stub proposing the reference stream for exactly the
    first ``m`` positions of every round, then a provably-wrong token
    — pins acceptance at offset m ∈ {0 .. k}."""
    state = {"calls": 0, "n": 0}

    def fake(dparams, cur, pos, data, dbt, key, temps, act, eos, rem):
        t = state["calls"] % k
        if t == 0:      # round start: snapshot the emitted-token count
            state["n"] = len(eng.slot_req[0].out_tokens)
        state["calls"] += 1
        idx = state["n"] + t
        if t < m and idx < len(ref):
            tok = int(ref[idx])
        else:
            tok = (int(ref[min(idx, len(ref) - 1)]) + 1) % VOCAB
        return (jnp.full((eng.B,), tok, jnp.int32), None, data, None)

    return fake


@pytest.mark.parametrize("k,m", [(1, 0), (1, 1), (2, 0), (2, 1),
                                 (2, 2), (4, 0), (4, 3), (4, 4)])
def test_spec_acceptance_offsets_exact(k, m):
    """Acceptance offsets {0, k-1, k}: the oracle drafter makes every
    round accept exactly m drafts, so the accepted-token counter is
    m · rounds EXACTLY, and the stream still equals the
    non-speculative reference bit for bit (full rejection emits the
    verify pass's own argmax — never a stall, never a wrong token)."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, VOCAB, size=(10,)).astype(np.int32)
    # every spec round advances m+1 tokens from n=1, so this budget
    # ends with remaining == 1 after six full rounds — no truncated
    # tail round, and every oracle index stays inside the reference
    max_new = 6 * (m + 1) + 2
    mk = lambda: [Request(rid=0, prompt=prompt.copy(),
                          max_new_tokens=max_new)]
    off = _drive(_engine(params, cfg, batch_slots=1), mk())
    ref = off[0]
    eng = _engine(params, cfg, draft=0.75, draft_k=k, batch_slots=1)
    eng._draft_decode = _offset_drafter(eng, ref, m, k)
    on = _drive(eng, mk())
    assert on == off
    st = eng.stats
    assert st["spec_rounds"] > 0
    assert st["spec_accepted_tokens"] == m * st["spec_rounds"], st
    _spec_clean(eng)


# ---------------------------------------------------------------------------
# Chaos drafter: random proposals, refcounts checked after every verify
# ---------------------------------------------------------------------------


def test_spec_random_drafter_invariants_after_every_verify():
    """A drafter emitting seeded random garbage drives acceptance
    toward zero while the engine keeps emitting correct target tokens.
    ``check_page_refcounts`` runs after EVERY verify round (the chaos-
    harness hook), so a single leaked or double-owned scratch page
    fails at the round that leaked it, not at drain."""
    cfg, params = _setup()
    rng = np.random.default_rng(6)
    mk = lambda: [Request(
        rid=i, prompt=rng.integers(0, VOCAB, size=(6 + 5 * i,))
        .astype(np.int32), max_new_tokens=14) for i in range(4)]
    rng = np.random.default_rng(6)
    off = _drive(_engine(params, cfg), mk())
    rng = np.random.default_rng(6)
    eng = _engine(params, cfg, draft=0.75, draft_k=4)
    bad = np.random.default_rng(99)

    def chaos_draft(dparams, cur, pos, data, dbt, key, temps, act,
                    eos, rem):
        toks = bad.integers(0, VOCAB, size=(eng.B,))
        return (jnp.asarray(toks, jnp.int32), None, data, None)

    eng._draft_decode = chaos_draft
    orig = eng._run_spec_round
    rounds_checked = [0]

    def checked(specs):
        out = orig(specs)
        errs = check_page_refcounts(eng.pool)
        assert not errs, errs
        rounds_checked[0] += 1
        return out

    eng._run_spec_round = checked
    on = _drive(eng, mk())
    assert on == off
    st = eng.stats
    assert rounds_checked[0] > 0
    assert st["spec_rounds"] > 0
    # random proposals over a 64-token vocab: near-total rejection
    assert st["spec_accepted_tokens"] < st["spec_draft_tokens"] // 2
    _spec_clean(eng)


# ---------------------------------------------------------------------------
# Ring wrap mid-draft
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [2, 4])
def test_spec_ring_wrap_during_draft_bit_identical(k):
    """Decode far past the ring capacity (cache_len 16, several laps)
    so draft write ranges straddle the wrap seam and land on pages
    holding a previous lap's entries — the masked scatter and the
    window-C attention rule must keep streams equal to the
    non-speculative paged engine through every lap."""
    cfg, params = _setup()
    rng = np.random.default_rng(8)
    mk = lambda: [Request(
        rid=i, prompt=rng.integers(0, VOCAB, size=(5 + 3 * i,))
        .astype(np.int32), max_new_tokens=40) for i in range(2)]
    rng = np.random.default_rng(8)
    off = _drive(_engine(params, cfg, cache_len=16, kv_page_len=4,
                         kv_pages=12), mk())
    rng = np.random.default_rng(8)
    eng = _engine(params, cfg, draft=0.75, draft_k=k, cache_len=16,
                  kv_page_len=4, kv_pages=12)
    on = _drive(eng, mk())
    assert on == off
    assert eng.stats["spec_rounds"] > 0
    _spec_clean(eng)


# ---------------------------------------------------------------------------
# Scratch pressure: begin_scratch fails, slot decodes normally
# ---------------------------------------------------------------------------


def test_spec_scratch_denied_under_pool_pressure_falls_back():
    """kv_pages sized to exactly two full rings: with both slots
    resident there is never a free page for scratch, so speculation
    must silently fall back to plain decode (spec_fallbacks) — a slot
    is NEVER preempted just to speculate — and resume drafting once a
    slot drains. Streams stay bit-identical throughout."""
    cfg, params = _setup()
    rng = np.random.default_rng(9)
    # near-full rings (4 pages each at cache 32 / page 8): two
    # residents own all 8 pages, so scratch allocation must fail
    mk = lambda: [Request(
        rid=i, prompt=rng.integers(0, VOCAB, size=(28 + i,))
        .astype(np.int32), max_new_tokens=10) for i in range(3)]
    rng = np.random.default_rng(9)
    off = _drive(_engine(params, cfg, cache_len=32, kv_page_len=8,
                         kv_pages=8), mk())
    rng = np.random.default_rng(9)
    eng = _engine(params, cfg, draft=0.75, draft_k=4, cache_len=32,
                  kv_page_len=8, kv_pages=8)
    on = _drive(eng, mk())
    assert on == off
    st = eng.stats
    assert st["spec_fallbacks"] > 0, st    # both-resident phases denied
    assert st["spec_rounds"] > 0, st       # single-resident tail drafts
    _spec_clean(eng)


# ---------------------------------------------------------------------------
# Interaction with preemption, spill and prefix sharing
# ---------------------------------------------------------------------------


def test_spec_with_preempt_spill_share_bit_identical():
    """The PR-8 acceptance cycle WITH a drafter: shared-prefix batch
    requests speculate, an interactive deadline preempts them (scratch
    is empty between steps by construction — preempt asserts it),
    private pages spill and fault back — streams still equal the solo
    contiguous engine bit for bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(10)
    shared = rng.integers(0, VOCAB, size=(17,)).astype(np.int32)
    inter = rng.integers(0, VOCAB, size=(40,)).astype(np.int32)
    mk = lambda: [
        Request(rid=0, prompt=shared.copy(), max_new_tokens=12,
                slo="batch"),
        Request(rid=1, prompt=np.concatenate(
            [shared, np.asarray([3], np.int32)]), max_new_tokens=12,
            slo="batch"),
        Request(rid=2, prompt=inter.copy(), max_new_tokens=3,
                slo="interactive", deadline=0.01)]
    ref = {r.rid: _solo(params, cfg, r) for r in mk()}
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=10,
                              kv_page_len=8, kv_host_pages=10,
                              kv_share=True, draft_sparsity=0.75,
                              draft_k=2))
    reqs = mk()
    assert sched.submit(reqs[0])
    for _ in range(4):
        sched.step()
    assert sched.submit(reqs[1])
    for _ in range(2):
        sched.step()
    assert sched.submit(reqs[2])
    done = []
    while sched.has_work():
        done.extend(sched.step())
    eng = sched.shards[0]
    st = sched.stats()
    assert {r.rid: r.out_tokens for r in done} == ref
    assert st["preemptions"] >= 1
    assert eng.stats["spec_rounds"] >= 1, eng.stats
    _spec_clean(eng)


# ---------------------------------------------------------------------------
# Serving-stat bugfix: reprefill resume must not re-charge prefill stats
# ---------------------------------------------------------------------------


def test_reprefill_resume_stats_equal_unpreempted_run():
    """Regression (the ``prefill_tokens_skipped`` double-count): a
    reprefill-mode resume re-admits through the shared-prefix path and
    used to charge prefill_tokens / prefill_tokens_skipped AGAIN for
    tokens already counted at first admission. Both counters must now
    equal a run of the same requests that was never preempted, with
    the resume's actual work visible in ``reprefill_tokens``."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    shared = rng.integers(0, VOCAB, size=(17,)).astype(np.int32)
    inter = rng.integers(0, VOCAB, size=(40,)).astype(np.int32)
    mk = lambda: [
        Request(rid=0, prompt=shared.copy(), max_new_tokens=10,
                slo="batch"),
        Request(rid=1, prompt=np.concatenate(
            [shared, np.asarray([3], np.int32)]), max_new_tokens=10,
            slo="batch"),
        Request(rid=2, prompt=inter.copy(), max_new_tokens=3,
                slo="interactive", deadline=0.01)]
    ref = {r.rid: _solo(params, cfg, r) for r in mk()}

    def serve(preempt):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(
                slots_per_rank=1, cache_len=64,
                policy="edf" if preempt else "fcfs", preempt=preempt,
                preempt_mode="reprefill", kv_pages=12, kv_page_len=8,
                kv_host_pages=0, kv_share=True))
        reqs = mk()
        assert sched.submit(reqs[0])
        for _ in range(4):
            sched.step()
        assert sched.submit(reqs[1])
        for _ in range(2):
            sched.step()
        assert sched.submit(reqs[2])
        done = []
        while sched.has_work():
            done.extend(sched.step())
        assert {r.rid: r.out_tokens for r in done} == ref
        return sched.stats(), sched.shards[0].stats

    base_st, base = serve(False)
    pre_st, pre = serve(True)
    assert pre_st["preemptions"] >= 1, pre_st
    assert pre["reprefill_tokens"] > 0, pre
    assert base["reprefill_tokens"] == 0, base
    # the bug charged resume tokens here a second time
    assert pre["prefill_tokens"] == base["prefill_tokens"], (pre, base)
    assert pre["prefill_tokens_skipped"] == \
        base["prefill_tokens_skipped"], (pre, base)


# ---------------------------------------------------------------------------
# Engine-level validation
# ---------------------------------------------------------------------------


def test_spec_engine_validation():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="kv_pages"):
        Engine(params, cfg, batch_slots=1, cache_len=64,
               draft_sparsity=0.5)
    with pytest.raises(ValueError, match="draft_k"):
        _engine(params, cfg, draft=0.5, draft_k=0)
    with pytest.raises(ValueError, match="cache_len"):
        _engine(params, cfg, draft=0.5, draft_k=64, cache_len=32,
                kv_page_len=8, kv_pages=8)
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    qparams = lm.init_params(KEY, qcfg)
    with pytest.raises(ValueError, match="kv_quant"):
        Engine(qparams, qcfg, batch_slots=1, cache_len=64,
               kv_pages=16, kv_page_len=8, draft_sparsity=0.5)
    with pytest.raises(ValueError, match="kv_dedup_every"):
        Engine(params, cfg, batch_slots=1, cache_len=64,
               kv_pages=16, kv_page_len=8, kv_dedup_every=4)


# ---------------------------------------------------------------------------
# Hypothesis property: arbitrary workloads, spec-on == spec-off
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10**6), k=st.sampled_from([1, 2, 4]),
           eos=st.booleans())
    def test_spec_property_bit_identical(seed, k, eos):
        """Random prompts/budgets/EOS and draft depth: the speculative
        engine's streams always equal the non-speculative paged twin,
        and the pool drains clean."""
        cfg, params = _setup()

        def mk():
            r = np.random.default_rng(seed)
            return [Request(
                rid=i,
                prompt=r.integers(0, VOCAB, size=(int(
                    r.integers(4, 20)),)).astype(np.int32),
                max_new_tokens=int(r.integers(2, 9)),
                eos_id=int(r.integers(0, VOCAB)) if eos else None)
                for i in range(3)]

        off = _drive(_engine(params, cfg, cache_len=32, kv_page_len=8,
                             kv_pages=16), mk())
        eng = _engine(params, cfg, draft=0.75, draft_k=k,
                      cache_len=32, kv_page_len=8, kv_pages=16)
        on = _drive(eng, mk())
        assert on == off
        _spec_clean(eng)
